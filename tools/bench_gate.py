"""Bench-regression gate (``tools/check.sh --bench``).

Runs the key ``benchmarks/serving_bench.py`` sections, writes
``BENCH_PR10.json`` at the repo root, and compares the tracked metrics
against a baseline read *before* the write: the committed/previous
``BENCH_PR10.json`` itself when present, else the newest other
``BENCH_*.json`` (e.g. the PR 9 baseline).  Any metric that regresses
more than the threshold (default 20%, knob: ``BENCH_REGRESSION_PCT``
env var or ``--threshold``) fails the gate with a nonzero exit.

Tracked metrics (direction-aware):

  decode_tok_per_s        serving_cb continuous decode throughput (^)
  max_decode_gap_ms       serving_chunk chunked32 worst decode stall (v)
  decode_step_ms_p512     scan-escape compiled decode step, 512-page
                          pool (v) — the per-step O(touched bytes)
                          claim in absolute terms
  decode_flatness         scan-escape t(p512)/t(p64) (v) — per-step
                          cost must stay flat as the pool grows 8x
  async_ttft_p50_ms       serving_async live-submission TTFT median
                          (v) — the async layer must not tax
                          time-to-first-token (p99 is reported but not
                          gated: 16 samples make it a max)
  tp_decode_tok_per_s     serving_tp 2-shard decode throughput (^) on
                          the forced-host-device mesh — the TP engine
                          must not rot (absolute numbers are fake-
                          device timings; the trend is what's gated)
  serving_obs_overhead_pct
                          serving_obs instrumented-vs-noop decode
                          tok/s overhead in percent (v) — the
                          observability layer's <= 3% budget
                          (docs/observability.md)
  http_ttft_p50_ms        serving_http single-replica client-side
                          TTFT median over the full wire path —
                          HTTP front door -> router -> worker ->
                          engine (v); the network edge must not rot
                          (r2 rows are reported but not gated: on a
                          single-core host they measure scheduler
                          contention, not the stack)
  quant_decode_tok_per_s  serving_quant --quant q4 --kv-dtype int8
                          decode throughput (^) — the quantized path
                          must not rot vs its own history
  quant_token_match_rate  serving_quant teacher-forced greedy
                          agreement vs fp32 (^) — the accuracy side of
                          the quantization tradeoff, bounded below by
                          QUANT_MATCH_BOUND inside the bench itself
  kv_page_capacity_ratio  serving_quant int8-vs-fp32 pages at equal
                          pool bytes (^) — the capacity side; the int8
                          page format must keep fitting >= 1.9x
  spec_decode_tok_per_s   serving_spec ``spec_decode=4`` decode
                          throughput (^) on the repetitive-text
                          workload — speculation must keep paying for
                          its verify overhead there
  spec_accept_rate        serving_spec accepted/drafted draft tokens
                          (^) — the drafter+model pairing must keep
                          accepting; a rate collapse silently turns
                          speculation into pure overhead
  slo_goodput             serving_slo protected/unprotected goodput
                          ratio at saturation (^) — SLO-aware
                          protection (priority admission + deadline
                          shedding) must keep beating the unprotected
                          run on tokens-inside-window per second

A metric present in the current run but NOT in the baseline (a freshly
landed bench, e.g. the first ``serving_tp.*`` run) is reported as
``new`` — visibly, so schema drift can neither fail the gate nor slip
through silently; it becomes comparable once this run's report is the
next baseline.  Metrics that vanished from the current run are
reported as ``dropped`` the same way.

Usage:
  python tools/bench_gate.py run [--out BENCH_PR10.json] [--threshold 20]
  python tools/bench_gate.py compare CURRENT.json BASELINE.json \
      [--threshold 20]

``compare`` is pure (no benches run) so tests can exercise the
regression logic against injected baselines.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

# metric -> (bench row name, direction); "higher" = bigger is better
METRICS: Dict[str, Tuple[str, str]] = {
    "decode_tok_per_s": ("serving_cb.continuous.decode_toks_per_s",
                         "higher"),
    "max_decode_gap_ms": ("serving_chunk.max_decode_gap_ms.chunked32",
                          "lower"),
    "decode_step_ms_p512": ("serving_scan_escape.decode_step_ms.p512",
                            "lower"),
    "decode_flatness": ("serving_scan_escape.decode_flatness", "lower"),
    "async_ttft_p50_ms": ("serving_async.ttft_p50_ms", "lower"),
    "tp_decode_tok_per_s": ("serving_tp.decode_toks_per_s.s2", "higher"),
    "serving_obs_overhead_pct": ("serving_obs.overhead_pct", "lower"),
    "http_ttft_p50_ms": ("serving_http.ttft_p50_ms.r1", "lower"),
    "quant_decode_tok_per_s": ("serving_quant.decode_toks_per_s.q4int8",
                               "higher"),
    "quant_token_match_rate": ("serving_quant.token_match_rate",
                               "higher"),
    "kv_page_capacity_ratio": ("serving_quant.page_capacity_ratio",
                               "higher"),
    "spec_decode_tok_per_s": ("serving_spec.decode_toks_per_s.k4",
                              "higher"),
    "spec_accept_rate": ("serving_spec.accept_rate", "higher"),
    "slo_goodput": ("serving_slo.goodput_ratio", "higher"),
}


def _parse_derived(s: str) -> float:
    return float(s.rstrip("x"))


def collect() -> Dict[str, object]:
    """Run the gate's bench sections and assemble the report dict."""
    from benchmarks import serving_bench

    rows: List[Tuple[str, float, str]] = []
    rows += serving_bench.serving_cb_rows()
    rows += serving_bench.serving_chunk_rows()
    rows += serving_bench.serving_async_rows()
    rows += serving_bench.serving_obs_rows()
    rows += serving_bench.serving_scan_escape_rows()
    rows += serving_bench.serving_tp_rows()
    rows += serving_bench.serving_http_rows()
    rows += serving_bench.serving_quant_rows()
    rows += serving_bench.serving_spec_rows()
    rows += serving_bench.serving_slo_rows()
    by_name = {name: derived for name, _us, derived in rows}

    metrics = {}
    for metric, (row, direction) in METRICS.items():
        if row not in by_name:
            raise RuntimeError(f"bench row {row!r} missing for {metric}")
        metrics[metric] = {"value": _parse_derived(by_name[row]),
                           "direction": direction}
    return {
        "meta": {"unix_time": time.time(),
                 "source": "tools/bench_gate.py"},
        "metrics": metrics,
        "rows": {name: derived for name, _us, derived in rows},
    }


def compare(current: Dict[str, object], baseline: Dict[str, object],
            threshold: float) -> List[str]:
    """Return regression messages (empty = gate passes).

    A metric regresses when it moves in its bad direction by more than
    ``threshold`` (fraction, e.g. 0.2) relative to the baseline.
    Metrics present in only one file never fail the gate (schema drift
    is not a regression) — :func:`schema_drift` reports them so they
    are never *silently* passed over either.
    """
    out: List[str] = []
    cur_m = current.get("metrics", {})
    base_m = baseline.get("metrics", {})
    for name, cur in cur_m.items():
        base = base_m.get(name)
        if base is None:
            continue
        cv, bv = float(cur["value"]), float(base["value"])
        direction = cur.get("direction", base.get("direction", "higher"))
        if bv == 0:
            continue
        if direction == "higher":
            bad = cv < bv * (1.0 - threshold)
            move = (bv - cv) / bv
        else:
            bad = cv > bv * (1.0 + threshold)
            move = (cv - bv) / bv
        if bad:
            out.append(
                f"{name}: {cv:g} vs baseline {bv:g} "
                f"({move * 100:.0f}% worse, direction={direction}, "
                f"threshold={threshold * 100:.0f}%)")
    return out


def schema_drift(current: Dict[str, object], baseline: Dict[str, object],
                 ) -> List[str]:
    """Metrics in exactly one of the two reports, as human-readable
    lines: ``new`` = in the current run only (first run of a fresh
    bench — tracked from now on, nothing to compare yet), ``dropped`` =
    in the baseline only.  Informational: never fails the gate, but
    always printed so a vanished or not-yet-compared metric can't pass
    silently."""
    cur_m = current.get("metrics", {})
    base_m = baseline.get("metrics", {})
    out = [f"{name}: new metric "
           f"(current {float(cur_m[name]['value']):g}, no baseline — "
           "compared from the next run)"
           for name in sorted(set(cur_m) - set(base_m))]
    out += [f"{name}: dropped metric (baseline "
            f"{float(base_m[name]['value']):g}, absent from this run)"
            for name in sorted(set(base_m) - set(cur_m))]
    return out


def load_baseline(root: str, out_path: str,
                  ) -> Tuple[Optional[Dict[str, object]], str]:
    """Pick the baseline for a ``run``: the committed/previous report
    at ``out_path`` itself (read BEFORE the run overwrites it), else
    the newest other ``BENCH_*.json`` in the repo root."""
    if os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f), os.path.basename(out_path) + " (previous)"
    cands = [p for p in glob.glob(os.path.join(root, "BENCH_*.json"))
             if os.path.abspath(p) != os.path.abspath(out_path)]
    if not cands:
        return None, ""
    best = max(cands, key=os.path.getmtime)
    with open(best) as f:
        return json.load(f), os.path.basename(best)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    run_p = sub.add_parser("run", help="run benches, write + compare")
    run_p.add_argument("--out", default="BENCH_PR10.json")
    run_p.add_argument("--threshold", type=float, default=None,
                       help="regression threshold in percent")
    cmp_p = sub.add_parser("compare", help="compare two reports")
    cmp_p.add_argument("current")
    cmp_p.add_argument("baseline")
    cmp_p.add_argument("--threshold", type=float, default=None)
    args = ap.parse_args(argv)

    pct = args.threshold
    if pct is None:
        pct = float(os.environ.get("BENCH_REGRESSION_PCT", "20"))
    threshold = pct / 100.0

    if args.cmd == "compare":
        with open(args.current) as f:
            current = json.load(f)
        with open(args.baseline) as f:
            baseline = json.load(f)
        for d in schema_drift(current, baseline):
            print(f"bench-gate {d}")
        regs = compare(current, baseline, threshold)
        for r in regs:
            print(f"bench-gate REGRESSION: {r}", file=sys.stderr)
        print("bench-gate: " + ("FAILED" if regs else "OK"))
        return 1 if regs else 0

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, root)
    out_path = os.path.join(root, args.out) \
        if not os.path.isabs(args.out) else args.out
    # read the baseline FIRST: the committed out-file is itself the
    # baseline of record, and the run below overwrites it
    baseline, base_name = load_baseline(root, out_path)
    report = collect()
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"bench-gate: wrote {out_path}")
    for m, v in report["metrics"].items():
        print(f"  {m} = {v['value']:g} ({v['direction']} is better)")
    if baseline is None:
        print("bench-gate: no baseline BENCH_*.json found — "
              "nothing to compare, gate passes")
        return 0
    regs = compare(report, baseline, threshold)
    print(f"bench-gate: baseline {base_name}, threshold {pct:.0f}%")
    for d in schema_drift(report, baseline):
        print(f"bench-gate {d}")
    for r in regs:
        print(f"bench-gate REGRESSION: {r}", file=sys.stderr)
    print("bench-gate: " + ("FAILED" if regs else "OK"))
    return 1 if regs else 0


if __name__ == "__main__":
    sys.exit(main())
