"""Render EXPERIMENTS.md tables from the dry-run sweep JSONs."""

import json


def table(path, title):
    d = json.load(open(path))
    out = [f"### {title}", ""]
    out.append("| arch | shape | note | compute_s | memory_s | coll_s | "
               "dominant | useful | GiB/dev | compile_s |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in d["results"]:
        gib = (r["bytes_per_device"] or 0) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r.get('note','') or '-'} | "
            f"{r['t_compute']:.2e} | {r['t_memory']:.2e} | "
            f"{r['t_collective']:.2e} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | {gib:.2f} | "
            f"{r.get('compile_s', 0):.0f} |")
    for f in d.get("failures", []):
        out.append(f"| {f['arch']} | {f['shape']} | FAILED | | | | | | | |")
    n_ok = len(d["results"])
    n_fail = len(d.get("failures", []))
    out.append("")
    out.append(f"*{n_ok} compiled OK, {n_fail} failed.*")
    return "\n".join(out)


if __name__ == "__main__":
    print(table("experiments/dryrun_single_pod.json",
                "Single-pod mesh 16×16 (256 chips) — baseline"))
    print()
    print(table("experiments/dryrun_multi_pod.json",
                "Multi-pod mesh 2×16×16 (512 chips) — baseline"))
