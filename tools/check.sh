#!/usr/bin/env bash
# PR gate: tier-1 tests + a short continuous-serving smoke so the
# paged-KV scheduler path is exercised on every change, plus a doc-link
# check so README.md / docs/*.md never reference a module path or CLI
# flag that no longer exists.  CI (.github/workflows/ci.yml) runs the
# same entry points, one job per lane.
#
#   tools/check.sh            # lint + docs + tier-1 + serving smoke
#   tools/check.sh --smoke    # serving smoke only (~60 s): engine
#                             # drivers + a live HTTP front door with
#                             # 2 engine-worker replicas (streamed
#                             # completion, /healthz, /metrics,
#                             # /metrics.json via repro.obs.validate),
#                             # then a chaos lane: REPRO_FAULTS-injected
#                             # worker latency, an overload burst that
#                             # must shed (429 + Retry-After), and a
#                             # SIGKILLed worker the fleet must survive
#                             # (breaker opens, requests fail over)
#   tools/check.sh --docs     # doc-link check only (<1 s)
#   tools/check.sh --lint     # ruff check + format check (skips with a
#                             # warning when ruff is not installed)
#   tools/check.sh --bench    # bench-regression gate: runs the key
#                             # serving_bench sections, writes
#                             # BENCH_PR10.json, fails on a >20%
#                             # regression vs the newest BENCH_*.json
#                             # (knob: BENCH_REGRESSION_PCT=<percent>)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

lint_check() {
    echo "== lint: ruff =="
    if ! command -v ruff >/dev/null 2>&1; then
        echo "lint: ruff not installed — skipping (CI's lint job runs it)"
        return 0
    fi
    ruff check src benchmarks tools tests examples
    # formatting is advisory: the codebase is hand-formatted (aligned
    # jax shapes); `ruff check` (E/W/F in pyproject.toml) is the gate
    ruff format --check src benchmarks tools tests examples \
        || echo "lint: ruff format differences (advisory, not a gate)"
    echo "lint: OK"
}

doc_check() {
    echo "== doc check: module paths and CLI flags =="
    local docs=(README.md docs/*.md) fail=0

    # 1. literal file paths like src/repro/serving/kv_pool.py
    for p in $(grep -hoE 'src/repro/[A-Za-z0-9_/.-]+\.py' "${docs[@]}" \
                   | sort -u); do
        if [[ ! -f "$p" ]]; then
            echo "doc-check: missing file referenced in docs: $p"
            fail=1
        fi
    done

    # 2. dotted module paths like repro.launch.serve (last component may
    #    be an attribute, so also accept the parent resolving)
    for m in $(grep -hoE '\brepro\.[a-z0-9_.]+[a-z0-9_]' "${docs[@]}" \
                   | sort -u); do
        local f="src/${m//./\/}" parent
        parent="$(dirname "$f")"
        if [[ ! -f "$f.py" && ! -d "$f" && ! -f "$parent.py" \
              && ! -d "$parent" ]]; then
            echo "doc-check: missing module referenced in docs: $m"
            fail=1
        fi
    done

    # 3. CLI flags like --prefill-chunk must appear in some source file
    #    under src/, benchmarks/ or tools/ (argparse / script flags)
    for flag in $(grep -hoE '(^|[^-])--[a-z][a-z0-9-]+' "${docs[@]}" \
                      | grep -oE '\-\-[a-z][a-z0-9-]+' | sort -u); do
        if ! grep -rqF -- "\"$flag\"" src benchmarks tools; then
            echo "doc-check: flag $flag in docs but not in any CLI"
            fail=1
        fi
    done

    if [[ "$fail" != 0 ]]; then
        echo "doc check: FAILED"
        return 1
    fi
    echo "doc check: OK"
}

if [[ "${1:-}" == "--docs" ]]; then
    doc_check
    exit 0
fi

if [[ "${1:-}" == "--lint" ]]; then
    lint_check
    exit 0
fi

if [[ "${1:-}" == "--bench" ]]; then
    echo "== bench-regression gate (serving_bench key sections) =="
    python tools/bench_gate.py run
    exit 0
fi

if [[ "${1:-}" != "--smoke" ]]; then
    lint_check
    doc_check
    echo "== tier-1: pytest =="
    python -m pytest -x -q
fi

echo "== serving smoke: continuous engine, tiny arch =="
python -m repro.launch.serve --arch qwen3-1.7b --engine continuous \
    --max-new 8 --max-running 4 --page-size 8 --prefill-chunk 16 \
    --warmup-steps 0
echo "== serving smoke: async engine, live submit/stream =="
python -m repro.launch.serve --arch qwen3-1.7b --engine async \
    --max-new 8 --max-running 4 --page-size 8 --prefill-chunk 16 \
    --warmup-steps 0
echo "== serving smoke: bucket baseline parity path =="
python -m repro.launch.serve --arch qwen3-1.7b --engine bucket \
    --max-new 8 --warmup-steps 0
echo "== serving smoke: quantized path (q4 weights, int8 KV pages) =="
python -m repro.launch.serve --arch qwen3-1.7b --engine async \
    --quant q4 --kv-dtype int8 --max-new 8 --max-running 4 \
    --page-size 8 --prefill-chunk 16 --warmup-steps 0
echo "== serving smoke: tensor-parallel paged engine (2 shards) =="
python -m repro.launch.serve --arch qwen3-1.7b --engine continuous \
    --tp-shards 2 --max-new 8 --max-running 4 --page-size 8 \
    --warmup-steps 0
echo "== serving smoke: observability exports (async, 2 shards) =="
OBS_TMP="$(mktemp -d)"
trap 'rm -rf "$OBS_TMP"' EXIT
python -m repro.launch.serve --arch qwen3-1.7b --engine async \
    --tp-shards 2 --max-new 8 --max-running 4 --page-size 8 \
    --prefill-chunk 16 --warmup-steps 0 \
    --metrics-json "$OBS_TMP/metrics.json" --trace "$OBS_TMP/trace.jsonl"
python -m repro.obs.validate --metrics "$OBS_TMP/metrics.json" \
    --trace "$OBS_TMP/trace.jsonl" \
    --require-gauge kv_pool.pages_free:node,shard
echo "== serving smoke: self-speculative decoding (async, k=4) =="
python -m repro.launch.serve --arch qwen3-1.7b --engine async \
    --spec-decode 4 --max-new 8 --max-running 4 --page-size 8 \
    --prefill-chunk 16 --warmup-steps 0 \
    --metrics-json "$OBS_TMP/spec_metrics.json"
python -m repro.obs.validate --metrics "$OBS_TMP/spec_metrics.json" \
    --require-counter spec.accepted
echo "== serving smoke: http front door, router over 2 replicas =="
python -m repro.launch.serve --arch tiny --engine async --http \
    --replicas 2 --port 0 --port-file "$OBS_TMP/http.port" &
SERVE_PID=$!
for _ in $(seq 1 600); do
    [[ -s "$OBS_TMP/http.port" ]] && break
    if ! kill -0 "$SERVE_PID" 2>/dev/null; then
        echo "smoke: http serve exited before listening"
        exit 1
    fi
    sleep 0.5
done
[[ -s "$OBS_TMP/http.port" ]] || { echo "smoke: no port file"; exit 1; }
python - "$(cat "$OBS_TMP/http.port")" "$OBS_TMP/http_metrics.json" <<'PY'
import json
import sys
import urllib.request

port, out = int(sys.argv[1]), sys.argv[2]
base = f"http://127.0.0.1:{port}"
body = json.dumps({"prompt": list(range(1, 40)), "max_tokens": 4,
                   "stream": True}).encode()
req = urllib.request.Request(
    base + "/v1/completions", data=body,
    headers={"Content-Type": "application/json"})
toks = []
with urllib.request.urlopen(req, timeout=300) as resp:
    for line in resp:
        payload = line.strip()[5:].strip() \
            if line.startswith(b"data:") else None
        if payload is None or not payload:
            continue
        if payload == b"[DONE]":
            break
        ev = json.loads(payload)
        if "error" in ev:
            sys.exit(f"smoke: stream error: {ev['error']}")
        if "token" in ev:
            toks.append(ev["token"])
assert len(toks) == 4, f"smoke: wanted 4 streamed tokens, got {toks}"
health = json.load(urllib.request.urlopen(base + "/healthz", timeout=30))
assert health.get("status") == "ok", health
prom = urllib.request.urlopen(base + "/metrics", timeout=30).read()
assert b"http_requests" in prom and b"router_requests" in prom, prom[:300]
with urllib.request.urlopen(base + "/metrics.json", timeout=30) as r:
    open(out, "wb").write(r.read())
print(f"smoke: streamed {toks} over {base}")
PY
python -m repro.obs.validate --metrics "$OBS_TMP/http_metrics.json" \
    --require-gauge router.inflight:replica \
    --require-counter router.requests:replica
kill -TERM "$SERVE_PID"
wait "$SERVE_PID"
echo "== serving smoke: chaos lane (injected faults + SIGKILL) =="
# two replicas under injected 30ms/step worker latency, a 1-deep
# admission gate so a burst must shed, and a hair-trigger breaker so
# one worker SIGKILL opens it; the fleet must keep serving throughout
REPRO_FAULTS="step.latency_ms=30" \
python -m repro.launch.serve --arch tiny --engine async --http \
    --replicas 2 --port 0 --port-file "$OBS_TMP/chaos.port" \
    --breaker-threshold 1 --max-inflight 1 &
CHAOS_PID=$!
for _ in $(seq 1 600); do
    [[ -s "$OBS_TMP/chaos.port" ]] && break
    if ! kill -0 "$CHAOS_PID" 2>/dev/null; then
        echo "smoke: chaos serve exited before listening"
        exit 1
    fi
    sleep 0.5
done
[[ -s "$OBS_TMP/chaos.port" ]] || { echo "smoke: no chaos port"; exit 1; }
CHAOS_WORKER=$(pgrep -P "$CHAOS_PID" -f "repro.serving.worker" | head -1)
[[ -n "$CHAOS_WORKER" ]] || { echo "smoke: no worker to kill"; exit 1; }
python - "$(cat "$OBS_TMP/chaos.port")" "$CHAOS_WORKER" \
    "$OBS_TMP/chaos_metrics.json" <<'PY'
import json
import os
import signal
import sys
import threading
import urllib.error
import urllib.request

port, victim, out = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
base = f"http://127.0.0.1:{port}"


def post(prompt, timeout=300):
    """Blocked completion; returns (status, headers, body-dict)."""
    body = json.dumps({"prompt": prompt, "max_tokens": 4}).encode()
    req = urllib.request.Request(
        base + "/v1/completions", data=body,
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, dict(resp.headers), json.load(resp)
    except urllib.error.HTTPError as e:
        return e.code, dict(e.headers), json.loads(e.read() or b"{}")


# 1. overload burst: 6 concurrent against a 1-deep gate under 30ms/step
#    injected latency — the extras must shed as 429 + Retry-After with
#    a structured, retryable error body
results = []
lock = threading.Lock()


def worker(i):
    r = post(list(range(1 + i, 30 + i)))
    with lock:
        results.append(r)


threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
[t.start() for t in threads]
[t.join() for t in threads]
sheds = [(h, b) for s, h, b in results if s == 429]
assert any(s == 200 for s, _h, _b in results), results
assert sheds, "smoke: 6-burst against --max-inflight 1 never shed"
for h, b in sheds:
    assert h.get("Retry-After"), f"smoke: 429 without Retry-After: {h}"
    err = b.get("error", {})
    assert err.get("type") == "Overloaded" and err.get("retryable"), b
print(f"smoke: chaos burst shed {len(sheds)}/6 with Retry-After")

# 2. SIGKILL one worker, then keep serving: the router must open the
#    breaker on the corpse and fail over — every request still succeeds
os.kill(victim, signal.SIGKILL)
for i in range(12):
    s, _h, b = post(list(range(40 + 3 * i, 70 + 3 * i)))
    assert s == 200, f"smoke: post-kill request {i} failed: {s} {b}"
    assert len(b["choices"][0]["tokens"]) == 4, b
print("smoke: 12/12 completions served across a SIGKILLed worker")

with urllib.request.urlopen(base + "/metrics.json", timeout=30) as r:
    open(out, "wb").write(r.read())
PY
python -m repro.obs.validate --metrics "$OBS_TMP/chaos_metrics.json" \
    --require-counter http.shed \
    --require-counter router.breaker_open
kill -TERM "$CHAOS_PID"
wait "$CHAOS_PID" || true
echo "check.sh: OK"
