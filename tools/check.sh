#!/usr/bin/env bash
# PR gate: tier-1 tests + a short continuous-serving smoke so the
# paged-KV scheduler path is exercised on every change.
#
#   tools/check.sh            # full tier-1 + serving smoke
#   tools/check.sh --smoke    # serving smoke only (~30 s)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--smoke" ]]; then
    echo "== tier-1: pytest =="
    python -m pytest -x -q
fi

echo "== serving smoke: continuous engine, tiny arch =="
python -m repro.launch.serve --arch qwen3-1.7b --engine continuous \
    --max-new 8 --max-running 4 --page-size 8 --warmup-steps 0
echo "== serving smoke: bucket baseline parity path =="
python -m repro.launch.serve --arch qwen3-1.7b --engine bucket \
    --max-new 8 --warmup-steps 0
echo "check.sh: OK"
