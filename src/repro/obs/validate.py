"""Schema validation for observability exports (no jsonschema dep).

Two documents leave the serving stack (``docs/observability.md``):

* the **metrics snapshot** (``--metrics-json``, JSON) — checked by
  :func:`validate_snapshot` against the shape
  ``MetricsRegistry.snapshot`` produces: ``version`` plus
  ``counters`` / ``gauges`` / ``histograms`` lists whose entries carry
  ``name``/``labels``/``value`` (histograms: aligned
  ``buckets``/``counts``, consistent ``count``);
* the **request trace** (``--trace``, JSONL) — checked by
  :func:`validate_trace_file` via ``trace.validate_events`` (per-uid
  monotone stamps, QUEUED-first, terminal lifecycle).

The module doubles as the smoke gate's CLI::

    python -m repro.obs.validate --metrics M.json --trace T.jsonl \
        [--require-gauge kv_pool.pages_free:node,shard] \
        [--require-counter router.requests:replica]

``--require-gauge`` / ``--require-counter`` (``NAME[:label,label]``)
additionally assert the snapshot contains that series with the given
label keys — how ``tools/check.sh --smoke`` pins the per-(node, shard)
pool gauges of a ``--tp-shards 2`` run and the per-replica ``router.*``
series of a ``--http --replicas 2`` run.  Exit 0 = all documents valid.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from .metrics import SNAPSHOT_VERSION
from .trace import load_jsonl, validate_events


def validate_snapshot(doc: object) -> List[str]:
    """Problems with a ``MetricsRegistry.snapshot()`` document (empty
    list = valid)."""
    out: List[str] = []
    if not isinstance(doc, dict):
        return [f"snapshot is {type(doc).__name__}, not an object"]
    if doc.get("version") != SNAPSHOT_VERSION:
        out.append(f"version {doc.get('version')!r} != "
                   f"{SNAPSHOT_VERSION}")
    for kind in ("counters", "gauges", "histograms"):
        entries = doc.get(kind)
        if not isinstance(entries, list):
            out.append(f"{kind}: missing or not a list")
            continue
        for i, e in enumerate(entries):
            where = f"{kind}[{i}]"
            if not isinstance(e, dict):
                out.append(f"{where}: not an object")
                continue
            if not isinstance(e.get("name"), str) or not e.get("name"):
                out.append(f"{where}: missing name")
            if not isinstance(e.get("labels"), dict):
                out.append(f"{where}: missing labels object")
            if kind == "histograms":
                out.extend(_check_histogram(where, e))
            elif not isinstance(e.get("value"), (int, float)):
                out.append(f"{where}: missing numeric value")
    return out


def _check_histogram(where: str, e: Dict[str, object]) -> List[str]:
    out: List[str] = []
    buckets, counts = e.get("buckets"), e.get("counts")
    if not isinstance(buckets, list) or not buckets:
        out.append(f"{where}: missing buckets")
    if not isinstance(counts, list):
        out.append(f"{where}: missing counts")
    if (isinstance(buckets, list) and isinstance(counts, list)
            and len(counts) != len(buckets) + 1):
        out.append(f"{where}: {len(counts)} counts for "
                   f"{len(buckets)} buckets (want buckets+1)")
    n = e.get("count")
    if not isinstance(n, int):
        out.append(f"{where}: missing integer count")
    elif isinstance(counts, list) and sum(counts) != n:
        out.append(f"{where}: counts sum {sum(counts)} != count {n}")
    if not isinstance(e.get("sum"), (int, float)):
        out.append(f"{where}: missing numeric sum")
    return out


def validate_snapshot_file(path: str) -> List[str]:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    return validate_snapshot(doc)


def validate_trace_file(path: str,
                        require_terminal: bool = True) -> List[str]:
    try:
        events = load_jsonl(path)
    except (OSError, json.JSONDecodeError, KeyError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    if not events:
        return [f"{path}: no trace events"]
    return validate_events(events, require_terminal=require_terminal)


def _require_series(doc: Dict[str, object], kind: str, name: str,
                    label_keys: List[str]) -> List[str]:
    hits = [g for g in doc.get(kind, [])
            if g.get("name") == name
            and all(k in g.get("labels", {}) for k in label_keys)]
    if not hits:
        want = name + (":" + ",".join(label_keys) if label_keys else "")
        return [f"snapshot has no {kind[:-1]} {want}"]
    return []


def require_gauge(doc: Dict[str, object], name: str,
                  label_keys: List[str]) -> List[str]:
    """Assert the snapshot has >= 1 ``name`` gauge series carrying
    every label key in ``label_keys``."""
    return _require_series(doc, "gauges", name, label_keys)


def require_counter(doc: Dict[str, object], name: str,
                    label_keys: List[str]) -> List[str]:
    """Counter twin of :func:`require_gauge`."""
    return _require_series(doc, "counters", name, label_keys)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--metrics", help="metrics snapshot JSON to check")
    ap.add_argument("--trace", help="trace JSONL to check")
    ap.add_argument("--require-gauge", action="append", default=[],
                    metavar="NAME[:label,label]",
                    help="snapshot must contain this gauge (with these "
                         "label keys)")
    ap.add_argument("--require-counter", action="append", default=[],
                    metavar="NAME[:label,label]",
                    help="snapshot must contain this counter (with "
                         "these label keys)")
    args = ap.parse_args(argv)
    if not args.metrics and not args.trace:
        ap.error("nothing to validate: pass --metrics and/or --trace")

    problems: List[str] = []
    if args.metrics:
        problems += validate_snapshot_file(args.metrics)
        if not problems and (args.require_gauge or args.require_counter):
            with open(args.metrics) as f:
                doc = json.load(f)
            for spec in args.require_gauge:
                name, _, keys = spec.partition(":")
                problems += require_gauge(
                    doc, name, [k for k in keys.split(",") if k])
            for spec in args.require_counter:
                name, _, keys = spec.partition(":")
                problems += require_counter(
                    doc, name, [k for k in keys.split(",") if k])
    if args.trace:
        problems += validate_trace_file(args.trace)

    for p in problems:
        print(f"obs-validate: {p}", file=sys.stderr)
    print("obs-validate: " + ("FAILED" if problems else "OK"))
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
