"""repro.obs — serving-wide observability substrate.

Lightweight (stdlib-only, jax-free) telemetry the whole serving stack
reports through, replacing the ad-hoc counters that accumulated in
PRs 1–5 (``EngineCore.phase_s``, ``decode_gaps_s``, pool stat ints):

* :mod:`repro.obs.metrics` — :class:`MetricsRegistry`: counters,
  gauges and fixed-bucket histograms with labels, a JSON snapshot API
  and Prometheus text exposition.  :class:`NullRegistry` is the no-op
  twin the ``serving_obs.overhead_pct`` bench compares against.
* :mod:`repro.obs.trace` — :class:`RequestTracer`: per-request
  lifecycle span events (QUEUED → PREFILLING → DECODING → FINISHED /
  CANCELLED / FAILED, plus per-chunk prefill / preemption / CoW
  annotations) stamped from the engine's injected Clock, exported as
  JSONL keyed by request uid.
* :mod:`repro.obs.validate` — schema checks for both exports (used by
  ``tools/check.sh --smoke`` and the tests); also a CLI:
  ``python -m repro.obs.validate --metrics M.json --trace T.jsonl``.

The metric catalogue, trace schema and overhead budget live in
``docs/observability.md``.
"""

from .metrics import (DEFAULT_BUCKETS_MS, Counter, Gauge, Histogram,
                      MetricsRegistry, NullRegistry)
from .trace import (NullTracer, RequestTracer, TraceEvent, load_jsonl,
                    reconstruct_spans, validate_events)
from .validate import (validate_snapshot, validate_snapshot_file,
                       validate_trace_file)

__all__ = [
    "Counter", "DEFAULT_BUCKETS_MS", "Gauge", "Histogram",
    "MetricsRegistry", "NullRegistry", "NullTracer", "RequestTracer",
    "TraceEvent", "load_jsonl", "reconstruct_spans", "validate_events",
    "validate_snapshot", "validate_snapshot_file", "validate_trace_file",
]
