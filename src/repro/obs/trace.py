"""Per-request trace spans for the serving stack.

Every request served by the paged engines walks the lifecycle
``QUEUED -> PREFILLING -> DECODING -> FINISHED`` (or ``CANCELLED`` /
``FAILED``, with ``PREEMPTED`` bouncing back to ``QUEUED``).  The
:class:`RequestTracer` records that walk as an append-only stream of
**events** — one ``(uid, name, t, attrs)`` tuple per state transition
or annotation, stamped from the engine's injected
:class:`~repro.serving.core.Clock` so tests trace in virtual time and
production traces in monotonic wall seconds.

Event names (``docs/observability.md`` "Trace schema"):

=============  ========================================================
state events   ``QUEUED``, ``PREFILLING``, ``DECODING``, ``FINISHED``,
               ``CANCELLED``, ``FAILED`` — each opens the span the
               next state event closes
annotations    ``PREFILL_CHUNK`` (one per chunk: ``start``/``n``
               attrs), ``PREEMPTED`` (recompute restart — next state
               event is a fresh ``PREFILLING``), ``COW`` (page clones
               applied before this request's chunk resumed)
=============  ========================================================

Export is JSONL — one ``{"uid":…, "event":…, "t":…, …attrs}`` object
per line, keyed by request uid (:meth:`RequestTracer.to_jsonl`) —
chosen over a nested document so a long-running server can append and
rotate.  :func:`reconstruct_spans` folds an event stream back into
per-uid ``(state, t_start, t_end)`` spans; :func:`validate_events`
checks the invariants the acceptance bench asserts (per-uid monotone
stamps, lifecycle starts at QUEUED and reaches a terminal state).

:class:`NullTracer` is the no-op twin (tracing disabled / overhead
baseline).  ``event()`` appends one tuple to a list — O(1), no
formatting — so tracing sits inside the <= 3% observability budget.
"""

from __future__ import annotations

import json
import threading
from typing import Dict, IO, Iterable, List, Optional, Tuple

STATE_EVENTS = ("QUEUED", "PREFILLING", "DECODING", "FINISHED",
                "CANCELLED", "FAILED")
TERMINAL_EVENTS = ("FINISHED", "CANCELLED", "FAILED")
ANNOTATION_EVENTS = ("PREFILL_CHUNK", "PREEMPTED", "COW")


class TraceEvent(Tuple[int, str, float, dict]):
    """Lightweight view: ``(uid, name, t, attrs)`` named accessors."""

    __slots__ = ()

    @property
    def uid(self) -> int:
        return self[0]

    @property
    def name(self) -> str:
        return self[1]

    @property
    def t(self) -> float:
        return self[2]

    @property
    def attrs(self) -> dict:
        return self[3]


class RequestTracer:
    """Append-only per-request event recorder (see module docstring).

    Writes come from one engine thread (the core's driver or the async
    stepper); reads (``events``/``to_jsonl``) may come from another, so
    the buffer is guarded by a lock taken only on read and on the
    rare-by-design append (one tuple per state change, not per token).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: List[TraceEvent] = []

    def __len__(self) -> int:
        return len(self._events)

    @property
    def enabled(self) -> bool:
        return True

    def event(self, uid: int, name: str, t: float, **attrs: object) -> None:
        with self._lock:
            self._events.append(TraceEvent((uid, name, t, attrs)))

    # -- read side --------------------------------------------------------
    def events(self, uid: Optional[int] = None) -> List[TraceEvent]:
        with self._lock:
            evs = list(self._events)
        if uid is None:
            return evs
        return [e for e in evs if e.uid == uid]

    def spans(self, uid: int) -> List[Tuple[str, float, float]]:
        """This uid's reconstructed ``(state, t_start, t_end)`` spans
        (the last span's end repeats its start when still open)."""
        return reconstruct_spans(self.events(uid)).get(uid, [])

    def to_jsonl(self, f: IO[str]) -> int:
        """Write every event as one JSON object per line; returns the
        number of lines written."""
        evs = self.events()
        for e in evs:
            doc = {"uid": e.uid, "event": e.name, "t": e.t}
            doc.update(e.attrs)
            f.write(json.dumps(doc, sort_keys=True) + "\n")
        return len(evs)

    def write_jsonl(self, path: str) -> int:
        with open(path, "w") as f:
            return self.to_jsonl(f)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()


class NullTracer(RequestTracer):
    """No-op twin: tracing disabled (and the overhead baseline)."""

    @property
    def enabled(self) -> bool:
        return False

    def event(self, uid: int, name: str, t: float, **attrs: object) -> None:
        pass


def load_jsonl(path: str) -> List[TraceEvent]:
    """Read a ``to_jsonl`` export back into events."""
    out: List[TraceEvent] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            doc = json.loads(line)
            attrs = {k: v for k, v in doc.items()
                     if k not in ("uid", "event", "t")}
            out.append(TraceEvent(
                (int(doc["uid"]), str(doc["event"]), float(doc["t"]),
                 attrs)))
    return out


def reconstruct_spans(events: Iterable[TraceEvent],
                      ) -> Dict[int, List[Tuple[str, float, float]]]:
    """Fold a (time-ordered per uid) event stream into per-uid spans:
    each *state* event opens a span the next state event closes;
    annotations never open spans.  A terminal state is a zero-length
    span marking the end stamp."""
    out: Dict[int, List[Tuple[str, float, float]]] = {}
    open_span: Dict[int, Tuple[str, float]] = {}
    for e in events:
        if e.name not in STATE_EVENTS:
            continue
        prev = open_span.get(e.uid)
        if prev is not None:
            out.setdefault(e.uid, []).append((prev[0], prev[1], e.t))
        open_span[e.uid] = (e.name, e.t)
    for uid, (name, t) in open_span.items():
        out.setdefault(uid, []).append((name, t, t))
    return out


def validate_events(events: Iterable[TraceEvent],
                    require_terminal: bool = True) -> List[str]:
    """Lifecycle invariants; returns human-readable problems (empty =
    valid).  Checks, per uid: stamps monotone non-decreasing in stream
    order, first state event is QUEUED, nothing follows a terminal
    event, and (``require_terminal``) the lifecycle reaches FINISHED /
    CANCELLED / FAILED."""
    problems: List[str] = []
    last_t: Dict[int, float] = {}
    first_state: Dict[int, str] = {}
    terminal: Dict[int, str] = {}
    seen: Dict[int, int] = {}
    for e in events:
        seen[e.uid] = seen.get(e.uid, 0) + 1
        if e.name not in STATE_EVENTS + ANNOTATION_EVENTS:
            problems.append(f"uid {e.uid}: unknown event {e.name!r}")
        if e.uid in last_t and e.t < last_t[e.uid] - 1e-12:
            problems.append(
                f"uid {e.uid}: non-monotone stamp {e.t!r} after "
                f"{last_t[e.uid]!r} ({e.name})")
        last_t[e.uid] = e.t
        if e.uid in terminal:
            problems.append(
                f"uid {e.uid}: event {e.name} after terminal "
                f"{terminal[e.uid]}")
        if e.name in STATE_EVENTS and e.uid not in first_state:
            first_state[e.uid] = e.name
        if e.name in TERMINAL_EVENTS:
            terminal[e.uid] = e.name
    for uid, name in first_state.items():
        if name != "QUEUED":
            problems.append(f"uid {uid}: lifecycle starts at {name}, "
                            "not QUEUED")
    if require_terminal:
        for uid in seen:
            if uid not in terminal:
                problems.append(f"uid {uid}: no terminal event")
    return problems
