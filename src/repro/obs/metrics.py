"""Dependency-free metrics registry for the serving stack.

ArcLight's serving claims are all *measurements* — cross-NUMA page
traffic, scheduling stalls, phase splits — so the stack needs one
substrate every layer reports through instead of ad-hoc counters per
module.  Three instrument kinds, modelled on the Prometheus data
model but with zero dependencies:

* :class:`Counter` — monotonically increasing float (``inc``);
* :class:`Gauge` — last-write-wins float (``set``/``inc``);
* :class:`Histogram` — fixed-bucket distribution (``observe``) with
  cumulative bucket counts, sum/count, and quantile *estimates* by
  linear interpolation inside the winning bucket.

Every instrument supports **labels** (``labels(node=0, shard=1)``
returns a child bound to that label set), so one metric family covers
per-(node, shard) pool gauges, per-shard dispatch times, etc.

Two export surfaces:

* :meth:`MetricsRegistry.snapshot` — a plain-dict JSON document
  (schema checked by ``repro.obs.validate``), what ``--metrics-json``
  writes and benches assert on;
* :meth:`MetricsRegistry.to_prometheus` — the Prometheus text
  exposition format (``name{label="v"} value`` plus
  ``_bucket``/``_sum``/``_count`` series for histograms; dots in
  metric names become underscores), ready for a future HTTP
  ``/metrics`` endpoint.

:class:`NullRegistry` is the no-op twin: same API, every operation a
``pass``.  The ``serving_obs.*`` bench serves the same workload under
both and gates the instrumentation overhead (<= 3% decode tok/s —
``docs/observability.md`` "Overhead budget").  Hot-path discipline:
engines resolve instruments **once at construction** (attribute
lookups, not registry dict lookups, inside ``step()``).

Thread-safety: instrument writes are single-``dict``-op (atomic under
the GIL) and the async stepper is the only writer of engine metrics;
``snapshot``/``to_prometheus`` take a consistent point-in-time copy
under the registry lock.
"""

from __future__ import annotations

import bisect
import json
import threading
from typing import Dict, Iterable, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]

SNAPSHOT_VERSION = 1

#: default histogram buckets (milliseconds): sub-ms dispatches up to
#: multi-second stalls, roughly log-spaced
DEFAULT_BUCKETS_MS = (0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
                      100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0)


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class _Instrument:
    """One metric family: name + help + per-label-set series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help

    def labels(self, **labels: object) -> "_Instrument":
        raise NotImplementedError


class Counter(_Instrument):
    kind = "counter"

    def __init__(self, name: str, help: str = "") -> None:
        super().__init__(name, help)
        self._series: Dict[LabelKey, float] = {}

    def inc(self, v: float = 1.0, **labels: object) -> None:
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0.0) + v

    def value(self, **labels: object) -> float:
        return self._series.get(_label_key(labels), 0.0)

    def labels(self, **labels: object) -> "_BoundCounter":
        return _BoundCounter(self, _label_key(labels))

    def reset(self) -> None:
        self._series.clear()


class _BoundCounter:
    """Counter child bound to one label set (hot-path handle)."""

    __slots__ = ("_c", "_key")

    def __init__(self, c: Counter, key: LabelKey) -> None:
        self._c, self._key = c, key

    def inc(self, v: float = 1.0) -> None:
        s = self._c._series
        s[self._key] = s.get(self._key, 0.0) + v


class Gauge(_Instrument):
    kind = "gauge"

    def __init__(self, name: str, help: str = "") -> None:
        super().__init__(name, help)
        self._series: Dict[LabelKey, float] = {}

    def set(self, v: float, **labels: object) -> None:
        self._series[_label_key(labels)] = float(v)

    def inc(self, v: float = 1.0, **labels: object) -> None:
        key = _label_key(labels)
        self._series[key] = self._series.get(key, 0.0) + v

    def value(self, **labels: object) -> float:
        return self._series.get(_label_key(labels), 0.0)

    def labels(self, **labels: object) -> "_BoundGauge":
        return _BoundGauge(self, _label_key(labels))

    def reset(self) -> None:
        self._series.clear()


class _BoundGauge:
    __slots__ = ("_g", "_key")

    def __init__(self, g: Gauge, key: LabelKey) -> None:
        self._g, self._key = g, key

    def set(self, v: float) -> None:
        self._g._series[self._key] = float(v)


class _HistSeries:
    """One label set's distribution: cumulative-style bucket counts
    kept as per-bucket tallies (cumulated on export)."""

    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int) -> None:
        self.counts = [0] * (n_buckets + 1)     # +1 = +Inf overflow
        self.sum = 0.0
        self.count = 0


class Histogram(_Instrument):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = DEFAULT_BUCKETS_MS) -> None:
        super().__init__(name, help)
        bs = sorted(float(b) for b in buckets)
        if not bs:
            raise ValueError(f"histogram {name}: need >= 1 bucket bound")
        self.buckets: Tuple[float, ...] = tuple(bs)
        self._series: Dict[LabelKey, _HistSeries] = {}

    def _get(self, key: LabelKey) -> _HistSeries:
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries(len(self.buckets))
        return s

    def observe(self, v: float, **labels: object) -> None:
        s = self._get(_label_key(labels))
        s.counts[bisect.bisect_left(self.buckets, v)] += 1
        s.sum += v
        s.count += 1

    def labels(self, **labels: object) -> "_BoundHistogram":
        return _BoundHistogram(self, _label_key(labels))

    def value(self, **labels: object) -> Tuple[float, int]:
        """(sum, count) for one label set."""
        s = self._series.get(_label_key(labels))
        return (s.sum, s.count) if s is not None else (0.0, 0)

    def quantile(self, q: float, **labels: object) -> float:
        """Quantile *estimate* from the bucket counts: linear
        interpolation inside the bucket the rank lands in (the overflow
        bucket clamps to the top bound).  0.0 when empty."""
        s = self._series.get(_label_key(labels))
        if s is None or s.count == 0:
            return 0.0
        rank = q * s.count
        seen = 0.0
        lo = 0.0
        for i, n in enumerate(s.counts):
            if n == 0:
                continue
            hi = (self.buckets[i] if i < len(self.buckets)
                  else self.buckets[-1])
            if seen + n >= rank:
                frac = min(max((rank - seen) / n, 0.0), 1.0)
                return lo + frac * (hi - lo)
            seen += n
            lo = hi
        return self.buckets[-1]

    def reset(self) -> None:
        self._series.clear()


class _BoundHistogram:
    __slots__ = ("_h", "_key")

    def __init__(self, h: Histogram, key: LabelKey) -> None:
        self._h, self._key = h, key

    def observe(self, v: float) -> None:
        s = self._h._get(self._key)
        s.counts[bisect.bisect_left(self._h.buckets, v)] += 1
        s.sum += v
        s.count += 1


class MetricsRegistry:
    """Name -> instrument map with JSON + Prometheus export.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent,
    so layers can resolve the same family independently); a name
    re-registered as a different kind raises.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Instrument] = {}

    # -- registration ---------------------------------------------------
    def _get_or_create(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}")
                return m
            m = cls(name, help, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS_MS,
                  ) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Instrument]:
        return self._metrics.get(name)

    def reset(self) -> None:
        """Zero every series (run-scoped accounting: the engines call
        this from ``reset_run_stats`` so per-run reports start clean)."""
        with self._lock:
            for m in self._metrics.values():
                m.reset()

    # -- export ----------------------------------------------------------
    def snapshot(self) -> Dict[str, object]:
        """Point-in-time JSON document (see ``repro.obs.validate`` for
        the schema): one entry per (metric, label set)."""
        with self._lock:
            counters, gauges, hists = [], [], []
            for name in sorted(self._metrics):
                m = self._metrics[name]
                if isinstance(m, (Counter, Gauge)):
                    dest = counters if isinstance(m, Counter) else gauges
                    for key, v in sorted(m._series.items()):
                        dest.append({"name": name, "labels": dict(key),
                                     "value": v})
                elif isinstance(m, Histogram):
                    for key, s in sorted(m._series.items()):
                        hists.append({
                            "name": name, "labels": dict(key),
                            "buckets": list(m.buckets),
                            "counts": list(s.counts),
                            "sum": s.sum, "count": s.count,
                            "p50": m.quantile(0.5, **dict(key)),
                            "p99": m.quantile(0.99, **dict(key)),
                        })
        return {"version": SNAPSHOT_VERSION, "counters": counters,
                "gauges": gauges, "histograms": hists}

    def snapshot_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4).  Dots in metric
        names become underscores (``serving.decode.itl_ms`` ->
        ``serving_decode_itl_ms``)."""
        out: List[str] = []
        with self._lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                pname = name.replace(".", "_")
                if m.help:
                    out.append(f"# HELP {pname} {m.help}")
                out.append(f"# TYPE {pname} {m.kind}")
                if isinstance(m, (Counter, Gauge)):
                    for key, v in sorted(m._series.items()):
                        out.append(f"{pname}{_fmt_labels(key)} {v:g}")
                elif isinstance(m, Histogram):
                    for key, s in sorted(m._series.items()):
                        cum = 0
                        for b, n in zip(m.buckets, s.counts):
                            cum += n
                            out.append(
                                f"{pname}_bucket"
                                f"{_fmt_labels(key, le=f'{b:g}')} {cum}")
                        out.append(
                            f"{pname}_bucket"
                            f"{_fmt_labels(key, le='+Inf')} {s.count}")
                        out.append(
                            f"{pname}_sum{_fmt_labels(key)} {s.sum:g}")
                        out.append(
                            f"{pname}_count{_fmt_labels(key)} {s.count}")
        return "\n".join(out) + "\n"

    def stats_line(self, names: Iterable[str]) -> str:
        """One compact ``k=v`` line for the launcher's periodic stats
        print; unknown names render as ``-`` so callers can list
        metrics that only exist in some configurations."""
        parts = []
        for name in names:
            m = self._metrics.get(name)
            if isinstance(m, (Counter, Gauge)):
                parts.append(f"{name}={sum(m._series.values()):g}")
            elif isinstance(m, Histogram):
                tot = sum(s.count for s in m._series.values())
                parts.append(f"{name}.n={tot}")
            else:
                parts.append(f"{name}=-")
        return " ".join(parts)


def _fmt_labels(key: LabelKey, **extra: str) -> str:
    items = list(key) + sorted(extra.items())
    if not items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}"


# ----------------------------------------------------------------------
# no-op twin: the overhead-comparison baseline and the "observability
# disabled" mode.  One shared instance of each no-op instrument.
# ----------------------------------------------------------------------
class _NullBound:
    __slots__ = ()

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


_NULL_BOUND = _NullBound()


class _NullInstrument:
    __slots__ = ("name", "help", "kind", "buckets")

    def __init__(self, name: str = "", kind: str = "untyped") -> None:
        self.name, self.help, self.kind = name, "", kind
        self.buckets: Tuple[float, ...] = ()

    def inc(self, v: float = 1.0, **labels: object) -> None:
        pass

    def set(self, v: float, **labels: object) -> None:
        pass

    def observe(self, v: float, **labels: object) -> None:
        pass

    def value(self, **labels: object) -> float:
        return 0.0

    def quantile(self, q: float, **labels: object) -> float:
        return 0.0

    def labels(self, **labels: object) -> _NullBound:
        return _NULL_BOUND

    def reset(self) -> None:
        pass


class NullRegistry(MetricsRegistry):
    """Same API as :class:`MetricsRegistry`, every operation a no-op —
    the baseline the ``serving_obs.overhead_pct`` bench compares the
    real registry against."""

    def __init__(self) -> None:
        super().__init__()

    def counter(self, name: str, help: str = "") -> _NullInstrument:
        return _NullInstrument(name, "counter")

    def gauge(self, name: str, help: str = "") -> _NullInstrument:
        return _NullInstrument(name, "gauge")

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS_MS,
                  ) -> _NullInstrument:
        return _NullInstrument(name, "histogram")

    def snapshot(self) -> Dict[str, object]:
        return {"version": SNAPSHOT_VERSION, "counters": [],
                "gauges": [], "histograms": []}

    def to_prometheus(self) -> str:
        return "\n"
