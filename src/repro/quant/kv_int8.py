"""int8 KV-page quantization for the paged serving cache.

The paged pool stores each layer's K/V as a flat row buffer
((n_pages * page_size, Hkv, D) — see ``Model.init_cache``).  Under
``kv_dtype="int8"`` the same rows hold int8 codes plus one f32 scale
per **(row, kv head)**:

    k / v           (rows, Hkv, D) int8   code = round(x / scale)
    k_scale/v_scale (rows, Hkv)    f32    scale = max|x| / 127

Per-(token, head) scales — not per-page — because pages fill one token
row at a time (prefill scatters a chunk, decode scatters a single row
per sequence): a page-granular scale would have to be rewritten, and
every code in the page requantized, on each append.  Row scales make
the write path a pure scatter, identical in shape to the fp32 path,
and cost 4 bytes per head per token next to D bytes of codes:

    bytes/token/head:  fp32  4·D        int8  D + 4

so a page shrinks by 4D/(D+4) ≈ 3.8x at D = 64 (the capacity lever —
``KVPoolConfig.page_bytes`` does this arithmetic for the planner).

Dequantization happens only on the **read** side, after the block-table
gather, so per-step cost stays O(touched bytes) — the pool is never
dequantized wholesale (``repro.kernels.ops.paged_gqa_decode_attention``
and the resumed-prefill gather in ``models.transformer._paged_attn``).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_rows(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(..., H, D) float -> ((..., H, D) int8 codes, (..., H) f32 scales).

    Symmetric absmax scaling per (row, head); all-zero rows (idle batch
    lanes writing the scratch page) get scale 0 and dequantize to 0.
    """
    xf = jnp.asarray(x, jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1)                    # (..., H)
    scale = absmax / 127.0
    inv = jnp.where(scale > 0.0, 1.0 / jnp.where(scale > 0.0, scale, 1.0),
                    0.0)
    q = jnp.clip(jnp.round(xf * inv[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_rows(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    """((..., H, D) int8, (..., H) f32) -> (..., H, D) ``dtype``."""
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def kv_bytes_per_row_head(head_dim: int) -> int:
    """Pool bytes one (token, kv head) costs: D code bytes + 4 scale."""
    return head_dim + 4
