"""Q4_0 block quantization — the paper's weight format (§4: "quantized
in the Q4_0 format").

llama.cpp Q4_0: contiguous blocks of 32 values share one fp16 scale
``d = max_abs / -8``; each value is stored as a 4-bit code
``q = clamp(round(x/d) + 8, 0, 15)`` and dequantizes to ``(q - 8)·d``.

Here a weight ``W (K, N)`` is quantized along the contraction axis K
(so a GEMM tile's scales are contiguous):

    packed  (K//2,  N) uint8 — two 4-bit codes per byte
                               (low nibble = even k, high nibble = odd k)
    scales  (K//32, N) f32   — per 32-row block, per column

Effective 4.5 bits/weight, matching the paper's 0.5625 B/weight used by
the NUMA cost model.  ``repro.kernels.q4_gemm`` consumes this layout
directly (HBM→VMEM tile, unpack + dequant in VMEM, MXU matmul).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


BLOCK = 32
BYTES_PER_WEIGHT = 4 / 8 + 2 / BLOCK  # 4-bit code + fp16 scale share


def padded_k(K: int) -> int:
    """Smallest multiple of ``BLOCK`` >= K (the pad-to-block row count)."""
    return -(-K // BLOCK) * BLOCK


def quantize(w: jax.Array, *, pad: bool = False,
             ) -> Tuple[jax.Array, jax.Array]:
    """W (K, N) float -> (packed (K//2, N) uint8, scales (K//32, N) f32).

    ``pad=True`` accepts any K by zero-padding the contraction axis to
    the next multiple of ``BLOCK``.  The pad is *exact*, not approximate:
    a zero input quantizes to code 8 and dequantizes to ``(8 - 8)·d = 0``
    for every possible block scale, so a matmul against the padded
    weight (with the activation zero-padded to match, or the dequantized
    weight sliced back to K rows) is bit-identical to the unpadded one.
    Callers recover the original K from the activation they contract
    with (see ``repro.quant.policy.make_qmm``).
    """
    K, N = w.shape
    if K % BLOCK:
        if not pad:
            raise ValueError(f"K={K} not a multiple of {BLOCK} "
                             "(pass pad=True for the pad-to-block path)")
        w = jnp.pad(jnp.asarray(w, jnp.float32),
                    ((0, padded_k(K) - K), (0, 0)))
        K = padded_k(K)
    wf = jnp.asarray(w, jnp.float32).reshape(K // BLOCK, BLOCK, N)
    absmax = jnp.max(jnp.abs(wf), axis=1)                     # (K/32, N)
    imax = jnp.argmax(jnp.abs(wf), axis=1)
    signed_max = jnp.take_along_axis(wf, imax[:, None, :], axis=1)[:, 0, :]
    scale = signed_max / -8.0                                 # llama.cpp sign trick
    inv = jnp.where(scale != 0.0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.round(wf * inv[:, None, :]) + 8, 0, 15).astype(jnp.uint8)
    q = q.reshape(K, N)
    lo = q[0::2]                                              # even k rows
    hi = q[1::2]
    packed = (lo | (hi << 4)).astype(jnp.uint8)               # (K/2, N)
    # fp16 round-trip of the scale, stored f32 for TPU friendliness
    scales = scale.astype(jnp.float16).astype(jnp.float32)
    return packed, scales


def unpack_codes(packed: jax.Array) -> jax.Array:
    """(K//2, N) uint8 -> (K, N) int8 codes in [-8, 7]."""
    lo = (packed & 0x0F).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    K2, N = packed.shape
    out = jnp.stack([lo, hi], axis=1)                         # (K/2, 2, N)
    return out.reshape(2 * K2, N)


def dequantize(packed: jax.Array, scales: jax.Array,
               dtype=jnp.float32) -> jax.Array:
    codes = unpack_codes(packed).astype(jnp.float32)          # (K, N)
    K = codes.shape[0]
    s = jnp.repeat(scales, BLOCK, axis=0)                     # (K, N)
    return (codes * s).astype(dtype)


def quantize_stacked(w: jax.Array, *, pad: bool = False,
                     ) -> Tuple[jax.Array, jax.Array]:
    """Per-layer-stacked weights (L, K, N) -> ((L, K//2, N), (L, K//32, N)).

    The uniform paged stacks keep layer parameters stacked on a leading
    L axis (``Model._run_paged_layers`` slices one layer per step);
    quantizing each layer independently keeps that static slice working
    unchanged on the packed/scales pair."""
    return jax.vmap(lambda x: quantize(x, pad=pad))(w)


def quantize_params(params, *, min_size: int = 1024):
    """Quantize every 2-D weight in a pytree; returns (q_tree, meta).

    Leaves become dicts {"packed", "scales"}; small or non-2D leaves
    stay dense.  Used by the serving engine's Q4_0 mode."""
    def q(x):
        if (hasattr(x, "ndim") and x.ndim == 2 and x.size >= min_size
                and x.shape[0] % BLOCK == 0):
            p, s = quantize(x)
            return {"q4_packed": p, "q4_scales": s}
        return x
    return jax.tree.map(q, params)


def quantized_bytes(shape: Tuple[int, int]) -> int:
    K, N = shape
    return K * N // 2 + (K // BLOCK) * N * 4
