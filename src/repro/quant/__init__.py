"""repro.quant substrate: Q4_0 weights, int8 KV pages, serving policy."""

from .kv_int8 import dequantize_rows, kv_bytes_per_row_head, quantize_rows
from .policy import (Q4_WEIGHT_NAMES, QuantPolicy, count_q4_leaves,
                     is_q4_leaf, make_qmm, param_bytes,
                     quantize_serving_params)
from .q4_0 import (BLOCK, BYTES_PER_WEIGHT, dequantize, padded_k, quantize,
                   quantize_params, quantize_stacked, quantized_bytes,
                   unpack_codes)

__all__ = [
    "BLOCK", "BYTES_PER_WEIGHT", "Q4_WEIGHT_NAMES", "QuantPolicy",
    "count_q4_leaves", "dequantize", "dequantize_rows", "is_q4_leaf",
    "kv_bytes_per_row_head", "make_qmm", "padded_k", "param_bytes",
    "quantize", "quantize_params", "quantize_rows",
    "quantize_serving_params", "quantize_stacked", "quantized_bytes",
    "unpack_codes",
]
