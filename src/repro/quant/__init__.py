"""repro.quant substrate."""
