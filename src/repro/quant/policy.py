"""Serving quantization policy (``--quant`` / ``--kv-dtype``).

:class:`QuantPolicy` is the one knob the serving stack threads from the
CLI down to the device layer (``EngineCore(quant=...)`` →
``ModelRunner``): which weight format to serve (``weights``), which KV
page format to allocate (``kv_dtype``), and how quantized matmuls
dispatch (``impl`` — the ``repro.kernels.ops.q4_matmul`` rule: Pallas
kernel on TPU, jnp dequant reference elsewhere).

Weight quantization (``weights="q4"``) rewrites the attention and MLP
projection leaves of the params tree to Q4_0 at load
(:func:`quantize_serving_params`): each targeted ``(..., K, N)`` matrix
becomes a ``{"q4_packed", "q4_scales"}`` subtree in place, quantized
along the contraction axis K (padding K to the 32-row block exactly —
see ``q4_0.quantize``).  Embedding, lm_head, norms and biases stay
dense: they are a small fraction of the bytes and sit on the
numerically touchy ends of the network.

The model consumes quantized leaves through the ``qmm`` hook
(:func:`make_qmm`), installed on the (local) model by ``ModelRunner``:
a matmul that passes dense arrays straight to ``x @ w`` and routes
quantized subtrees through ``kernels.ops.q4_matmul``.  Under
tensor-parallel serving the q4 leaves shard exactly like the dense
weights they replace — Q4_0 quantizes along K while the head split
slices columns (N), so a column shard of the packed/scales pair is
byte-identical to quantizing the sharded weight
(``launch.shardings.serving_tp_param_specs``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .q4_0 import BLOCK, quantize, quantize_stacked

#: projection leaves `quantize_serving_params` targets, under an
#: ``attn`` / ``mlp`` parent (MoE expert stacks are excluded: their
#: extra experts axis needs a different layout)
Q4_WEIGHT_NAMES = ("w_q", "w_k", "w_v", "w_o", "w_gate", "w_up", "w_down")

WEIGHT_FORMATS = ("none", "q4")
KV_DTYPES = ("fp32", "int8")
Q4_IMPLS = ("auto", "ref", "kernel")


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """What the serving engine quantizes and how it dispatches.

    ``weights``   "none" | "q4"   — Q4_0-quantize attn/MLP projections
                                    at load (4.5 bits/weight)
    ``kv_dtype``  "fp32" | "int8" — KV page-pool element format
                                    (int8 + per-(row, head) f32 scales)
    ``impl``      "auto" | "ref" | "kernel" — q4 matmul dispatch;
                  "auto" = Pallas kernel on TPU, jnp dequant reference
                  fallback elsewhere (``kernels.ops.q4_matmul``)
    ``min_size``  smallest element count a leaf must have to be
                  quantized (tiny projections aren't worth the codes)
    """

    weights: str = "none"
    kv_dtype: str = "fp32"
    impl: str = "auto"
    min_size: int = 1024

    def __post_init__(self) -> None:
        if self.weights not in WEIGHT_FORMATS:
            raise ValueError(f"weights={self.weights!r}: "
                             f"choose from {WEIGHT_FORMATS}")
        if self.kv_dtype not in KV_DTYPES:
            raise ValueError(f"kv_dtype={self.kv_dtype!r}: "
                             f"choose from {KV_DTYPES}")
        if self.impl not in Q4_IMPLS:
            raise ValueError(f"impl={self.impl!r}: "
                             f"choose from {Q4_IMPLS}")

    @property
    def active(self) -> bool:
        return self.weights != "none" or self.kv_dtype != "fp32"


def is_q4_leaf(w: Any) -> bool:
    """True for a ``{"q4_packed", "q4_scales"}`` quantized-weight subtree."""
    return isinstance(w, dict) and "q4_packed" in w


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def quantize_serving_params(params: Any, *, min_size: int = 1024) -> Any:
    """Rewrite attn/MLP projection leaves to Q4_0 subtrees, in place in
    the tree structure (each matched array leaf becomes a
    ``{"q4_packed", "q4_scales"}`` dict; everything else is unchanged).

    Matches by name (:data:`Q4_WEIGHT_NAMES`) under an ``attn`` or
    ``mlp`` path component, on 2-D ``(K, N)`` or layer-stacked 3-D
    ``(L, K, N)`` leaves of at least ``min_size`` elements.  K is
    padded to the 32-row Q4_0 block when needed (exact — zero rows
    dequantize to exact zeros; ``q4_0.quantize``).
    """
    def f(path, leaf):
        p = _path_str(path)
        parts = p.split("/")
        if parts[-1] not in Q4_WEIGHT_NAMES:
            return leaf
        if "attn" not in parts and "mlp" not in parts:
            return leaf
        if not hasattr(leaf, "ndim") or leaf.ndim not in (2, 3):
            return leaf
        if leaf.size < min_size:
            return leaf
        qfn = quantize_stacked if leaf.ndim == 3 else quantize
        packed, scales = qfn(leaf, pad=True)
        return {"q4_packed": packed, "q4_scales": scales}

    return jax.tree_util.tree_map_with_path(f, params)


def count_q4_leaves(params: Any) -> int:
    """Number of quantized-weight subtrees in a params tree."""
    n = 0
    for path, _leaf in jax.tree_util.tree_leaves_with_path(params):
        if _path_str(path).endswith("q4_packed"):
            n += 1
    return n


def param_bytes(params: Any) -> int:
    """Total bytes of every array leaf (dense and quantized alike)."""
    return sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree_util.tree_leaves(params)
               if hasattr(leaf, "size"))


def _largest_divisor_block(n: int, cap: int) -> int:
    """Largest power-of-two multiple-of-32 tile <= cap dividing n, for
    the Pallas kernel's grid (any n: falls back to n itself)."""
    for b in (cap, cap // 2, cap // 4, cap // 8, 64, 32):
        if b and b <= cap and n % b == 0:
            return b
    return n


def make_qmm(impl: str = "auto"):
    """Build the model's quantized-matmul hook (``Model.qmm``).

    The returned ``qmm(x, w)`` computes ``x @ w`` for dense ``w`` and
    dispatches Q4_0 subtrees through ``kernels.ops.q4_matmul`` with the
    given ``impl``, handling leading batch dims and the pad-to-block K
    mismatch (activations zero-pad to the packed row count — exact,
    because padded weight rows dequantize to exact zeros).
    """
    from ..kernels.ops import q4_matmul

    def qmm(x: jax.Array, w: Any) -> jax.Array:
        if not is_q4_leaf(w):
            return x @ w
        packed, scales = w["q4_packed"], w["q4_scales"]
        K = x.shape[-1]
        Kq = packed.shape[-2] * 2
        N = packed.shape[-1]
        x2 = x.reshape(-1, K)
        if Kq > K:                       # pad-to-block (exact, see above)
            x2 = jnp.pad(x2, ((0, 0), (0, Kq - K)))
        out = q4_matmul(x2.astype(jnp.float32), packed, scales, impl=impl,
                        block_k=_largest_divisor_block(Kq, 256),
                        block_n=_largest_divisor_block(N, 256))
        return out.reshape(x.shape[:-1] + (N,)).astype(x.dtype)

    return qmm
