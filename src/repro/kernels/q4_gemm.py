"""Pallas TPU kernel: Q4_0 dequant + matmul (the decode GEMV hot-spot).

The paper's engine spends its decode time in Q4_0 GEMV/GEMM NEON
kernels (§2.7, §4).  The TPU adaptation rethinks the blocking for the
memory hierarchy: weight tiles stream HBM→VMEM in their *packed* form
(0.5625 B/weight — the whole point of Q4_0 is bandwidth), are unpacked
and dequantized in VMEM registers, and feed the MXU as bf16/f32 tiles
with 128-aligned shapes.  fp32 accumulation across the K grid axis.

Layout (see ``repro.quant.q4_0``):
    x       (M, K)        activation
    packed  (K//2, N)     two 4-bit codes per byte along K
    scales  (K//32, N)    per-block scale

Grid: (N/BN, K/BK); the K axis accumulates into the output block
(revisited across the innermost grid dim).  BK is a multiple of 32 so
scale blocks never straddle tiles.  M stays whole per tile — decode is
M ∈ {1..batch}, far below the 128 sublane budget at these sizes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quant.q4_0 import BLOCK


DEFAULT_BN = 256
DEFAULT_BK = 256


def _q4_gemm_kernel(x_ref, packed_ref, scales_ref, out_ref, *, n_k: int):
    """One (BN, BK) tile: unpack, dequant, matmul, accumulate."""
    k = pl.program_id(1)

    packed = packed_ref[...]                       # (BK//2, BN) uint8
    lo = (packed & 0x0F).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    codes = jnp.stack([lo, hi], axis=1)            # (BK//2, 2, BN)
    bk2, _, bn = codes.shape
    codes = codes.reshape(2 * bk2, bn)             # (BK, BN)

    scales = scales_ref[...]                       # (BK//32, BN)
    w = codes.astype(jnp.float32) * jnp.repeat(scales, BLOCK, axis=0)

    x = x_ref[...].astype(jnp.float32)             # (M, BK)
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(k > 0)
    def _accum():
        out_ref[...] += acc


def q4_gemm(x: jax.Array, packed: jax.Array, scales: jax.Array, *,
            block_n: int = DEFAULT_BN, block_k: int = DEFAULT_BK,
            interpret: bool = True) -> jax.Array:
    """x (M, K) @ dequant(packed, scales) (K, N) -> (M, N) f32.

    ``interpret=True`` executes the kernel body on CPU (this container's
    validation mode); on TPU pass ``interpret=False``.
    """
    M, K = x.shape
    K2, N = packed.shape
    if K != 2 * K2:
        raise ValueError(f"K mismatch: x has {K}, packed has {2 * K2}")
    if block_k % BLOCK:
        raise ValueError(f"block_k={block_k} must be a multiple of {BLOCK}")
    block_n = min(block_n, N)
    block_k = min(block_k, K)
    if N % block_n or K % block_k:
        raise ValueError(f"(K={K}, N={N}) not divisible by "
                         f"(block_k={block_k}, block_n={block_n})")
    n_n, n_k = N // block_n, K // block_k

    return pl.pallas_call(
        functools.partial(_q4_gemm_kernel, n_k=n_k),
        grid=(n_n, n_k),
        in_specs=[
            pl.BlockSpec((M, block_k), lambda n, k: (0, k)),
            pl.BlockSpec((block_k // 2, block_n), lambda n, k: (k, n)),
            pl.BlockSpec((block_k // BLOCK, block_n), lambda n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((M, block_n), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, packed, scales)
