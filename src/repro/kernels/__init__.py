"""repro.kernels — Pallas TPU kernels for the compute hot-spots.

- ``q4_gemm``          Q4_0 dequant+matmul (the paper's NEON GEMM,
                       re-blocked for VMEM/MXU)
- ``decode_attention`` flash-decoding over the KV cache
- ``rglru_scan``       RG-LRU linear-recurrence scan (hybrid archs)
- ``ops``              jit'd wrappers (kernel on TPU, interpret/ref on CPU)
- ``ref``              pure-jnp oracles
"""

from .ops import gqa_decode_attention, q4_matmul, rglru_linear_scan

__all__ = ["gqa_decode_attention", "q4_matmul", "rglru_linear_scan"]
