"""jit'd dispatch wrappers for the Pallas kernels.

On TPU the wrappers call the compiled kernels; on CPU (this container)
they run the kernels in interpret mode for correctness work, or fall
back to the jnp oracle for speed (``impl="ref"``).
"""

from __future__ import annotations

import functools
from typing import Any

import jax

from . import ref as _ref
from .decode_attention import decode_attention as _decode_attention_kernel
from .decode_attention import \
    paged_decode_attention as _paged_decode_attention_kernel
from .q4_gemm import q4_gemm as _q4_gemm_kernel
from .rglru_scan import rglru_scan_kernel as _rglru_scan_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl", "block_n", "block_k"))
def q4_matmul(x: jax.Array, packed: jax.Array, scales: jax.Array, *,
              impl: str = "auto", block_n: int = 256,
              block_k: int = 256) -> jax.Array:
    """Quantized matmul: x (M,K) @ W_q4 (K,N) -> (M,N) f32."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _ref.q4_gemm_ref(x, packed, scales)
    return _q4_gemm_kernel(x, packed, scales, block_n=block_n,
                           block_k=block_k, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("impl", "block_s"))
def gqa_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: Any, *, impl: str = "auto",
                         block_s: int = 512) -> jax.Array:
    """Flash-decoding for one token with GQA.

    q (B,1,Hq,D); k,v (B,S,Hkv,D) -> out (B,1,Hq,D), matching the
    model-zoo attention contract."""
    B, one, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qk = q.reshape(B, Hkv, G, D)
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        out = _ref.decode_attention_ref(qk, k, v, kv_len)
    else:
        out = _decode_attention_kernel(qk, k, v, kv_len, block_s=block_s,
                                       interpret=not _on_tpu())
    return out.reshape(B, 1, Hq, D)


@functools.partial(jax.jit, static_argnames=("page_size", "impl",
                                             "softcap"))
def paged_gqa_decode_attention(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_tables: jax.Array,
                               kv_lens: jax.Array, window=0, *,
                               page_size: int, softcap: float = 0.0,
                               impl: str = "auto",
                               k_scale=None, v_scale=None) -> jax.Array:
    """Paged flash-decoding for one token per sequence with GQA.

    q (B,1,Hq,D); k_pool,v_pool (n_pages*page_size,Hkv,D) — ONE layer's
    flat page-pool buffer, exactly as the per-layer paged cache holds it
    (``Model.init_cache(page_size=...)``); the paged view is a free
    reshape here.  block_tables (B,max_pages); kv_lens (B,) ->
    out (B,1,Hq,D).  The device-side read path of the serving KV pool
    (``repro.serving.kv_pool``): K/V are addressed *through* the block
    table, so batch membership and sequence length change without
    recompilation or cache copies.

    ``k_scale``/``v_scale`` ((n_pages*page_size, Hkv) f32) select the
    **int8 page** format (``--kv-dtype int8``): the pools hold int8
    codes with per-(row, head) scales, dequantized after the block-table
    gather (O(touched bytes)).  The quantized read currently routes
    through the jnp reference path on every backend — teaching the
    Pallas paged kernel to dequantize in-tile is listed future work
    (``docs/quantization.md``).
    """
    B, one, Hq, D = q.shape
    Hkv = k_pool.shape[1]
    G = Hq // Hkv
    n_pages = k_pool.shape[0] // page_size
    # all shapes here may be the TP-local slice: under the head-sharded
    # serving mesh (serving.runner mesh mode) this runs inside
    # shard_map with Hq/Hkv divided by the shard count and the pool
    # buffer holding only the local kv heads — the block-table gather
    # is identical, the GQA group size G is shard-invariant, and no
    # collective appears at this level (the head merge happens in the
    # transformer, once per layer)
    k_pages = k_pool.reshape(n_pages, page_size, Hkv, D)
    v_pages = v_pool.reshape(n_pages, page_size, Hkv, D)
    qk = q.reshape(B, Hkv, G, D)
    if k_scale is not None or v_scale is not None:
        ks = k_scale.reshape(n_pages, page_size, Hkv)
        vs = v_scale.reshape(n_pages, page_size, Hkv)
        out = _ref.paged_decode_attention_ref(qk, k_pages, v_pages,
                                              block_tables, kv_lens, window,
                                              softcap=softcap,
                                              k_scales=ks, v_scales=vs)
    elif impl == "ref" or (impl == "auto" and not _on_tpu()):
        out = _ref.paged_decode_attention_ref(qk, k_pages, v_pages,
                                              block_tables, kv_lens, window,
                                              softcap=softcap)
    else:
        out = _paged_decode_attention_kernel(qk, k_pages, v_pages,
                                             block_tables, kv_lens, window,
                                             softcap=softcap,
                                             interpret=not _on_tpu())
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("impl", "block_t"))
def rglru_linear_scan(a: jax.Array, u: jax.Array, h0=None, *,
                      impl: str = "auto", block_t: int = 128) -> jax.Array:
    """RG-LRU recurrence h[t] = a[t]*h[t-1] + u[t] over (B, T, W)."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return _ref.rglru_scan_ref(a, u, h0)
    return _rglru_scan_kernel(a, u, h0=h0, block_t=block_t,
                              interpret=not _on_tpu())
