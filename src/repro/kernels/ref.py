"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..quant.q4_0 import dequantize


def q4_gemm_ref(x: jax.Array, packed: jax.Array,
                scales: jax.Array) -> jax.Array:
    """x (M,K) @ dequant(packed, scales) (K,N) -> (M,N) f32."""
    w = dequantize(packed, scales, dtype=jnp.float32)
    return x.astype(jnp.float32) @ w


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len) -> jax.Array:
    """q (B,H,G,D) × cache k,v (B,S,H,D) -> (B,H,G,D) f32."""
    B, H, G, D = q.shape
    S = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(S) < jnp.asarray(kv_len)
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))


def rglru_scan_ref(a: jax.Array, u: jax.Array, h0=None) -> jax.Array:
    """Associative-scan oracle for the RG-LRU recurrence kernel."""
    if h0 is not None:
        u = u.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    return h
