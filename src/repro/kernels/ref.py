"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..quant.q4_0 import dequantize


def q4_gemm_ref(x: jax.Array, packed: jax.Array,
                scales: jax.Array) -> jax.Array:
    """x (M,K) @ dequant(packed, scales) (K,N) -> (M,N) f32."""
    w = dequantize(packed, scales, dtype=jnp.float32)
    return x.astype(jnp.float32) @ w


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len) -> jax.Array:
    """q (B,H,G,D) × cache k,v (B,S,H,D) -> (B,H,G,D) f32."""
    B, H, G, D = q.shape
    S = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.arange(S) < jnp.asarray(kv_len)
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))


NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def paged_decode_attention_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_tables: jax.Array,
                               kv_lens: jax.Array,
                               window=0, softcap: float = 0.0,
                               k_scales=None, v_scales=None) -> jax.Array:
    """Gather-based paged flash-decoding oracle.

    q (B,H,G,D) one token per sequence; k_pages/v_pages (P,ps,H,D) the
    shared physical page pool; block_tables (B,max_pages) maps each
    sequence's logical page j to a physical page id; kv_lens (B,) is the
    per-sequence token count (logical positions are contiguous 0..len-1,
    unlike the ring cache).  Fully-masked rows (kv_len == 0, idle batch
    slots) produce finite garbage, not NaN.

    ``k_scales``/``v_scales`` (P, ps, H) switch on the **int8 page**
    format (``repro.quant.kv_int8``): pages hold int8 codes and the
    per-(token, head) scales are gathered through the same block table,
    so dequantization costs O(gathered bytes), never O(pool bytes).
    """
    B, H, G, D = q.shape
    P, ps, _, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    L = max_pages * ps
    scale = 1.0 / math.sqrt(D)
    # gather each sequence's pages, flatten to its logical KV view
    k = k_pages[block_tables].reshape(B, L, H, D)
    v = v_pages[block_tables].reshape(B, L, H, D)
    if k_scales is not None:
        k = k.astype(jnp.float32) \
            * k_scales[block_tables].reshape(B, L, H)[..., None]
    if v_scales is not None:
        v = v.astype(jnp.float32) \
            * v_scales[block_tables].reshape(B, L, H)[..., None]
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    j = jnp.arange(L)
    valid = j[None, :] < kv_lens[:, None]
    w = jnp.asarray(window, jnp.int32)
    qpos = kv_lens[:, None] - 1
    valid &= (w <= 0) | (j[None, :] > qpos - w)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))


def rglru_scan_ref(a: jax.Array, u: jax.Array, h0=None) -> jax.Array:
    """Associative-scan oracle for the RG-LRU recurrence kernel."""
    if h0 is not None:
        u = u.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    return h
