"""Pallas TPU kernel: RG-LRU linear-recurrence scan.

The sequential hot-spot of the hybrid archs (recurrentgemma): given
per-step gates ``a`` and scaled inputs ``u`` (both (B, T, W), computed
by cheap GEMMs outside), produce

    h_t = a_t ⊙ h_{t-1} + u_t          (elementwise, W-wide)

TPU adaptation: the recurrence is memory-bound (3 streams of B·T·W) and
strictly sequential in T, so the kernel tiles T into VMEM-resident
chunks — grid (T/BT,) — and carries the running state h (B, W) in VMEM
scratch across grid steps.  Inside a chunk a ``fori_loop`` walks rows
at VREG speed; HBM sees exactly one read of a/u and one write of h per
element.  W shards over the mesh's model axis outside the kernel (the
recurrence is elementwise in W — ArcLight's row-partitioning applied to
the recurrence width, DESIGN.md §4).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_scan_kernel(a_ref, u_ref, o_ref, h_ref, *, block_t: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def body(i, h):
        h = a_ref[:, i, :] * h + u_ref[:, i, :]
        pl.store(o_ref, (slice(None), pl.dslice(i, 1), slice(None)),
                 h[:, None, :])
        return h

    h_ref[...] = jax.lax.fori_loop(0, block_t, body, h_ref[...])


def rglru_scan_kernel(a: jax.Array, u: jax.Array, *,
                      h0: Optional[jax.Array] = None,
                      block_t: int = 128,
                      interpret: bool = True) -> jax.Array:
    """h[t] = a[t]*h[t-1] + u[t] over axis 1.  a,u (B,T,W) -> h (B,T,W).

    ``h0``: optional initial state (B, W) — folded into the first step
    (h_1 = a_1·h0 + u_1), matching ``repro.models.recurrent``.
    """
    B, T, W = a.shape
    if u.shape != a.shape:
        raise ValueError(f"shape mismatch {a.shape} vs {u.shape}")
    if h0 is not None:
        u = u.at[:, 0].add(a[:, 0] * h0)
    block_t = min(block_t, T)
    pad = (-T) % block_t
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    n_t = (T + pad) // block_t

    out = pl.pallas_call(
        functools.partial(_rglru_scan_kernel, block_t=block_t),
        grid=(n_t,),
        in_specs=[
            pl.BlockSpec((B, block_t, W), lambda t: (0, t, 0)),
            pl.BlockSpec((B, block_t, W), lambda t: (0, t, 0)),
        ],
        out_specs=pl.BlockSpec((B, block_t, W), lambda t: (0, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T + pad, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((B, W), jnp.float32)],
        interpret=interpret,
    )(a, u)
    return out[:, :T]
