"""Pallas TPU kernel: flash-decoding attention (one query token).

The decode hot-spot: a single query attends over a long KV cache —
pure HBM bandwidth (read every cache byte once), exactly the workload
ArcLight's NUMA placement targets.  TPU adaptation: the cache streams
HBM→VMEM in (BS, D) chunks along the sequence grid axis; online
softmax state (m, l, acc) lives in VMEM scratch across grid steps;
the final grid step normalises and writes out.

Shapes (GQA folded outside the kernel by the ops wrapper):
    q   (B, H, G, D)   one token's queries, G = Hq // Hkv
    k,v (B, S, H, D)   cache (H = kv heads)
    kv_len scalar      number of valid cache slots (rest masked)

Grid: (B, H, S/BS) — the sequence axis is innermost so scratch
accumulates per (batch, head).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                        acc_ref, m_ref, l_ref, *,
                        block_s: int, n_s: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (BS, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)      # (BS, D)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G,BS)
    kv_len = len_ref[0]
    kpos = s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_s), 1)
    s = jnp.where(kpos < kv_len, s, NEG_INF)

    m_prev = m_ref[...]                            # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                         # (G, BS)
    alpha = jnp.exp(m_prev - m_new)                # (G, 1)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == n_s - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = acc_ref[...] / l


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len, *, block_s: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q (B,H,G,D) × cache k,v (B,S,H,D) -> out (B,H,G,D) f32."""
    B, H, G, D = q.shape
    _, S, _, _ = k.shape
    block_s = min(block_s, S)
    if S % block_s:
        raise ValueError(f"S={S} not divisible by block_s={block_s}")
    n_s = S // block_s
    scale = 1.0 / math.sqrt(D)
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_attn_kernel, block_s=block_s,
                               n_s=n_s, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                     # kv_len in SMEM
        grid=(B, H, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s, _: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s, _: (b, s, h, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s, _: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s, _: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, G, D), jnp.float32),
        interpret=interpret,
    )(kv_len, q, k, v)
