"""Pallas TPU kernel: flash-decoding attention (one query token).

The decode hot-spot: a single query attends over a long KV cache —
pure HBM bandwidth (read every cache byte once), exactly the workload
ArcLight's NUMA placement targets.  TPU adaptation: the cache streams
HBM→VMEM in (BS, D) chunks along the sequence grid axis; online
softmax state (m, l, acc) lives in VMEM scratch across grid steps;
the final grid step normalises and writes out.

Shapes (GQA folded outside the kernel by the ops wrapper):
    q   (B, H, G, D)   one token's queries, G = Hq // Hkv
    k,v (B, S, H, D)   cache (H = kv heads)
    kv_len scalar      number of valid cache slots (rest masked)

Grid: (B, H, S/BS) — the sequence axis is innermost so scratch
accumulates per (batch, head).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                        acc_ref, m_ref, l_ref, *,
                        block_s: int, n_s: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (BS, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)      # (BS, D)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G,BS)
    kv_len = len_ref[0]
    kpos = s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_s), 1)
    s = jnp.where(kpos < kv_len, s, NEG_INF)

    m_prev = m_ref[...]                            # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                         # (G, BS)
    alpha = jnp.exp(m_prev - m_new)                # (G, 1)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == n_s - 1)
    def _finalize():
        denom = l_ref[...]
        denom = jnp.where(denom > 0, denom, 1.0)
        o_ref[0, 0] = acc_ref[...] / denom


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len, *, block_s: int = 512,
                     interpret: bool = True) -> jax.Array:
    """q (B,H,G,D) × cache k,v (B,S,H,D) -> out (B,H,G,D) f32."""
    B, H, G, D = q.shape
    _, S, _, _ = k.shape
    block_s = min(block_s, S)
    if S % block_s:
        raise ValueError(f"S={S} not divisible by block_s={block_s}")
    n_s = S // block_s
    scale = 1.0 / math.sqrt(D)
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_attn_kernel, block_s=block_s,
                               n_s=n_s, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                     # kv_len in SMEM
        grid=(B, H, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s, _: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s, _: (b, s, h, 0)),
            pl.BlockSpec((1, block_s, 1, D), lambda b, h, s, _: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s, _: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, G, D), jnp.float32),
        interpret=interpret,
    )(kv_len, q, k, v)


# ----------------------------------------------------------------------
# paged variant: K/V live in a shared page pool, read through per-
# sequence block tables (the serving KV pool's device layout)
# ----------------------------------------------------------------------

def _paged_decode_attn_kernel(bt_ref, len_ref, win_ref, q_ref, k_ref, v_ref,
                              o_ref, acc_ref, m_ref, l_ref, *,
                              page_size: int, n_pages: int, scale: float,
                              softcap: float):
    p_idx = pl.program_id(2)

    @pl.when(p_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    b = pl.program_id(0)
    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (ps, D) — one page
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kv_len = len_ref[b]
    window = win_ref[0]
    # logical (not physical) positions of this page's slots
    kpos = p_idx * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    mask = kpos < kv_len
    mask &= (window <= 0) | (kpos > kv_len - 1 - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(p_idx == n_pages - 1)
    def _finalize():
        denom = l_ref[...]
        denom = jnp.where(denom > 0, denom, 1.0)
        o_ref[0, 0] = acc_ref[...] / denom


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           kv_lens: jax.Array, window=0, *,
                           softcap: float = 0.0,
                           interpret: bool = True) -> jax.Array:
    """q (B,H,G,D) × page pool k,v (P,ps,H,D) -> out (B,H,G,D) f32.

    ``k_pages``/``v_pages`` are the paged view of ONE layer's flat pool
    buffer — the ops wrapper (``repro.kernels.ops``) reshapes the
    per-layer (P*ps, H, D) cache buffer before dispatching here.
    ``block_tables`` (B, max_pages) int32 and ``kv_lens`` (B,) int32 are
    scalar-prefetched so each grid step's BlockSpec index_map can DMA the
    *physical* page the sequence's logical page j maps to — the gather
    never materialises a contiguous copy of the sequence's cache.
    """
    B, H, G, D = q.shape
    P, page_size, _, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    scale = 1.0 / math.sqrt(D)
    block_tables = jnp.asarray(block_tables, jnp.int32)
    kv_lens = jnp.asarray(kv_lens, jnp.int32).reshape(B)
    window = jnp.asarray(window, jnp.int32).reshape(1)

    kernel = functools.partial(_paged_decode_attn_kernel,
                               page_size=page_size, n_pages=max_pages,
                               scale=scale, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,        # block tables, kv lens, window
        grid=(B, H, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, p, bt, ln, w: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, D),
                         lambda b, h, p, bt, ln, w: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, D),
                         lambda b, h, p, bt, ln, w: (bt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, p, bt, ln, w: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, G, D), jnp.float32),
        interpret=interpret,
    )(block_tables, kv_lens, window, q, k_pages, v_pages)
