"""repro.training substrate."""
