"""Sharded-aware npz checkpointing.

Arrays are flattened to ``path/to/leaf`` keys.  Sharded ``jax.Array``s
are gathered to host before saving (fine at the example scale; a real
multi-host deployment would write per-shard files — the format keeps a
``_sharding`` sidecar entry so that extension is mechanical).
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


_SEP = "::"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, step: int, trees: Dict[str, Any]) -> str:
    """trees: name -> pytree (e.g. {"params": ..., "opt": ...})."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload: Dict[str, np.ndarray] = {"_step": np.asarray(step)}
    manifest: Dict[str, Any] = {"step": step, "trees": {}}
    for name, tree in trees.items():
        flat = _flatten(tree)
        manifest["trees"][name] = {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()}
        for k, v in flat.items():
            payload[f"{name}{_SEP}{k}"] = v
    payload["_manifest"] = np.frombuffer(
        json.dumps(manifest).encode(), dtype=np.uint8)
    np.savez(path, **payload)
    return path if path.endswith(".npz") else path + ".npz"


def load_checkpoint(path: str, templates: Dict[str, Any],
                    ) -> Tuple[int, Dict[str, Any]]:
    """Restore pytrees with the structure of ``templates``."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    step = int(data["_step"])
    out: Dict[str, Any] = {}
    for name, template in templates.items():
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(
            template)
        new_leaves = []
        for p, leaf in leaves_with_paths:
            key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                            for q in p)
            arr = data[f"{name}{_SEP}{key}"]
            new_leaves.append(jnp.asarray(arr, dtype=leaf.dtype)
                              if hasattr(leaf, "dtype") else arr)
        out[name] = treedef.unflatten(new_leaves)
    return step, out
