"""AdamW + cosine schedule + global-norm clipping (pure JAX pytrees)."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree: Any, max_norm: float,
                        ) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


def adamw_init(params: Any) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def _is_decayed(path: Tuple) -> bool:
    """No decay for norms / biases / scalars (1-D or 0-D leaves)."""
    return True  # decided per-leaf by ndim below


def adamw_update(cfg: AdamWConfig, grads: Any, state: AdamWState,
                 params: Any) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
