"""Training loop: jit'd train_step factory + host-side driver."""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.transformer import Model
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


def make_train_step(model: Model, opt_cfg: AdamWConfig, *,
                    microbatches: int = 1) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Pure function — jit/pjit it at the call site with the
    mesh shardings (see repro.launch).

    ``microbatches > 1`` splits the batch and accumulates gradients
    with a lax.scan — activation temporaries scale with the microbatch
    size while the maths (and the per-step collective *bytes*) stay
    identical.  The perf lever for train shapes whose activation
    working set exceeds HBM (EXPERIMENTS.md §Perf, qwen2-72b)."""

    def loss_fn(p, b):
        loss, metrics = model.loss(p, b)
        return loss, metrics

    def train_step(params, opt_state: AdamWState, batch: Dict[str, Any]):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def body(acc, b):
                g_acc, l_acc = acc
                (l, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, b)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32) / microbatches,
                    g_acc, g)
                return (g_acc, l_acc + l / microbatches), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype),
                                 grads, params)
            metrics = jax.tree.map(lambda x: x[-1], ms)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {"loss": loss, **metrics}
    return eval_step


def train(model: Model, params, batches: Iterator[Dict[str, Any]],
          opt_cfg: AdamWConfig, *, steps: int,
          log_every: int = 10,
          callback: Optional[Callable[[int, Dict], None]] = None,
          ) -> Tuple[Any, AdamWState, list]:
    """Host driver: single-process training for the examples/tests."""
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    opt_state = adamw_init(params)
    history = []
    t0 = time.time()
    for step in range(steps):
        batch = next(batches)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["elapsed_s"] = time.time() - t0
            history.append(m)
            if callback:
                callback(step, m)
    return params, opt_state, history
