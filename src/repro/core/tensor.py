"""ArcLight tensor library (paper §2.2), adapted to JAX.

An ArcLight tensor has two distinct components: a *header* holding
metadata (name, shape, dtype, op type, auxiliary parameters, source
pointers) and a *data* area.  In the C++ original the data area is a
contiguous block of virtual memory carved out of a per-NUMA-node pool;
here the data area is a ``jax.Array`` (materialised lazily by the graph
interpreter) while the header remains an explicit, inspectable Python
object so the graph builder / scheduler / memory planner can reason
about the computation without touching device state.

The paper's appendix A.1 extends the single ``tensor*`` pointer type to
a ``tensor_ptrs`` bundle so that module interfaces are reused unchanged
when tensor parallelism splits the graph into subgraphs.  That is
``TensorBundle`` below: it holds one header per TP subgraph and supports
"mutual assignment with a single tensor pointer" (a bundle of size one
is interchangeable with a bare header).
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
import math
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np


class OpType(enum.Enum):
    """Graph node operation types (the paper's operator library, §2.7)."""

    INPUT = "input"            # graph input (activation entering the graph)
    WEIGHT = "weight"          # parameter tensor (lives in the weight pool)
    VIEW = "view"              # zero-copy view (Scatter creates these)
    COPY = "copy"
    RESHAPE = "reshape"
    TRANSPOSE = "transpose"
    GEMM = "gemm"
    ADD = "add"
    MUL = "mul"
    SILU = "silu"
    GELU = "gelu"
    SOFTMAX = "softmax"
    RMSNORM = "rmsnorm"
    ROPE = "rope"
    ATTENTION = "attention"    # fused (flash-style) attention
    SCATTER = "scatter"        # enter TP mode: split pool into groups, make views
    GATHER = "gather"          # leave TP mode: sum partials, merge pool
    KV_SET = "kv_set"          # KV cache injection
    KV_GET = "kv_get"          # KV cache retrieval
    EMBED = "embed"


#: op types whose output may alias their input (no new allocation).
ALIASING_OPS = frozenset({OpType.VIEW, OpType.RESHAPE, OpType.KV_GET})


_uid = itertools.count()


def _fresh_name(prefix: str) -> str:
    return f"{prefix}_{next(_uid)}"


@dataclasses.dataclass
class TensorHeader:
    """Metadata header of an ArcLight tensor (paper §2.2).

    ``srcs`` are the source-tensor pointers used for computation-graph
    construction; ``params`` are the auxiliary operation parameters
    (e.g. transpose permutation, attention scale).  ``node_id`` is the
    NUMA node (mesh shard, after adaptation) whose local pool owns the
    data area; ``None`` means replicated / node-agnostic.
    """

    name: str
    shape: Tuple[int, ...]
    dtype: Any
    op: OpType = OpType.INPUT
    srcs: Tuple["TensorHeader", ...] = ()
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    node_id: Optional[int] = None
    #: index of the successor node in the static execution list (A.1);
    #: filled in by the graph builder when the node is appended.
    next_index: Optional[int] = None
    #: buffer assigned by the memory manager (pool name, offset).
    buffer: Optional[Tuple[str, int]] = None

    # -- high-level interfaces the paper lists ("get/set names and
    # shapes, or calculate the total byte size required") -------------

    def nbytes(self) -> int:
        return self.numel() * np.dtype(self.dtype).itemsize

    def numel(self) -> int:
        return math.prod(self.shape) if self.shape else 1

    def set_name(self, name: str) -> "TensorHeader":
        self.name = name
        return self

    def with_shape(self, shape: Sequence[int]) -> "TensorHeader":
        self.shape = tuple(int(s) for s in shape)
        return self

    def is_weight(self) -> bool:
        return self.op is OpType.WEIGHT

    def __hash__(self) -> int:  # headers are identity-hashed graph nodes
        return id(self)

    def __eq__(self, other: object) -> bool:
        return self is other

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"TensorHeader({self.name!r}, shape={self.shape}, "
            f"op={self.op.value}, node={self.node_id})"
        )


class TensorBundle:
    """``tensor_ptrs``: a set of tensor pointers (paper A.1).

    Supports "mutual assignment with a single tensor pointer": a bundle
    constructed from one header behaves like that header, and every
    module interface in the graph builder accepts either.  When TP is
    enabled a bundle holds one header per subgraph (per NUMA node /
    model shard).
    """

    __slots__ = ("headers",)

    def __init__(self, headers: Sequence[TensorHeader] | TensorHeader):
        if isinstance(headers, TensorHeader):
            headers = [headers]
        if not headers:
            raise ValueError("empty tensor bundle")
        self.headers: List[TensorHeader] = list(headers)

    # -- single-pointer interchangeability ----------------------------
    @property
    def single(self) -> TensorHeader:
        if len(self.headers) != 1:
            raise ValueError(
                f"bundle of size {len(self.headers)} used where a single "
                "tensor is required (missing Gather?)"
            )
        return self.headers[0]

    def __len__(self) -> int:
        return len(self.headers)

    def __iter__(self) -> Iterator[TensorHeader]:
        return iter(self.headers)

    def __getitem__(self, i: int) -> TensorHeader:
        return self.headers[i]

    @property
    def is_parallel(self) -> bool:
        return len(self.headers) > 1

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.single.shape

    def nbytes(self) -> int:
        return sum(h.nbytes() for h in self.headers)

    def __repr__(self) -> str:  # pragma: no cover
        return f"TensorBundle({[h.name for h in self.headers]})"


def as_bundle(x: TensorBundle | TensorHeader) -> TensorBundle:
    return x if isinstance(x, TensorBundle) else TensorBundle(x)


def make_header(
    shape: Sequence[int],
    dtype: Any = np.float32,
    *,
    name: Optional[str] = None,
    op: OpType = OpType.INPUT,
    srcs: Sequence[TensorHeader] = (),
    node_id: Optional[int] = None,
    **params: Any,
) -> TensorHeader:
    return TensorHeader(
        name=name or _fresh_name(op.value),
        shape=tuple(int(s) for s in shape),
        dtype=np.dtype(dtype),
        op=op,
        srcs=tuple(srcs),
        params=dict(params),
        node_id=node_id,
    )
