"""NUMA topology + analytical throughput cost model (paper §3.1, §4).

This container exposes a single CPU device, so the paper's hardware
experiments (192-core, 4-NUMA Kunpeng-920) are reproduced with a
calibrated first-principles cost model instead of wall-clock timing.
The model is *mechanistic*: it derives per-token time from

  * the bandwidth matrix of Table 1 (local ≈ 102 GB/s per node, remote
    ≈ 22–26 GB/s per node pair),
  * the byte/FLOP traffic of the served model (weights read once per
    decoded token — decode is bandwidth-bound; prefill is
    compute-bound),
  * the placement policy (llama.cpp UMA-distribute vs ArcLight
    NUMA-TP), which determines *which fraction of that traffic crosses
    nodes*, and
  * the synchronisation schedule (Sync A global barriers vs Sync B
    async subgraphs, §3.4).

The same placement logic drives the TPU adaptation: "remote bytes" here
is the quantity that becomes "HLO collective bytes" in the roofline
analysis.  All constants are exposed so benchmarks can sweep them;
defaults are calibrated to the paper's platform and reproduce Figs
10–13 and the headline +46 % / +5 tok/s claims (see
``benchmarks/numa_sim.py`` and EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np



# ----------------------------------------------------------------------
# topology
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NumaTopology:
    """A many-core machine organised as NUMA nodes (Fig 1)."""

    n_nodes: int = 4
    cores_per_node: int = 48
    #: peak local DRAM bandwidth per node, GB/s (6x DDR4 channels)
    local_bw: float = 102.0
    #: cross-node bandwidth per (src,dst) node pair, GB/s
    remote_bw: float = 24.0
    #: achievable per-core streaming bandwidth during Q4_0 GEMV
    #: (dequant + dot; well below pure-STREAM), GB/s
    core_bw: float = 2.6
    #: fraction of STREAM bandwidth a Q4_0 GEMV kernel sustains at node
    #: saturation (dequant overhead, TLB, page-crossing)
    gemv_eff: float = 0.55
    #: per-core compute throughput, GFLOP/s (NEON fp32 FMA @2.6GHz)
    core_gflops: float = 20.8
    #: fixed + per-thread barrier latency, microseconds
    barrier_us: float = 0.8
    barrier_us_per_thread: float = 0.006
    #: cacheline/write-allocate amplification of remote activation reads
    act_amplification: float = 1.8

    @property
    def total_cores(self) -> int:
        return self.n_nodes * self.cores_per_node

    def bandwidth_matrix(self) -> np.ndarray:
        """Reproduce Table 1 (GB/s, rows = core node, cols = memory node).

        The paper's matrix is nearly symmetric with mild ring locality:
        adjacent nodes ~26 GB/s, distant ~22–24 GB/s; diagonal ~101–103.
        """
        m = np.full((self.n_nodes, self.n_nodes), self.remote_bw)
        for i in range(self.n_nodes):
            for j in range(self.n_nodes):
                if i == j:
                    m[i, j] = self.local_bw
                else:
                    hop = min(abs(i - j), self.n_nodes - abs(i - j))
                    m[i, j] = self.remote_bw + (2.0 if hop == 1 else -1.0)
        return m

    def aggregate_remote_bw(self, node: int) -> float:
        """Total bandwidth node ``node``'s cores see to all remote memory."""
        m = self.bandwidth_matrix()
        return float(m[node].sum() - m[node, node])


KUNPENG_920_4NODE = NumaTopology()


# ----------------------------------------------------------------------
# model traffic
# ----------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelTraffic:
    """Per-token byte/FLOP footprint of a decoder-only LLM."""

    name: str
    n_layers: int
    d_model: int
    d_ff: int
    n_heads: int
    n_kv_heads: int
    vocab: int
    bytes_per_weight: float = 0.5625   # Q4_0: 4 bits + scale/32
    act_bytes: int = 4                 # fp32 activations (llama.cpp default)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        d, f, L = self.d_model, self.d_ff, self.n_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        mlp = 3 * d * f
        return L * (attn + mlp) + 2 * self.vocab * d

    @property
    def weight_bytes(self) -> float:
        return self.n_params * self.bytes_per_weight

    @property
    def decode_flops(self) -> float:
        return 2.0 * self.n_params

    def gemm_input_dims(self) -> List[int]:
        """d_in of every GEMM in one layer (row-partitioned view)."""
        d, hd = self.d_model, self.head_dim
        return [d, d, d,                    # q, k, v
                self.n_heads * hd,          # o
                d, d,                       # gate, up
                self.d_ff]                  # down

    @property
    def ops_per_layer(self) -> int:
        # gemms + norms + rope + attention + residuals + activation
        return len(self.gemm_input_dims()) + 6

    def activation_read_bytes_per_thread(self) -> float:
        """Bytes of GEMM input each thread streams per token.

        Row partitioning means every thread reads the *full* input
        vector of every GEMM (for its slice of output rows)."""
        return float(sum(self.gemm_input_dims()) * self.n_layers
                     * self.act_bytes)


#: Qwen3-4B — the paper's evaluation model (Q4_0).
QWEN3_4B = ModelTraffic(
    name="qwen3-4b", n_layers=36, d_model=2560, d_ff=9728,
    n_heads=32, n_kv_heads=8, vocab=151936)


# ----------------------------------------------------------------------
# placement policies + throughput model
# ----------------------------------------------------------------------

@dataclasses.dataclass
class CostBreakdown:
    tokens_per_s: float
    t_weight_local_s: float
    t_weight_remote_s: float
    t_act_remote_s: float
    t_compute_s: float
    t_sync_s: float
    remote_bytes: float
    policy: str


def _sync_time(topo: NumaTopology, n_threads: int, n_barriers: float,
               ) -> float:
    per_barrier = (topo.barrier_us
                   + topo.barrier_us_per_thread * n_threads) * 1e-6
    return n_barriers * per_barrier


def _node_bw_gbs(n_threads_on_node: float, topo: NumaTopology) -> float:
    """Effective local GB/s n streaming threads achieve on one node.

    Few threads cannot saturate the channels (per-core GEMV cap); at
    saturation the Q4_0 kernel sustains ``gemv_eff`` of STREAM, with a
    small (~8 %) contention loss at full core occupancy."""
    if n_threads_on_node <= 0:
        return 0.0
    cap = min(n_threads_on_node * topo.core_bw,
              topo.local_bw * topo.gemv_eff)
    contention = 1.0 - 0.08 * (n_threads_on_node / topo.cores_per_node)
    return cap * contention


def decode_throughput(
    model: ModelTraffic,
    topo: NumaTopology,
    n_threads: int,
    n_nodes_used: int,
    policy: str,
    *,
    sync_mode: str = "sync_b",
    uma_local_fraction: Optional[float] = None,
    batch: int = 1,
) -> CostBreakdown:
    """Per-token decode cost under a placement policy.

    Policies:
      * ``"llama_uma_isolate"``   — all threads on one node; monolithic
        buffer whose pages the OS spreads (a small fraction lands
        remote even in the isolate case — Fig 10's gap).
      * ``"llama_uma_distribute"``— threads round-robin across nodes;
        weights first-touch local but *activations* are scattered, so
        (M-1)/M of every GEMM input read is remote (Fig 7).
      * ``"arclight_numa_tp"``    — ArcLight: per-node pools + TP;
        weights and activations node-local, remote traffic only at the
        per-block Gather (§3.2/3.3).
      * ``"arclight_single"``     — ArcLight on one node (node-local
        enforced; Fig 10's upper curve).
    """
    M = max(1, n_nodes_used)
    threads_per_node = n_threads / M
    node_bw = _node_bw_gbs(threads_per_node, topo) * 1e9   # B/s per node
    remote_bw = topo.aggregate_remote_bw(0) * 1e9          # B/s per node

    W = model.weight_bytes                 # bytes, read once per token
    A_thread = (model.activation_read_bytes_per_thread()
                * topo.act_amplification)
    n_ops = model.ops_per_layer * model.n_layers

    w_local = w_remote = a_remote = 0.0
    if policy == "llama_uma_isolate":
        # isolate packs threads on one node, but the mmap'd model file's
        # page cache spills a small fraction rho to remote nodes; with a
        # single node's worth of threads those remote streams are
        # latency-bound (~30 % of aggregate remote bandwidth).
        rho = 0.06 if uma_local_fraction is None else 1 - uma_local_fraction
        w_local = W * (1 - rho) / node_bw
        # remote streams are latency-bound at ~30 % of per-core bandwidth,
        # capped by 30 % of the aggregate remote link bandwidth
        remote_eff = min(n_threads * topo.core_bw * 0.3e9, 0.3 * remote_bw)
        w_remote = W * rho / remote_eff
        n_barriers = n_ops
    elif policy == "arclight_single":
        w_local = W / node_bw
        n_barriers = n_ops
    elif policy == "llama_uma_distribute":
        # weights: first-touch local per partition -> parallel across nodes
        w_local = (W / M) / node_bw
        # activations: every thread streams full GEMM inputs, (M-1)/M remote
        a_remote = (A_thread * n_threads * (M - 1) / M) / (M * remote_bw)
        # plus the local 1/M share rides the local channels with weights
        w_local += (A_thread * n_threads / M) / (M * node_bw)
        n_barriers = n_ops
    elif policy == "arclight_numa_tp":
        w_local = (W / M) / node_bw
        # Gather: partial outputs (d_model fp32) from M-1 nodes,
        # twice per layer (attention block + MLP block)
        gather_bytes = (model.d_model * model.act_bytes * (M - 1)
                        * 2 * model.n_layers)
        a_remote = gather_bytes / remote_bw
        n_barriers = (2 * 2 * model.n_layers if sync_mode == "sync_b"
                      else n_ops)
    else:
        raise ValueError(f"unknown policy {policy!r}")

    t_mem = w_local + w_remote + a_remote
    t_compute = (model.decode_flops * batch
                 / (n_threads * topo.core_gflops * 1e9))
    t_sync = _sync_time(topo, n_threads, n_barriers)
    t_token = max(t_mem, t_compute) + t_sync
    return CostBreakdown(
        tokens_per_s=batch / t_token,
        t_weight_local_s=w_local, t_weight_remote_s=w_remote,
        t_act_remote_s=a_remote, t_compute_s=t_compute, t_sync_s=t_sync,
        remote_bytes=w_remote * remote_bw + a_remote * remote_bw,
        policy=policy)


def prefill_throughput(
    model: ModelTraffic,
    topo: NumaTopology,
    n_threads: int,
    n_nodes_used: int,
    policy: str,
    *,
    prompt_len: int = 300,
    sync_mode: str = "sync_b",
) -> CostBreakdown:
    """Prefill is compute-bound (paper A.2): weights are reused across
    the whole prompt, so the memory term is amortised by prompt_len."""
    d = decode_throughput(model, topo, n_threads, n_nodes_used, policy,
                          sync_mode=sync_mode)
    t_mem = (d.t_weight_local_s + d.t_weight_remote_s
             + d.t_act_remote_s * prompt_len / 8)  # acts scale w/ tokens; cache reuse
    t_compute = (model.decode_flops * prompt_len
                 / (n_threads * topo.core_gflops * 1e9 * 0.75))  # GEMM eff.
    t_sync = d.t_sync_s
    t_total = max(t_mem, t_compute) + t_sync
    return CostBreakdown(
        tokens_per_s=prompt_len / t_total,
        t_weight_local_s=d.t_weight_local_s,
        t_weight_remote_s=d.t_weight_remote_s,
        t_act_remote_s=d.t_act_remote_s * prompt_len / 8,
        t_compute_s=t_compute, t_sync_s=t_sync,
        remote_bytes=d.remote_bytes, policy=policy)


# ----------------------------------------------------------------------
# figure-level sweeps (consumed by benchmarks/numa_sim.py)
# ----------------------------------------------------------------------

def fig10_single_node(model: ModelTraffic = QWEN3_4B,
                      topo: NumaTopology = KUNPENG_920_4NODE,
                      threads: Sequence[int] = (6, 12, 24, 36, 48),
                      ) -> Dict[str, List[float]]:
    """Decoding speed, all threads on a single NUMA node (Fig 10)."""
    out = {"threads": list(threads), "llama.cpp": [], "arclight": []}
    for t in threads:
        out["llama.cpp"].append(
            decode_throughput(model, topo, t, 1, "llama_uma_isolate").tokens_per_s)
        out["arclight"].append(
            decode_throughput(model, topo, t, 1, "arclight_single").tokens_per_s)
    return out


def fig11_multi_node(model: ModelTraffic = QWEN3_4B,
                     topo: NumaTopology = KUNPENG_920_4NODE,
                     ) -> Dict[str, Dict[int, List[float]]]:
    """Decoding speed across nodes (Fig 11): N=2 and N=4, threads/node
    swept 6..48."""
    per_node = (6, 12, 24, 36, 48)
    out: Dict[str, Dict[int, List[float]]] = {
        "threads_per_node": {n: list(per_node) for n in (2, 4)},
        "llama.cpp": {}, "arclight_tp": {}, "arclight_tp_sync_a": {}}
    for n in (2, 4):
        out["llama.cpp"][n] = [
            decode_throughput(model, topo, t * n, n,
                              "llama_uma_distribute").tokens_per_s
            for t in per_node]
        out["arclight_tp"][n] = [
            decode_throughput(model, topo, t * n, n, "arclight_numa_tp",
                              sync_mode="sync_b").tokens_per_s
            for t in per_node]
        out["arclight_tp_sync_a"][n] = [
            decode_throughput(model, topo, t * n, n, "arclight_numa_tp",
                              sync_mode="sync_a").tokens_per_s
            for t in per_node]
    return out


def fig12_13_long_prompt(model: ModelTraffic = QWEN3_4B,
                         topo: NumaTopology = KUNPENG_920_4NODE,
                         prompt_len: int = 300,
                         ) -> Dict[str, Dict[str, Dict[int, float]]]:
    """Decode + prefill throughput at prompt length 300 (Figs 12/13)."""
    out: Dict[str, Dict[str, Dict[int, float]]] = {
        "decode": {"llama.cpp": {}, "arclight_tp": {}},
        "prefill": {"llama.cpp": {}, "arclight_tp": {}}}
    for n in (2, 4):
        t = 48 * n
        out["decode"]["llama.cpp"][n] = decode_throughput(
            model, topo, t, n, "llama_uma_distribute", batch=1).tokens_per_s * 0.97
        out["decode"]["arclight_tp"][n] = decode_throughput(
            model, topo, t, n, "arclight_numa_tp", batch=1).tokens_per_s * 0.97
        out["prefill"]["llama.cpp"][n] = prefill_throughput(
            model, topo, t, n, "llama_uma_distribute",
            prompt_len=prompt_len).tokens_per_s
        out["prefill"]["arclight_tp"][n] = prefill_throughput(
            model, topo, t, n, "arclight_numa_tp",
            prompt_len=prompt_len).tokens_per_s
    return out


def headline_gain(model: ModelTraffic = QWEN3_4B,
                  topo: NumaTopology = KUNPENG_920_4NODE) -> float:
    """ArcLight-TP over llama.cpp-distribute at 4 nodes x 48 threads —
    the paper's 'up to 46 %' configuration."""
    a = decode_throughput(model, topo, 192, 4, "arclight_numa_tp").tokens_per_s
    b = decode_throughput(model, topo, 192, 4, "llama_uma_distribute").tokens_per_s
    return a / b - 1.0


def async_gain_tokens_per_s(model: ModelTraffic = QWEN3_4B,
                            topo: NumaTopology = KUNPENG_920_4NODE) -> float:
    """Sync B over Sync A in absolute tok/s (paper: ≈ +5 tok/s)."""
    b = decode_throughput(model, topo, 192, 4, "arclight_numa_tp",
                          sync_mode="sync_b").tokens_per_s
    a = decode_throughput(model, topo, 192, 4, "arclight_numa_tp",
                          sync_mode="sync_a").tokens_per_s
    return b - a
