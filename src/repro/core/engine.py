"""The ArcLight inference engine (paper §2.1, Fig 2).

Decoupled architecture: a high-level decoding *frontend* (weight
loading, model definition, autoregressive loop — ``repro.serving``)
over an *inference engine backend* made of the five core modules:

    memory manager   -> core.memory.MemoryManager
    thread manager   -> core.threads.ThreadPool
    tensor library   -> core.tensor
    graph builder    -> core.graph.ForwardGraph
    graph scheduler  -> core.graph.GraphScheduler

``Engine`` composes them behind the streamlined API the paper
describes: build a graph once (static), plan memory (per-node pools +
double buffering), configure the thread pool, then execute the graph
repeatedly.  The engine is the faithful, inspectable reproduction of
the C++ system; the high-throughput production path for the assigned
architectures is the plain-JAX model zoo + pjit (see
``repro.models`` / ``repro.launch``), which reuses the same partition
plan (`core.tp.PartitionPlan`).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import ForwardGraph, GraphScheduler
from .memory import MemoryManager, plan_graph_memory
from .tensor import TensorBundle
from .threads import ThreadPool


@dataclasses.dataclass
class EngineConfig:
    n_nodes: int = 1                 # NUMA nodes / TP degree
    n_threads: int = 8
    numa: bool = True                # per-node pools vs UMA buffer
    double_buffer: bool = True
    sync_mode: str = "sync_b"        # §3.4
    binding: str = "distribute"


@dataclasses.dataclass
class ExecutionReport:
    node_count: int
    barrier_count: int
    weight_bytes: Dict[str, int]
    activation_bytes: Dict[str, int]
    per_node_bytes: Dict[int, int]
    outputs: Dict[str, jax.Array]


class Engine:
    """Backend engine: graph + memory + threads + scheduler."""

    def __init__(self, config: EngineConfig) -> None:
        self.config = config
        self.graph = ForwardGraph(n_nodes=config.n_nodes)
        self.threads = ThreadPool(config.n_threads, n_nodes=config.n_nodes,
                                  binding=config.binding)
        self.memory: Optional[MemoryManager] = None
        self._layer_of: Dict[int, int] = {}
        self._current_layer = 0

    # -- model-definition API (used by the frontend) -------------------
    def begin_layer(self, index: Optional[int] = None) -> None:
        """Advance the activation double-buffer parity (Fig 4)."""
        self._current_layer = (index if index is not None
                               else self._current_layer + 1)

    def track(self, bundle: TensorBundle) -> TensorBundle:
        for h in bundle:
            self._layer_of[id(h)] = self._current_layer
        return bundle

    # -- lifecycle ------------------------------------------------------
    def plan(self) -> MemoryManager:
        """Pre-allocate pools and bind every tensor (§2.3)."""
        for h in self.graph.order:
            self._layer_of.setdefault(id(h), self._current_layer)
        self.memory = plan_graph_memory(
            list(self.graph.weights) + list(self.graph.order),
            self.config.n_nodes, numa=self.config.numa,
            double_buffer=self.config.double_buffer,
            layer_of=self._layer_of)
        return self.memory

    def execute(self, inputs: Dict[str, Any], weights: Dict[str, Any],
                kv: Optional[Dict[str, Any]] = None) -> ExecutionReport:
        if self.memory is None:
            self.plan()
        # reconfigure the pool for the graph's TP degree (Scatter does
        # this dynamically in the C++ engine; the static graph lets us
        # do it once up front).
        if self.config.n_nodes > 1:
            self.threads.split(self.config.n_nodes)
        sched = GraphScheduler(self.graph)
        outputs = sched.run(inputs, weights, kv)
        if self.config.n_nodes > 1:
            self.threads.merge()
        assert self.memory is not None
        return ExecutionReport(
            node_count=self.graph.node_count(),
            barrier_count=sched.barrier_count,
            weight_bytes=self.memory.weight_bytes(),
            activation_bytes=self.memory.activation_bytes(),
            per_node_bytes=self.memory.per_node_bytes(),
            outputs=outputs)


# ----------------------------------------------------------------------
# frontend helper: define a TP transformer MLP through the graph builder
# ----------------------------------------------------------------------

def build_tp_mlp_graph(engine: Engine, d_model: int, d_ff: int,
                       n_tokens: int, *, dtype: Any = jnp.float32,
                       ) -> Tuple[TensorBundle, TensorBundle]:
    """Paper Fig 8b: Scatter -> per-node [silu(A_i X) ; B_i Y_i] -> Gather.

    Returns (input bundle, output bundle).  Weight headers are created
    per node with ``node_id`` set, so the memory manager places each
    partition in its node-local pool.
    """
    g = engine.graph
    n = engine.config.n_nodes
    x = engine.track(g.input((d_model, n_tokens), dtype, name="x"))
    if n == 1:
        a = g.weight((d_ff, d_model), dtype, name="w_gate")
        u = g.weight((d_ff, d_model), dtype, name="w_up")
        b = g.weight((d_model, d_ff), dtype, name="w_down")
        y = engine.track(g.mul(g.silu(g.gemm(a, x)), g.gemm(u, x)))
        z = engine.track(g.gemm(b, y))
        return x, z
    if d_ff % n:
        raise ValueError(f"d_ff={d_ff} not divisible by {n} nodes")
    xs = engine.track(g.scatter(x, n=n))  # replicated views, one per node
    gates, ups, downs = [], [], []
    for i in range(n):
        gates.append(g.weight((d_ff // n, d_model), dtype,
                              name=f"w_gate/node{i}", node_id=i).single)
        ups.append(g.weight((d_ff // n, d_model), dtype,
                            name=f"w_up/node{i}", node_id=i).single)
        downs.append(g.weight((d_model, d_ff // n), dtype,
                              name=f"w_down/node{i}", node_id=i).single)
    a_b, u_b, b_b = (TensorBundle(gates), TensorBundle(ups),
                     TensorBundle(downs))
    y = engine.track(g.mul(g.silu(g.gemm(a_b, xs)), g.gemm(u_b, xs)))
    z_part = engine.track(g.gemm(b_b, y))
    z = engine.track(g.gather(z_part, mode="sum"))
    return x, z


def split_mlp_weights(weights: Dict[str, np.ndarray], n: int,
                      ) -> Dict[str, np.ndarray]:
    """Partition reference MLP weights the way §3.2 prescribes.

    ``w_gate/w_up`` (d_ff, d_model) row-partitioned; ``w_down``
    (d_model, d_ff) column-partitioned."""
    out: Dict[str, np.ndarray] = {}
    for i in range(n):
        f = weights["w_gate"].shape[0] // n
        out[f"w_gate/node{i}"] = weights["w_gate"][i * f:(i + 1) * f]
        out[f"w_up/node{i}"] = weights["w_up"][i * f:(i + 1) * f]
        out[f"w_down/node{i}"] = weights["w_down"][:, i * f:(i + 1) * f]
    return out
