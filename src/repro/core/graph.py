"""ArcLight forward graph builder + computation scheduler (paper §2.5, §2.6, A.1).

The builder exposes tensor-operation interfaces that create graph nodes;
each interface takes source tensor pointers (``TensorBundle``) plus
parameters and returns the output bundle.  Because model definitions are
written in execution order, the paper observes that the construction
order *is* a topological order — so instead of re-analysing the graph we
simply append every node to a static sequential container at the end of
its construction function.  The container supports four construction
modes (paper A.1):

* **Serial**   — append a single-tensor bundle to the tail.
* **Scatter**  — append a multi-tensor bundle after a single tensor:
  transition from one graph to ``n`` parallel subgraphs.
* **Parallel** — within TP-enabled modules, append each tensor of a
  bundle one-to-one onto the previous bundle.
* **Gather**   — append a single tensor after a multi-tensor bundle:
  transition from subgraphs back to a single graph.

The **scheduler** (§2.6) then walks the container in order, executing
each node and synchronising afterwards.  Here execution means
interpreting the node with jax.numpy; on the real engine each node also
carries the thread-group and NUMA-pool assignment produced by
``core.threads`` / ``core.memory``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .tensor import OpType, TensorBundle, TensorHeader, as_bundle, make_header


class GraphError(RuntimeError):
    pass


@dataclasses.dataclass
class KVCacheSlot:
    """A KV-cache tensor managed by the graph module (paper §2.5)."""

    name: str
    header: TensorHeader
    # live value; persists across graph executions.
    value: Optional[jax.Array] = None


class ForwardGraph:
    """Static computation graph with an append-order execution list."""

    def __init__(self, *, n_nodes: int = 1) -> None:
        #: static linked list (array-based) of execution order (A.1).
        self.order: List[TensorHeader] = []
        #: NUMA / TP degree the graph is built for (1 = no TP).
        self.n_nodes = n_nodes
        #: whether construction is currently inside a Scatter..Gather span.
        self._tp_depth = 0
        #: KV cache slots, keyed by name (§2.5).
        self.kv_slots: Dict[str, KVCacheSlot] = {}
        #: inputs in declaration order.
        self.inputs: List[TensorHeader] = []
        #: weights in declaration order.
        self.weights: List[TensorHeader] = []

    # ------------------------------------------------------------------
    # static-list construction modes (A.1)
    # ------------------------------------------------------------------
    def _append(self, header: TensorHeader) -> TensorHeader:
        if self.order:
            # each node stores the index of its successor
            self.order[-1].next_index = len(self.order)
        self.order.append(header)
        return header

    def _append_serial(self, bundle: TensorBundle) -> TensorBundle:
        self._append(bundle.single)
        return bundle

    def _append_parallel(self, bundle: TensorBundle) -> TensorBundle:
        for h in bundle:
            self._append(h)
        return bundle

    # ------------------------------------------------------------------
    # node constructors
    # ------------------------------------------------------------------
    def input(self, shape: Sequence[int], dtype: Any = jnp.float32,
              name: Optional[str] = None) -> TensorBundle:
        h = make_header(shape, dtype, name=name, op=OpType.INPUT)
        self.inputs.append(h)
        return TensorBundle(h)

    def weight(self, shape: Sequence[int], dtype: Any = jnp.float32,
               name: Optional[str] = None,
               node_id: Optional[int] = None) -> TensorBundle:
        h = make_header(shape, dtype, name=name, op=OpType.WEIGHT,
                        node_id=node_id)
        self.weights.append(h)
        return TensorBundle(h)

    def _unary(self, op: OpType, x: TensorBundle | TensorHeader,
               out_shape: Optional[Callable[[Tuple[int, ...]], Tuple[int, ...]]] = None,
               **params: Any) -> TensorBundle:
        x = as_bundle(x)
        outs = []
        for h in x:
            shape = out_shape(h.shape) if out_shape else h.shape
            outs.append(make_header(shape, h.dtype, op=op, srcs=(h,),
                                    node_id=h.node_id, **params))
        out = TensorBundle(outs)
        return (self._append_parallel(out) if out.is_parallel
                else self._append_serial(out))

    def _binary(self, op: OpType, a: TensorBundle | TensorHeader,
                b: TensorBundle | TensorHeader,
                shape_fn: Callable[[Tuple[int, ...], Tuple[int, ...]], Tuple[int, ...]],
                **params: Any) -> TensorBundle:
        a, b = as_bundle(a), as_bundle(b)
        if len(a) != len(b):
            if len(a) == 1:
                a = TensorBundle([a.single] * len(b))
            elif len(b) == 1:
                b = TensorBundle([b.single] * len(a))
            else:
                raise GraphError(f"bundle arity mismatch: {len(a)} vs {len(b)}")
        outs = []
        for ha, hb in zip(a, b):
            node = ha.node_id if ha.node_id is not None else hb.node_id
            outs.append(make_header(shape_fn(ha.shape, hb.shape), ha.dtype,
                                    op=op, srcs=(ha, hb), node_id=node,
                                    **params))
        out = TensorBundle(outs)
        return (self._append_parallel(out) if out.is_parallel
                else self._append_serial(out))

    # -- public op interfaces (the module interfaces of A.1) ----------

    def gemm(self, w: TensorBundle, x: TensorBundle) -> TensorBundle:
        """y = w @ x with w (out, in), x (in, cols) -> y (out, cols)."""

        def shape_fn(ws: Tuple[int, ...], xs: Tuple[int, ...]) -> Tuple[int, ...]:
            if ws[-1] != xs[0]:
                raise GraphError(f"gemm shape mismatch {ws} @ {xs}")
            return ws[:-1] + xs[1:]

        return self._binary(OpType.GEMM, w, x, shape_fn)

    def add(self, a: TensorBundle, b: TensorBundle) -> TensorBundle:
        return self._binary(OpType.ADD, a, b, lambda s, _: s)

    def mul(self, a: TensorBundle, b: TensorBundle) -> TensorBundle:
        return self._binary(OpType.MUL, a, b, lambda s, _: s)

    def silu(self, x: TensorBundle) -> TensorBundle:
        return self._unary(OpType.SILU, x)

    def gelu(self, x: TensorBundle) -> TensorBundle:
        return self._unary(OpType.GELU, x)

    def softmax(self, x: TensorBundle, axis: int = -1) -> TensorBundle:
        return self._unary(OpType.SOFTMAX, x, axis=axis)

    def rmsnorm(self, x: TensorBundle, gain: TensorBundle,
                eps: float = 1e-6) -> TensorBundle:
        return self._binary(OpType.RMSNORM, x, gain, lambda s, _: s, eps=eps)

    def reshape(self, x: TensorBundle, shape: Sequence[int]) -> TensorBundle:
        shape = tuple(int(s) for s in shape)
        return self._unary(OpType.RESHAPE, x, out_shape=lambda _: shape,
                           new_shape=shape)

    def transpose(self, x: TensorBundle, perm: Sequence[int]) -> TensorBundle:
        perm = tuple(perm)
        return self._unary(
            OpType.TRANSPOSE, x,
            out_shape=lambda s: tuple(s[p] for p in perm), perm=perm)

    def copy(self, x: TensorBundle) -> TensorBundle:
        return self._unary(OpType.COPY, x)

    def embed(self, table: TensorBundle, ids: TensorBundle) -> TensorBundle:
        def shape_fn(ts: Tuple[int, ...], is_: Tuple[int, ...]) -> Tuple[int, ...]:
            return is_ + (ts[-1],)
        return self._binary(OpType.EMBED, table, ids, shape_fn)

    # -- KV cache management (§2.5) ------------------------------------

    def kv_create(self, name: str, shape: Sequence[int],
                  dtype: Any = jnp.float32) -> KVCacheSlot:
        if name in self.kv_slots:
            raise GraphError(f"kv slot {name!r} already exists")
        h = make_header(shape, dtype, name=name, op=OpType.WEIGHT)
        slot = KVCacheSlot(name=name, header=h)
        self.kv_slots[name] = slot
        return slot

    def kv_set(self, name: str, value: TensorBundle,
               position: TensorBundle) -> TensorBundle:
        slot = self.kv_slots[name]
        h = make_header(slot.header.shape, slot.header.dtype, op=OpType.KV_SET,
                        srcs=(slot.header, value.single, position.single),
                        kv_name=name)
        return self._append_serial(TensorBundle(h))

    def kv_get(self, name: str) -> TensorBundle:
        slot = self.kv_slots[name]
        h = make_header(slot.header.shape, slot.header.dtype, op=OpType.KV_GET,
                        srcs=(slot.header,), kv_name=name)
        return self._append_serial(TensorBundle(h))

    # -- Scatter / Gather (§3.3) ---------------------------------------

    def scatter(self, x: TensorBundle, *, axis: Optional[int] = None,
                n: Optional[int] = None) -> TensorBundle:
        """Enter TP mode: produce one view tensor per subgraph.

        ``axis=None`` replicates ``x`` into each subgraph (the paper's
        Scatter makes *views* of the input activation for each NUMA
        node; the row-partitioned weights already live node-locally so
        a replicated activation view means zero data motion for the
        activation too — each node reads the same buffer).
        ``axis=k`` slices ``x`` along axis ``k`` instead.
        """
        n = n or self.n_nodes
        if n < 2:
            raise GraphError("scatter needs n >= 2 subgraphs")
        src = x.single
        outs = []
        for i in range(n):
            if axis is None:
                shape = src.shape
            else:
                if src.shape[axis] % n:
                    raise GraphError(
                        f"scatter axis {axis} ({src.shape[axis]}) not divisible by {n}")
                shape = tuple(
                    s // n if d == axis % len(src.shape) else s
                    for d, s in enumerate(src.shape))
            outs.append(make_header(
                shape, src.dtype, op=OpType.SCATTER, srcs=(src,),
                node_id=i, axis=axis, part=i, n=n))
        self._tp_depth += 1
        bundle = TensorBundle(outs)
        # Scatter mode: a multi-tensor bundle appended after a single tensor.
        return self._append_parallel(bundle)

    def gather(self, x: TensorBundle, *, mode: str = "sum",
               axis: int = 0) -> TensorBundle:
        """Leave TP mode: combine subgraph outputs into a single tensor.

        ``mode='sum'`` adds partial outputs (column-partitioned weights:
        the paper's Z = Z1 + Z2); ``mode='concat'`` concatenates along
        ``axis`` (row-partitioned outputs kept split).
        """
        if not x.is_parallel:
            raise GraphError("gather needs a parallel bundle")
        if mode == "sum":
            shape = x[0].shape
        elif mode == "concat":
            shape = tuple(
                s * len(x) if d == axis % len(x[0].shape) else s
                for d, s in enumerate(x[0].shape))
        else:
            raise GraphError(f"unknown gather mode {mode!r}")
        h = make_header(shape, x[0].dtype, op=OpType.GATHER,
                        srcs=tuple(x), mode=mode, axis=axis)
        self._tp_depth -= 1
        # Gather mode: a single tensor appended after a multi-tensor bundle.
        return self._append_serial(TensorBundle(h))

    # ------------------------------------------------------------------
    # properties / verification
    # ------------------------------------------------------------------
    def verify_topological(self) -> bool:
        """Check the append-order container is a valid topological order."""
        seen = set(id(h) for h in self.inputs)
        seen |= set(id(h) for h in self.weights)
        seen |= set(id(s.header) for s in self.kv_slots.values())
        for h in self.order:
            for s in h.srcs:
                if id(s) not in seen and s not in self.order[: self.order.index(h)]:
                    return False
            seen.add(id(h))
        return True

    def node_count(self) -> int:
        return len(self.order)


# ----------------------------------------------------------------------
# Graph computation scheduler (§2.6)
# ----------------------------------------------------------------------

class GraphScheduler:
    """Executes a ForwardGraph node-by-node in static-list order.

    The C++ scheduler runs each node on the thread pool and barriers
    after every node; this interpreter binds each header to a concrete
    ``jax.Array`` in a values dict, which keeps the same sequential
    semantics.  It is deliberately simple — the production fast path is
    the plain-JAX model zoo — but it is *complete*: every op the graph
    builder can emit is executable, so models defined through the
    builder run end to end (and the TP scatter/gather semantics can be
    checked numerically against the non-TP graph).
    """

    def __init__(self, graph: ForwardGraph,
                 barrier_hook: Optional[Callable[[TensorHeader], None]] = None):
        self.graph = graph
        self.barrier_hook = barrier_hook
        #: count of per-node barrier synchronisations performed.
        self.barrier_count = 0

    # -- op semantics ---------------------------------------------------
    def _exec_node(self, h: TensorHeader, env: Dict[int, jax.Array]) -> jax.Array:
        def val(src: TensorHeader) -> jax.Array:
            return env[id(src)]

        op = h.op
        if op is OpType.GEMM:
            w, x = h.srcs
            return jnp.matmul(val(w), val(x))
        if op is OpType.ADD:
            return val(h.srcs[0]) + val(h.srcs[1])
        if op is OpType.MUL:
            return val(h.srcs[0]) * val(h.srcs[1])
        if op is OpType.SILU:
            return jax.nn.silu(val(h.srcs[0]))
        if op is OpType.GELU:
            return jax.nn.gelu(val(h.srcs[0]))
        if op is OpType.SOFTMAX:
            return jax.nn.softmax(val(h.srcs[0]), axis=h.params["axis"])
        if op is OpType.RMSNORM:
            x, g = val(h.srcs[0]), val(h.srcs[1])
            eps = h.params["eps"]
            var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
            return x * jax.lax.rsqrt(var + eps) * g
        if op is OpType.RESHAPE:
            return jnp.reshape(val(h.srcs[0]), h.params["new_shape"])
        if op is OpType.TRANSPOSE:
            return jnp.transpose(val(h.srcs[0]), h.params["perm"])
        if op is OpType.COPY or op is OpType.VIEW:
            return val(h.srcs[0])
        if op is OpType.EMBED:
            table, ids = h.srcs
            return jnp.take(val(table), val(ids), axis=0)
        if op is OpType.SCATTER:
            src = val(h.srcs[0])
            axis, part, n = h.params["axis"], h.params["part"], h.params["n"]
            if axis is None:
                return src
            size = src.shape[axis] // n
            return jax.lax.slice_in_dim(src, part * size, (part + 1) * size,
                                        axis=axis)
        if op is OpType.GATHER:
            parts = [val(s) for s in h.srcs]
            if h.params["mode"] == "sum":
                out = parts[0]
                for p in parts[1:]:
                    out = out + p
                return out
            return jnp.concatenate(parts, axis=h.params["axis"])
        if op is OpType.KV_SET:
            slot_h, value, pos = h.srcs
            cache = env[id(slot_h)]
            updated = jax.lax.dynamic_update_slice_in_dim(
                cache, val(value), val(pos).reshape(()), axis=1)
            env[id(slot_h)] = updated
            return updated
        if op is OpType.KV_GET:
            return env[id(h.srcs[0])]
        raise GraphError(f"scheduler cannot execute op {op}")

    def run(self, inputs: Dict[str, jax.Array],
            weights: Dict[str, jax.Array],
            kv: Optional[Dict[str, jax.Array]] = None,
            ) -> Dict[str, jax.Array]:
        """Execute the whole graph; returns name -> value for every node."""
        g = self.graph
        env: Dict[int, jax.Array] = {}
        for h in g.inputs:
            if h.name not in inputs:
                raise GraphError(f"missing graph input {h.name!r}")
            env[id(h)] = jnp.asarray(inputs[h.name])
        for h in g.weights:
            if h.name not in weights:
                raise GraphError(f"missing weight {h.name!r}")
            env[id(h)] = jnp.asarray(weights[h.name])
        for name, slot in g.kv_slots.items():
            if kv and name in kv:
                env[id(slot.header)] = jnp.asarray(kv[name])
            else:
                env[id(slot.header)] = jnp.zeros(slot.header.shape,
                                                 slot.header.dtype)
        for h in g.order:
            env[id(h)] = self._exec_node(h, env)
            # barrier synchronisation after each node (§2.6)
            self.barrier_count += 1
            if self.barrier_hook is not None:
                self.barrier_hook(h)
        return {h.name: env[id(h)] for h in g.order}
