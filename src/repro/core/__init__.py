"""repro.core — the paper's contribution: ArcLight's engine in JAX.

Modules mirror the five C++ engine modules (paper Fig 2) plus the
cross-NUMA tensor-parallelism layer of §3:

- ``tensor``  — tensor library (§2.2): headers + bundles
- ``graph``   — forward graph builder + scheduler (§2.5/2.6, A.1)
- ``memory``  — memory manager (§2.3): per-node pools, double buffering
- ``threads`` — thread manager (§2.4): groups, Sync A/B schedules
- ``numa``    — NUMA topology, Table-1 bandwidth matrix, cost model
- ``tp``      — cross-NUMA TP (§3) executable under shard_map
- ``engine``  — the composed backend engine (§2.1)
"""

from .engine import Engine, EngineConfig, build_tp_mlp_graph, split_mlp_weights
from .graph import ForwardGraph, GraphScheduler
from .memory import MemoryManager, plan_graph_memory
from .numa import (KUNPENG_920_4NODE, QWEN3_4B, ModelTraffic, NumaTopology,
                   decode_throughput, prefill_throughput)
from .tensor import OpType, TensorBundle, TensorHeader, make_header
from .threads import SyncSchedule, ThreadPool
from .tp import PartitionPlan, make_tp_block, mlp_reference, shard_params

__all__ = [
    "Engine", "EngineConfig", "ForwardGraph", "GraphScheduler",
    "MemoryManager", "ModelTraffic", "NumaTopology", "OpType",
    "PartitionPlan", "SyncSchedule", "TensorBundle", "TensorHeader",
    "ThreadPool", "KUNPENG_920_4NODE", "QWEN3_4B",
    "build_tp_mlp_graph", "decode_throughput", "make_header",
    "make_tp_block", "mlp_reference", "plan_graph_memory",
    "prefill_throughput", "shard_params", "split_mlp_weights",
]
