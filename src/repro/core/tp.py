"""Cross-NUMA tensor parallelism (paper §3), executable in JAX.

The paper's §3.2 weight-partition plan, mapped 1:1 onto a mesh axis
(default ``"model"`` — the NUMA-node axis of the TPU adaptation):

* **row-partitioned** (output features split): ``w_q, w_k, w_v`` (split
  by attention head), ``w_gate, w_up``;
* **column-partitioned** (input features split): ``w_o, w_down``;
* everything else (norm gains, biases on the replicated dim) replicated.

§3.3's operators become:

* ``Scatter`` — entering a TP block.  Row-partitioned weights already
  live shard-locally, so the activation is *replicated* into every
  subgraph (a zero-copy view in the C++ engine; a no-op under
  shard_map because the input arrives replicated over the axis).
* ``Gather``  — leaving a TP block: sum the column-partitioned partial
  outputs — ``jax.lax.psum`` over the axis — and return to single-graph
  mode.

§3.4's synchronisation schedules:

* **Sync A** (global barrier after every operator): after each
  partitioned op the activation is all-gathered to full size and
  re-sliced, i.e. every node sees the globally coherent value before
  the next op.  This is the naive "global coherence" schedule and it
  costs one collective per op.
* **Sync B** (asynchronous subgraphs): activations stay shard-local for
  the whole block; the only collective is the Gather psum.  This is
  ArcLight's schedule.

Both schedules compute identical values (tested); they differ only in
collective traffic — Sync A's extra all-gathers are exactly the thread
idle time of Fig 9, measurable here as HLO collective bytes.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


# ----------------------------------------------------------------------
# §3.2 — the weight-partition plan
# ----------------------------------------------------------------------

ROW_PARTITIONED = ("w_q", "w_k", "w_v", "w_gate", "w_up")
COL_PARTITIONED = ("w_o", "w_down")


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """Paper §3.2 plan for one transformer layer's weights.

    Weight layout convention: every ``w_*`` is stored ``(d_in, d_out)``.
    Row-partitioning (by output feature / attention head) therefore
    shards axis 1; column-partitioning shards axis 0.
    """

    axis: str = "model"

    def spec_for(self, name: str) -> P:
        base = name.rsplit("/", 1)[-1]
        if base in ROW_PARTITIONED:
            return P(None, self.axis)
        if base in COL_PARTITIONED:
            return P(self.axis, None)
        if base in ("embed", "lm_head"):
            return P(None, self.axis)  # vocab-partitioned output features
        return P()  # norms, biases on replicated dims

    def params_specs(self, params: Dict[str, Any]) -> Dict[str, Any]:
        return jax.tree_util.tree_map_with_path(
            lambda path, _: self.spec_for(
                "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                         for k in path)),
            params)


# ----------------------------------------------------------------------
# reference (non-TP) blocks — the "vanilla MLP" of Fig 8a
# ----------------------------------------------------------------------

def mlp_reference(params: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """Z = W_down^T · (silu(W_gate^T X) * (W_up^T X)), weights (in, out)."""
    y = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    return y @ params["w_down"]


def attention_reference(params: Dict[str, jax.Array], x: jax.Array,
                        n_heads: int) -> jax.Array:
    """Single-sequence causal attention block (no cache), for TP checks."""
    t, d = x.shape
    hd = params["w_q"].shape[1] // n_heads
    q = (x @ params["w_q"]).reshape(t, n_heads, hd)
    k = (x @ params["w_k"]).reshape(t, n_heads, hd)
    v = (x @ params["w_v"]).reshape(t, n_heads, hd)
    scores = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(hd).astype(x.dtype)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None], scores, -jnp.inf)
    o = jnp.einsum("hqk,khd->qhd", jax.nn.softmax(scores, axis=-1), v)
    return o.reshape(t, n_heads * hd) @ params["w_o"]


# ----------------------------------------------------------------------
# §3.3 / §3.4 — TP blocks under shard_map
# ----------------------------------------------------------------------

def _sync_a_coherce(x_local: jax.Array, axis: str, shard_dim: int,
                    ) -> jax.Array:
    """Sync A global barrier: all-gather the sharded activation so every
    node observes the coherent global value, then re-slice its shard.

    Numerically a no-op; in HLO it is an all-gather + dynamic-slice per
    call — the collective cost of per-op global synchronisation."""
    full = jax.lax.all_gather(x_local, axis, axis=shard_dim, tiled=True)
    n = jax.lax.psum(1, axis)
    idx = jax.lax.axis_index(axis)
    size = full.shape[shard_dim] // n
    return jax.lax.dynamic_slice_in_dim(full, idx * size, size, shard_dim)


def mlp_tp(params: Dict[str, jax.Array], x: jax.Array, *, axis: str,
           sync_mode: str = "sync_b") -> jax.Array:
    """The paper's TP MLP (Fig 8b) as a shard_map body.

    Inputs: ``x`` replicated over ``axis`` (Scatter's activation view);
    ``w_gate, w_up`` row-sharded (axis 1), ``w_down`` col-sharded
    (axis 0).  Returns the replicated Z = Σ_i B_i Y_i (Gather).
    """
    y = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    if sync_mode == "sync_a":
        y = _sync_a_coherce(y, axis, shard_dim=y.ndim - 1)
    z_partial = y @ params["w_down"]
    return jax.lax.psum(z_partial, axis)          # Gather


def attention_tp(params: Dict[str, jax.Array], x: jax.Array, *,
                 n_heads: int, axis: str, sync_mode: str = "sync_b",
                 ) -> jax.Array:
    """Head-partitioned attention block (Fig 8c) as a shard_map body."""
    n_shards = jax.lax.psum(1, axis)
    heads_local = n_heads // n_shards
    t = x.shape[0]
    hd = params["w_q"].shape[1] // heads_local
    q = (x @ params["w_q"]).reshape(t, heads_local, hd)
    k = (x @ params["w_k"]).reshape(t, heads_local, hd)
    v = (x @ params["w_v"]).reshape(t, heads_local, hd)
    if sync_mode == "sync_a":
        q = _sync_a_coherce(q, axis, shard_dim=1)
        k = _sync_a_coherce(k, axis, shard_dim=1)
        v = _sync_a_coherce(v, axis, shard_dim=1)
    scores = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(hd).astype(x.dtype)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None], scores, -jnp.inf)
    o = jnp.einsum("hqk,khd->qhd", jax.nn.softmax(scores, axis=-1), v)
    z_partial = o.reshape(t, heads_local * hd) @ params["w_o"]
    return jax.lax.psum(z_partial, axis)          # Gather


def make_tp_block(mesh: Mesh, kind: str, *, axis: str = "model",
                  sync_mode: str = "sync_b", n_heads: Optional[int] = None,
                  ) -> Callable[..., jax.Array]:
    """Wrap a TP block body in shard_map with the §3.2 weight specs.

    The returned callable takes (params, x) with *global* arrays; the
    shard_map in_specs implement Scatter (weights shard-local,
    activation replicated) and the psum inside implements Gather.
    """
    plan = PartitionPlan(axis)
    if kind == "mlp":
        body = functools.partial(mlp_tp, axis=axis, sync_mode=sync_mode)
        wnames = ("w_gate", "w_up", "w_down")
    elif kind == "attention":
        if n_heads is None:
            raise ValueError("attention block needs n_heads")
        body = functools.partial(attention_tp, n_heads=n_heads, axis=axis,
                                 sync_mode=sync_mode)
        wnames = ("w_q", "w_k", "w_v", "w_o")
    else:
        raise ValueError(f"unknown TP block kind {kind!r}")

    in_specs = ({w: plan.spec_for(w) for w in wnames}, P())
    return shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)


# ----------------------------------------------------------------------
# engine-level helpers
# ----------------------------------------------------------------------

def shard_params(params: Dict[str, Any], mesh: Mesh,
                 plan: Optional[PartitionPlan] = None) -> Dict[str, Any]:
    """Bind every weight to its node-local pool (NamedSharding)."""
    plan = plan or PartitionPlan()
    specs = plan.params_specs(params)
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        params, specs)


def collective_ops_in(fn: Callable[..., Any], *args: Any) -> Dict[str, int]:
    """Count collective primitives in the jaxpr of ``fn`` (cheap probe
    used by tests/benchmarks to compare Sync A vs Sync B)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    counts: Dict[str, int] = {}

    def walk(jx) -> None:
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if name in ("psum", "all_gather", "all_to_all", "ppermute",
                        "reduce_scatter", "psum_scatter",
                        "all_gather_invariant", "psum_invariant"):
                counts[name] = counts.get(name, 0) + 1
            for sub in eqn.params.values():
                for s in (sub if isinstance(sub, (list, tuple)) else [sub]):
                    if hasattr(s, "eqns"):          # raw Jaxpr
                        walk(s)
                    elif hasattr(s, "jaxpr"):       # ClosedJaxpr
                        walk(s.jaxpr)
    walk(jaxpr.jaxpr)
    return counts
