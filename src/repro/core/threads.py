"""ArcLight thread manager (paper §2.4) — multi-view thread organisation.

The C++ engine creates one pool of worker threads before inference and
introduces the *logical* abstraction of **thread groups** inside it: the
pool can be dynamically reconfigured into ``n`` groups that execute
``n`` independent tensor operations in parallel (Fig 5), with a
**local barrier** confined to each group and a **global barrier** across
the whole pool (Fig 6).

On TPU, "threads" are mesh devices and a "group" is a sub-mesh: a
shard_map over the ``model`` axis gives every device its own program —
the multi-view organisation — while a collective (psum) over an axis is
exactly a barrier over that axis's group.  This module provides:

* ``ThreadPool`` / ``ThreadGroup`` — the logical organisation with the
  paper's reconfiguration interface (``split``/``merge``), used by the
  engine and the NUMA cost model;
* ``SyncSchedule`` — the Sync A (global barrier after every operator)
  vs Sync B (local barriers; global barriers only at Scatter/Gather)
  execution schedules of §3.4, with an analytic idle-time model that
  reproduces Fig 9's behaviour and the paper's ≈5 tok/s async gain.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class ThreadError(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class ThreadGroup:
    """A logical view over a contiguous span of pool threads."""

    group_id: int
    threads: Tuple[int, ...]
    node_id: Optional[int] = None  # NUMA node the group is bound to

    def __len__(self) -> int:
        return len(self.threads)


class ThreadPool:
    """Worker pool with dynamically reconfigurable logical groups."""

    def __init__(self, n_threads: int, *, n_nodes: int = 1,
                 binding: str = "distribute") -> None:
        """``binding``: 'distribute' spreads threads round-robin across
        NUMA nodes (llama.cpp --numa distribute); 'isolate' packs them
        into the fewest nodes (llama.cpp --numa isolate)."""
        if n_threads < 1:
            raise ThreadError("need at least one thread")
        self.n_threads = n_threads
        self.n_nodes = n_nodes
        self.binding = binding
        #: thread -> NUMA node affinity
        if binding == "distribute":
            self.affinity = [t % n_nodes for t in range(n_threads)]
        elif binding == "isolate":
            per = -(-n_threads // n_nodes)  # ceil; pack greedily
            self.affinity = [min(t // per, n_nodes - 1) for t in range(n_threads)]
        else:
            raise ThreadError(f"unknown binding {binding!r}")
        self.groups: List[ThreadGroup] = []
        self.merge()

    # -- explicit reconfiguration interface (paper §2.4) ---------------
    def split(self, n_groups: int) -> List[ThreadGroup]:
        """Reconfigure the pool into ``n_groups`` groups.

        Threads are grouped by NUMA affinity so that each group is
        node-local (the Scatter operator's reconfiguration): group *i*
        gets the threads bound to node ``i % n_nodes``.
        """
        if n_groups < 1 or n_groups > self.n_threads:
            raise ThreadError(f"cannot split {self.n_threads} threads into "
                              f"{n_groups} groups")
        by_node: Dict[int, List[int]] = {}
        for t, node in enumerate(self.affinity):
            by_node.setdefault(node, []).append(t)
        groups: List[ThreadGroup] = []
        if n_groups == len(by_node):
            for gid, node in enumerate(sorted(by_node)):
                groups.append(ThreadGroup(gid, tuple(by_node[node]), node))
        else:
            # fall back to contiguous equal spans
            spans = np.array_split(np.arange(self.n_threads), n_groups)
            for gid, span in enumerate(spans):
                nodes = {self.affinity[t] for t in span}
                node = nodes.pop() if len(nodes) == 1 else None
                groups.append(ThreadGroup(gid, tuple(int(t) for t in span), node))
        self.groups = groups
        return groups

    def merge(self) -> ThreadGroup:
        """Restore the single-group view (the Gather operator's merge)."""
        g = ThreadGroup(0, tuple(range(self.n_threads)),
                        None if self.n_nodes > 1 else 0)
        self.groups = [g]
        return g

    @property
    def n_groups(self) -> int:
        return len(self.groups)

    def group_of(self, thread: int) -> ThreadGroup:
        for g in self.groups:
            if thread in g.threads:
                return g
        raise ThreadError(f"thread {thread} not in any group")


# ----------------------------------------------------------------------
# Sync A / Sync B schedules (§3.4, Fig 9)
# ----------------------------------------------------------------------

@dataclasses.dataclass
class SyncReport:
    mode: str
    makespan: float          # total time of the TP span
    idle_time: float         # summed thread-group idle time at barriers
    global_barriers: int
    local_barriers: int


class SyncSchedule:
    """Analytic model of thread-group synchronisation during TP.

    Given per-group per-op durations ``durations[g][k]`` (group ``g``'s
    time on the ``k``-th operator of the TP span):

    * **Sync A** (global): every group waits for the slowest group after
      *each* operator — makespan = Σ_k max_g d[g][k].
    * **Sync B** (async subgraphs): groups run their whole subgraph
      independently; one global barrier at the end —
      makespan = max_g Σ_k d[g][k].

    Sync B's makespan is never larger (max of sums ≤ sum of maxes) and
    the gap is the idle time ArcLight recovers (Fig 9).
    """

    @staticmethod
    def sync_a(durations: Sequence[Sequence[float]],
               barrier_cost: float = 0.0) -> SyncReport:
        d = np.asarray(durations, dtype=float)
        if d.ndim != 2:
            raise ThreadError("durations must be [group][op]")
        per_op_max = d.max(axis=0)
        makespan = float(per_op_max.sum() + barrier_cost * d.shape[1])
        idle = float((per_op_max[None, :] - d).sum())
        return SyncReport("sync_a", makespan, idle,
                          global_barriers=d.shape[1], local_barriers=0)

    @staticmethod
    def sync_b(durations: Sequence[Sequence[float]],
               barrier_cost: float = 0.0) -> SyncReport:
        d = np.asarray(durations, dtype=float)
        if d.ndim != 2:
            raise ThreadError("durations must be [group][op]")
        per_group = d.sum(axis=1)
        # one global barrier at the start (Scatter) and one at the end
        # (Gather); local barriers after each op inside a group are
        # intra-group and do not stall other groups.
        makespan = float(per_group.max() + 2 * barrier_cost)
        idle = float((per_group.max() - per_group).sum())
        return SyncReport("sync_b", makespan, idle, global_barriers=2,
                          local_barriers=int(d.shape[0] * d.shape[1]))

    @staticmethod
    def speedup(durations: Sequence[Sequence[float]],
                barrier_cost: float = 0.0) -> float:
        a = SyncSchedule.sync_a(durations, barrier_cost).makespan
        b = SyncSchedule.sync_b(durations, barrier_cost).makespan
        return a / b
