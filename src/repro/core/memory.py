"""ArcLight memory manager (paper §2.3).

Responsibilities, mirroring the C++ engine:

* pre-allocate a memory **pool** per NUMA node at startup (vs the single
  UMA buffer of llama.cpp, Fig 3) and bind every tensor's data area to
  the pool of the node whose threads consume it;
* a **double-buffering** mechanism for activations (Fig 4): two
  activation buffers alternated on layer parity, so layer *i* writes
  buffer ``i % 2`` while reading buffer ``(i-1) % 2`` — runtime
  activation memory is 2 × the per-layer peak instead of graph-lifetime
  liveness.

On TPU the "pool" is HBM of a mesh shard and binding is a
``NamedSharding``; this module is the *planner* that decides, before any
allocation, which pool each tensor lives in and how big each pool must
be.  The planner is exact enough to reproduce the paper's memory
accounting and is unit/property-tested (allocation never overlaps, peak
is minimal under the parity policy, UMA vs NUMA placement bytes match).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .tensor import OpType, TensorHeader


_ALIGN = 128  # byte alignment of every carve-out (TPU lane/ sublane friendly)


def _align(n: int, a: int = _ALIGN) -> int:
    return (n + a - 1) // a * a


@dataclasses.dataclass
class Allocation:
    pool: str
    offset: int
    nbytes: int


@dataclasses.dataclass
class Pool:
    """A pre-allocated memory pool bound to one NUMA node (or UMA)."""

    name: str
    node_id: Optional[int]  # None = UMA / replicated
    cursor: int = 0
    peak: int = 0
    allocations: Dict[str, Allocation] = dataclasses.field(default_factory=dict)

    def alloc(self, name: str, nbytes: int) -> Allocation:
        a = Allocation(self.name, self.cursor, _align(nbytes))
        self.cursor += a.nbytes
        self.peak = max(self.peak, self.cursor)
        self.allocations[name] = a
        return a

    def reset(self) -> None:
        self.cursor = 0


class MemoryManager:
    """Plans weight + activation placement over per-node pools.

    ``numa=True``  -> one weight pool and one activation double-buffer
    pair per node (ArcLight strategy, Fig 3 bottom).
    ``numa=False`` -> a single monolithic buffer whose pages the OS
    interleaves (llama.cpp UMA strategy, Fig 3 top); modelled as one
    pool with ``node_id=None``.
    """

    def __init__(self, n_nodes: int = 1, *, numa: bool = True,
                 double_buffer: bool = True) -> None:
        if n_nodes < 1:
            raise ValueError("n_nodes must be >= 1")
        self.n_nodes = n_nodes
        self.numa = numa and n_nodes > 1
        self.double_buffer = double_buffer
        self.weight_pools: List[Pool] = []
        self.act_pools: List[List[Pool]] = []  # [node][parity]
        self.kv_pools: List[Pool] = []         # populated by plan_kv_pages
        if self.numa:
            for i in range(n_nodes):
                self.weight_pools.append(Pool(f"weights/node{i}", i))
                self.act_pools.append(
                    [Pool(f"acts/node{i}/buf{p}", i) for p in range(2)])
        else:
            self.weight_pools.append(Pool("weights/uma", None))
            self.act_pools.append(
                [Pool(f"acts/uma/buf{p}", None) for p in range(2)])

    # ------------------------------------------------------------------
    def place_weight(self, h: TensorHeader) -> Allocation:
        """Bind a weight tensor to its node-local pool."""
        if not h.is_weight():
            raise ValueError(f"{h.name} is not a weight")
        pool = self._pool_for(h.node_id, kind="weight")
        a = pool.alloc(h.name, h.nbytes())
        h.node_id = pool.node_id if pool.node_id is not None else h.node_id
        h.buffer = (a.pool, a.offset)
        return a

    def _pool_for(self, node_id: Optional[int], *, kind: str,
                  parity: int = 0) -> Pool:
        idx = 0
        if self.numa:
            idx = 0 if node_id is None else node_id % self.n_nodes
        if kind == "weight":
            return self.weight_pools[idx]
        return self.act_pools[idx][parity % 2]

    # ------------------------------------------------------------------
    def plan_activations(self, layer_tensors: Sequence[Sequence[TensorHeader]],
                         ) -> Dict[str, Allocation]:
        """Double-buffered activation plan (Fig 4).

        ``layer_tensors[i]`` lists the activation headers produced by
        layer ``i``.  Layer parity selects the buffer; each buffer's
        cursor resets when its parity comes round again, which is safe
        because layer ``i+2`` never reads layer ``i``'s outputs in a
        standard layerwise forward pass.  Without double buffering the
        plan degenerates to one linear region (llama.cpp-style graph
        arena), whose peak we also report for comparison.
        """
        plan: Dict[str, Allocation] = {}
        if not self.double_buffer:
            for layer in layer_tensors:
                for h in layer:
                    pool = self._pool_for(h.node_id, kind="act", parity=0)
                    plan[h.name] = pool.alloc(h.name, h.nbytes())
            return plan

        for i, layer in enumerate(layer_tensors):
            parity = i % 2
            # reset every pool of this parity: the previous same-parity
            # layer's activations are dead once the next layer ran.
            for node_pools in self.act_pools:
                node_pools[parity].reset()
            for h in layer:
                if h.op in (OpType.WEIGHT,):
                    raise ValueError(f"weight {h.name} in activation plan")
                pool = self._pool_for(h.node_id, kind="act", parity=parity)
                plan[h.name] = pool.alloc(h.name, h.nbytes())
                h.buffer = (plan[h.name].pool, plan[h.name].offset)
        return plan

    # ------------------------------------------------------------------
    # KV-cache page pools (serving)
    # ------------------------------------------------------------------
    def plan_kv_pages(self, n_pages: int, page_bytes: int,
                      ) -> List[Allocation]:
        """Carve the serving KV cache into fixed-size pages, one carve-out
        per page, striped round-robin across the node pools.

        The paged KV pool (``repro.serving.kv_pool``) is the runtime
        allocator on top of this plan: a page's *placement* (node, pool
        offset) is decided here at startup, exactly like weights and
        activations, while which *sequence* owns the page changes at
        runtime without moving bytes — ArcLight's pre-allocate-then-bind
        discipline (§2.3) applied to the serving cache.  Returns the
        per-page allocations indexed by page id.
        """
        if self.kv_pools:
            raise ValueError("KV pages already planned")
        if self.numa:
            self.kv_pools = [Pool(f"kv/node{i}", i)
                             for i in range(self.n_nodes)]
        else:
            self.kv_pools = [Pool("kv/uma", None)]
        allocs = []
        for pid in range(n_pages):
            pool = self.kv_pools[pid % len(self.kv_pools)]
            allocs.append(pool.alloc(f"kv_page{pid}", page_bytes))
        return allocs

    def kv_page_node(self, page_id: int) -> int:
        """NUMA node a planned page is resident on (0 under UMA)."""
        if not self.kv_pools:
            raise ValueError("no KV pages planned")
        return self.kv_pools[page_id % len(self.kv_pools)].node_id or 0

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    def weight_bytes(self) -> Dict[str, int]:
        return {p.name: p.peak for p in self.weight_pools}

    def activation_bytes(self) -> Dict[str, int]:
        return {p.name: p.peak for pools in self.act_pools for p in pools}

    def kv_bytes(self) -> Dict[str, int]:
        return {p.name: p.peak for p in self.kv_pools}

    def total_bytes(self) -> int:
        return (sum(self.weight_bytes().values())
                + sum(self.activation_bytes().values())
                + sum(self.kv_bytes().values()))

    def per_node_bytes(self) -> Dict[int, int]:
        """Bytes resident in each node's local memory."""
        out: Dict[int, int] = {}
        for p in self.weight_pools:
            out[p.node_id or 0] = out.get(p.node_id or 0, 0) + p.peak
        for pools in self.act_pools:
            for p in pools:
                out[p.node_id or 0] = out.get(p.node_id or 0, 0) + p.peak
        for p in self.kv_pools:
            out[p.node_id or 0] = out.get(p.node_id or 0, 0) + p.peak
        return out


def plan_graph_memory(order: Sequence[TensorHeader], n_nodes: int, *,
                      numa: bool, double_buffer: bool,
                      layer_of: Optional[Dict[int, int]] = None,
                      ) -> MemoryManager:
    """Convenience: place a whole ForwardGraph execution list.

    ``layer_of`` maps ``id(header) -> layer index`` for the parity
    policy; when absent, every node is treated as layer 0 (single
    buffer).
    """
    mm = MemoryManager(n_nodes, numa=numa, double_buffer=double_buffer)
    acts_by_layer: Dict[int, List[TensorHeader]] = {}
    for h in order:
        if h.is_weight():
            mm.place_weight(h)
            continue
        layer = (layer_of or {}).get(id(h), 0)
        acts_by_layer.setdefault(layer, []).append(h)
    layers = [acts_by_layer[k] for k in sorted(acts_by_layer)]
    mm.plan_activations(layers)
    return mm
