"""ArcLight memory manager (paper §2.3).

Responsibilities, mirroring the C++ engine:

* pre-allocate a memory **pool** per NUMA node at startup (vs the single
  UMA buffer of llama.cpp, Fig 3) and bind every tensor's data area to
  the pool of the node whose threads consume it;
* a **double-buffering** mechanism for activations (Fig 4): two
  activation buffers alternated on layer parity, so layer *i* writes
  buffer ``i % 2`` while reading buffer ``(i-1) % 2`` — runtime
  activation memory is 2 × the per-layer peak instead of graph-lifetime
  liveness.

On TPU the "pool" is HBM of a mesh shard and binding is a
``NamedSharding``; this module is the *planner* that decides, before any
allocation, which pool each tensor lives in and how big each pool must
be.  The planner is exact enough to reproduce the paper's memory
accounting and is unit/property-tested (allocation never overlaps, peak
is minimal under the parity policy, UMA vs NUMA placement bytes match).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .tensor import OpType, TensorHeader


_ALIGN = 128  # byte alignment of every carve-out (TPU lane/ sublane friendly)


def _align(n: int, a: int = _ALIGN) -> int:
    return (n + a - 1) // a * a


@dataclasses.dataclass
class Allocation:
    pool: str
    offset: int
    nbytes: int


@dataclasses.dataclass
class Pool:
    """A pre-allocated memory pool bound to one NUMA node (or UMA).

    ``shard_id`` is set only for KV page pools planned over a TP mesh
    (``plan_kv_pages(n_shards=)``): the mesh shard holding this pool's
    head-slice of every page resident on ``node_id``.
    """

    name: str
    node_id: Optional[int]  # None = UMA / replicated
    cursor: int = 0
    peak: int = 0
    shard_id: Optional[int] = None
    allocations: Dict[str, Allocation] = dataclasses.field(default_factory=dict)

    def alloc(self, name: str, nbytes: int) -> Allocation:
        a = Allocation(self.name, self.cursor, _align(nbytes))
        self.cursor += a.nbytes
        self.peak = max(self.peak, self.cursor)
        self.allocations[name] = a
        return a

    def reset(self) -> None:
        self.cursor = 0


class MemoryManager:
    """Plans weight + activation placement over per-node pools.

    ``numa=True``  -> one weight pool and one activation double-buffer
    pair per node (ArcLight strategy, Fig 3 bottom).
    ``numa=False`` -> a single monolithic buffer whose pages the OS
    interleaves (llama.cpp UMA strategy, Fig 3 top); modelled as one
    pool with ``node_id=None``.
    """

    def __init__(self, n_nodes: int = 1, *, numa: bool = True,
                 double_buffer: bool = True) -> None:
        if n_nodes < 1:
            raise ValueError("n_nodes must be >= 1")
        self.n_nodes = n_nodes
        self.numa = numa and n_nodes > 1
        self.double_buffer = double_buffer
        self.weight_pools: List[Pool] = []
        self.act_pools: List[List[Pool]] = []  # [node][parity]
        self.kv_pools: List[Pool] = []         # populated by plan_kv_pages
        self._kv_nodes = 1                     # nodes the KV plan stripes
        self._kv_shards = 1                    # TP shards per page
        if self.numa:
            for i in range(n_nodes):
                self.weight_pools.append(Pool(f"weights/node{i}", i))
                self.act_pools.append(
                    [Pool(f"acts/node{i}/buf{p}", i) for p in range(2)])
        else:
            self.weight_pools.append(Pool("weights/uma", None))
            self.act_pools.append(
                [Pool(f"acts/uma/buf{p}", None) for p in range(2)])

    # ------------------------------------------------------------------
    def place_weight(self, h: TensorHeader) -> Allocation:
        """Bind a weight tensor to its node-local pool."""
        if not h.is_weight():
            raise ValueError(f"{h.name} is not a weight")
        pool = self._pool_for(h.node_id, kind="weight")
        a = pool.alloc(h.name, h.nbytes())
        h.node_id = pool.node_id if pool.node_id is not None else h.node_id
        h.buffer = (a.pool, a.offset)
        return a

    def _pool_for(self, node_id: Optional[int], *, kind: str,
                  parity: int = 0) -> Pool:
        idx = 0
        if self.numa:
            idx = 0 if node_id is None else node_id % self.n_nodes
        if kind == "weight":
            return self.weight_pools[idx]
        return self.act_pools[idx][parity % 2]

    # ------------------------------------------------------------------
    def plan_activations(self, layer_tensors: Sequence[Sequence[TensorHeader]],
                         ) -> Dict[str, Allocation]:
        """Double-buffered activation plan (Fig 4).

        ``layer_tensors[i]`` lists the activation headers produced by
        layer ``i``.  Layer parity selects the buffer; each buffer's
        cursor resets when its parity comes round again, which is safe
        because layer ``i+2`` never reads layer ``i``'s outputs in a
        standard layerwise forward pass.  Without double buffering the
        plan degenerates to one linear region (llama.cpp-style graph
        arena), whose peak we also report for comparison.
        """
        plan: Dict[str, Allocation] = {}
        if not self.double_buffer:
            for layer in layer_tensors:
                for h in layer:
                    pool = self._pool_for(h.node_id, kind="act", parity=0)
                    plan[h.name] = pool.alloc(h.name, h.nbytes())
            return plan

        for i, layer in enumerate(layer_tensors):
            parity = i % 2
            # reset every pool of this parity: the previous same-parity
            # layer's activations are dead once the next layer ran.
            for node_pools in self.act_pools:
                node_pools[parity].reset()
            for h in layer:
                if h.op in (OpType.WEIGHT,):
                    raise ValueError(f"weight {h.name} in activation plan")
                pool = self._pool_for(h.node_id, kind="act", parity=parity)
                plan[h.name] = pool.alloc(h.name, h.nbytes())
                h.buffer = (plan[h.name].pool, plan[h.name].offset)
        return plan

    # ------------------------------------------------------------------
    # KV-cache page pools (serving)
    # ------------------------------------------------------------------
    def plan_kv_pages(self, n_pages: int, page_bytes: int, *,
                      n_shards: int = 1) -> List[Allocation]:
        """Carve the serving KV cache into fixed-size pages, one carve-out
        per page, striped round-robin across the node pools.

        The paged KV pool (``repro.serving.kv_pool``) is the runtime
        allocator on top of this plan: a page's *placement* (node, pool
        offset) is decided here at startup, exactly like weights and
        activations, while which *sequence* owns the page changes at
        runtime without moving bytes — ArcLight's pre-allocate-then-bind
        discipline (§2.3) applied to the serving cache.  Returns the
        per-page allocations indexed by page id.

        ``n_shards`` > 1 is the tensor-parallel serving layout: the
        page pool is **head-sharded** over the mesh's ``model`` axis, so
        every page's bytes live 1/S on each of the S shards.  Planning
        then carves one ``page_bytes / n_shards`` region per (node,
        shard) pool for every page — ``kv_page_placement`` reports the
        page's (node, shard byte map) and the per-page return value is
        the page's *node-local shard-0* allocation (offsets are
        identical on every shard of the node, so one allocation
        describes all S carve-outs).  Page *rows* never move between
        nodes and head-slices never move between shards: the block
        table is replicated and all runtime ownership changes stay
        host-side, exactly as in the single-shard plan.
        """
        if self.kv_pools:
            raise ValueError("KV pages already planned")
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if page_bytes % n_shards:
            raise ValueError(
                f"page_bytes={page_bytes} does not split over "
                f"{n_shards} shards")
        self._kv_shards = n_shards
        node_ids = list(range(self.n_nodes)) if self.numa else [None]
        self._kv_nodes = len(node_ids)
        self.kv_pools = []
        for i in node_ids:
            tag = f"node{i}" if i is not None else "uma"
            if n_shards == 1:
                self.kv_pools.append(Pool(f"kv/{tag}", i))
            else:
                self.kv_pools.extend(
                    Pool(f"kv/{tag}/shard{s}", i, shard_id=s)
                    for s in range(n_shards))
        allocs = []
        shard_bytes = page_bytes // n_shards
        for pid in range(n_pages):
            node_idx = pid % self._kv_nodes
            first: Optional[Allocation] = None
            for pool in self.kv_pools[node_idx * n_shards:
                                      (node_idx + 1) * n_shards]:
                a = pool.alloc(f"kv_page{pid}", shard_bytes)
                first = first if first is not None else a
            assert first is not None
            allocs.append(first)
        return allocs

    def kv_page_node(self, page_id: int) -> int:
        """NUMA node a planned page is resident on (0 under UMA)."""
        if not self.kv_pools:
            raise ValueError("no KV pages planned")
        node_id = self.kv_pools[
            (page_id % self._kv_nodes) * self._kv_shards].node_id
        return node_id or 0

    def kv_page_placement(self, page_id: int) -> Tuple[int, Tuple[int, ...]]:
        """(node, shards) of a planned page: the NUMA node its rows are
        bound to and the mesh shards its bytes live on — every shard
        under head-sharded TP (each holds the page's local head slice),
        just ``(0,)`` in the single-shard plan."""
        return (self.kv_page_node(page_id), tuple(range(self._kv_shards)))

    @property
    def kv_node_count(self) -> int:
        """Distinct NUMA nodes the KV plan stripes pages across."""
        if not self.kv_pools:
            raise ValueError("no KV pages planned")
        return self._kv_nodes

    @property
    def kv_shard_count(self) -> int:
        """Mesh shards each KV page's bytes are split over (1 = no TP)."""
        if not self.kv_pools:
            raise ValueError("no KV pages planned")
        return self._kv_shards

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    def weight_bytes(self) -> Dict[str, int]:
        return {p.name: p.peak for p in self.weight_pools}

    def activation_bytes(self) -> Dict[str, int]:
        return {p.name: p.peak for pools in self.act_pools for p in pools}

    def kv_bytes(self) -> Dict[str, int]:
        return {p.name: p.peak for p in self.kv_pools}

    def total_bytes(self) -> int:
        return (sum(self.weight_bytes().values())
                + sum(self.activation_bytes().values())
                + sum(self.kv_bytes().values()))

    def per_node_bytes(self) -> Dict[int, int]:
        """Bytes resident in each node's local memory."""
        out: Dict[int, int] = {}
        for p in self.weight_pools:
            out[p.node_id or 0] = out.get(p.node_id or 0, 0) + p.peak
        for pools in self.act_pools:
            for p in pools:
                out[p.node_id or 0] = out.get(p.node_id or 0, 0) + p.peak
        for p in self.kv_pools:
            out[p.node_id or 0] = out.get(p.node_id or 0, 0) + p.peak
        return out


def plan_graph_memory(order: Sequence[TensorHeader], n_nodes: int, *,
                      numa: bool, double_buffer: bool,
                      layer_of: Optional[Dict[int, int]] = None,
                      ) -> MemoryManager:
    """Convenience: place a whole ForwardGraph execution list.

    ``layer_of`` maps ``id(header) -> layer index`` for the parity
    policy; when absent, every node is treated as layer 0 (single
    buffer).
    """
    mm = MemoryManager(n_nodes, numa=numa, double_buffer=double_buffer)
    acts_by_layer: Dict[int, List[TensorHeader]] = {}
    for h in order:
        if h.is_weight():
            mm.place_weight(h)
            continue
        layer = (layer_of or {}).get(id(h), 0)
        acts_by_layer.setdefault(layer, []).append(h)
    layers = [acts_by_layer[k] for k in sorted(acts_by_layer)]
    mm.plan_activations(layers)
    return mm
