"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060]"""

import jax.numpy as jnp

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_heads=32,                      # d_inner = 2*d_model, head_dim 64
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=64,
    tie_embeddings=True,
    long_context="native",             # O(1)-state decode
    dtype=jnp.bfloat16,
    source="arXiv:2405.21060",
)
