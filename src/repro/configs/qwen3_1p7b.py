"""qwen3-1.7b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B]"""

import jax.numpy as jnp

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    arch_type="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    long_context="sliding_window",
    long_context_window=16_384,
    dtype=jnp.bfloat16,
    source="hf:Qwen/Qwen3-8B",
)
