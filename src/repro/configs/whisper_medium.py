"""whisper-medium [audio] — enc-dec transformer backbone; mel+conv
frontend STUBBED (input_specs supplies frame embeddings).

[arXiv:2212.04356]"""

import jax.numpy as jnp

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    arch_type="audio",
    n_layers=24,                       # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,                     # MHA
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    act="gelu",
    is_encoder_decoder=True,
    n_audio_frames=1500,
    tie_embeddings=True,
    long_context="sliding_window",     # decoder windowed for long_500k
    long_context_window=16_384,        # (far outside Whisper's native
    dtype=jnp.bfloat16,                # 448-token regime; exercised
    source="arXiv:2212.04356",         # mechanically per DESIGN.md)
)
