"""grok-1-314b [moe] — 8 experts, top-2, attention logit softcap.

[hf:xai-org/grok-1]"""

import jax.numpy as jnp

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32_768,
    vocab_size=131_072,
    n_experts=8,
    experts_per_token=2,
    attn_logit_softcap=30.0,
    rope_theta=10_000.0,
    tie_embeddings=True,
    long_context="sliding_window",
    long_context_window=16_384,
    remat=True,
    dtype=jnp.bfloat16,
    source="hf:xai-org/grok-1",
)
