"""qwen2-72b [dense] — GQA, QKV bias. [arXiv:2407.10671]"""

import jax.numpy as jnp

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    arch_type="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29_568,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    long_context="sliding_window",
    long_context_window=16_384,
    remat=True,
    dtype=jnp.bfloat16,
    source="arXiv:2407.10671",
)
