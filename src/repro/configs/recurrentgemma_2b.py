"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2:1 pattern.

[arXiv:2402.19427]"""

import jax.numpy as jnp

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,                      # MQA in the attention blocks
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=2560,
    sliding_window=2048,               # local attention
    tie_embeddings=True,
    long_context="native",             # RG-LRU state + window cache
    dtype=jnp.bfloat16,
    source="arXiv:2402.19427",
)
