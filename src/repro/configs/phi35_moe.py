"""phi3.5-moe-42b-a6.6b [moe] — 16 experts, top-2.

[hf:microsoft/Phi-3.5-MoE-instruct]"""

import jax.numpy as jnp

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32_064,
    n_experts=16,
    experts_per_token=2,
    rope_theta=10_000.0,
    tie_embeddings=False,
    long_context="sliding_window",
    long_context_window=16_384,
    remat=True,
    dtype=jnp.bfloat16,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)
