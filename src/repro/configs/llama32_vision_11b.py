"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th layer;
ViT/projector STUBBED (input_specs supplies patch embeddings).

[hf:meta-llama/Llama-3.2-11B-Vision]"""

import jax.numpy as jnp

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    arch_type="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=128_256,
    cross_attn_every=5,                # layers 5,10,...,40 are image layers
    n_image_tokens=6404,               # 4 tiles x 1601 patches
    rope_theta=500_000.0,
    tie_embeddings=False,
    long_context="sliding_window",
    long_context_window=16_384,
    remat=True,
    dtype=jnp.bfloat16,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
