"""Assigned-architecture registry (--arch <id>) + input shapes."""

from __future__ import annotations

from typing import Dict, List

from ..models.config import ModelConfig
from .shapes import SHAPES, InputShape

from .gemma3_1b import CONFIG as _gemma3_1b
from .granite_3_8b import CONFIG as _granite_3_8b
from .qwen3_1p7b import CONFIG as _qwen3_1p7b
from .llama32_vision_11b import CONFIG as _llama32_vision_11b
from .whisper_medium import CONFIG as _whisper_medium
from .phi35_moe import CONFIG as _phi35_moe
from .grok1 import CONFIG as _grok1
from .mamba2_370m import CONFIG as _mamba2_370m
from .qwen2_72b import CONFIG as _qwen2_72b
from .recurrentgemma_2b import CONFIG as _recurrentgemma_2b

ARCHS: Dict[str, ModelConfig] = {
    "gemma3-1b": _gemma3_1b,
    "granite-3-8b": _granite_3_8b,
    "qwen3-1.7b": _qwen3_1p7b,
    "llama-3.2-vision-11b": _llama32_vision_11b,
    "whisper-medium": _whisper_medium,
    "phi3.5-moe-42b-a6.6b": _phi35_moe,
    "grok-1-314b": _grok1,
    "mamba2-370m": _mamba2_370m,
    "qwen2-72b": _qwen2_72b,
    "recurrentgemma-2b": _recurrentgemma_2b,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from "
                       f"{sorted(ARCHS)}")
    return ARCHS[arch]


def list_archs() -> List[str]:
    return sorted(ARCHS)


__all__ = ["ARCHS", "SHAPES", "InputShape", "get_config", "list_archs"]
