"""gemma3-1b [dense] — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt]"""

import jax.numpy as jnp

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    arch_type="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    sliding_window=512,
    local_global_pattern=(5, 1),       # 5 local : 1 global
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    tie_embeddings=True,
    long_context="native",             # window layers native; kv=1 keeps
                                       # the global-layer cache tiny
    dtype=jnp.bfloat16,
    source="hf:google/gemma-3-1b-pt",
)
