"""granite-3-8b [dense] — GQA. [hf:ibm-granite/granite-3.0-2b-base]"""

import jax.numpy as jnp

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    arch_type="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12_800,
    vocab_size=49_155,
    rope_theta=10_000.0,
    tie_embeddings=False,
    long_context="sliding_window",     # full-attention arch: long_500k
    long_context_window=16_384,        # runs only under this window (SW)
    remat=True,
    dtype=jnp.bfloat16,
    source="hf:ibm-granite/granite-3.0-2b-base",
)
