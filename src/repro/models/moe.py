"""Mixture-of-Experts FFN (phi3.5-moe 16e top-2, grok-1 8e top-2).

Two dispatch implementations:

* ``dense``   — every expert computes every token, outputs weighted by
  router gates.  O(E/k) wasted FLOPs; used as the numerical oracle and
  for tiny smoke shapes.
* ``scatter`` — sort-free capacity dispatch (the production path): each
  (token, k) assignment is scattered into a per-expert capacity buffer,
  experts run as one batched einsum, results gather back.  Tokens over
  capacity are dropped (standard top-k MoE semantics); capacity_factor
  1.25 by default.

Under the mesh the expert dimension of the capacity buffer is sharded
on the ``model`` axis (expert parallelism — ArcLight's per-node weight
pools, where a "node" owns whole experts instead of weight rows), and
the scatter/gather becomes the all-to-all the roofline collective term
tracks.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .common import Params, dense_init


def init_moe(key: jax.Array, d: int, f: int, n_experts: int, act: str,
             dtype: Any) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"router": dense_init(ks[0], d, n_experts, jnp.float32)}
    shape_in, shape_out = (n_experts, d, f), (n_experts, f, d)
    def e_init(k, shape):
        import math
        scale = 1.0 / math.sqrt(shape[1])
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)
    if act == "silu":
        p["w_gate"] = e_init(ks[1], shape_in)
    p["w_up"] = e_init(ks[2], shape_in)
    p["w_down"] = e_init(ks[3], shape_out)
    return p


def _expert_ffn(params: Params, h: jax.Array, act: str) -> jax.Array:
    """h: (E, C, d) -> (E, C, d) through each expert's FFN."""
    up = jnp.einsum("ecd,edf->ecf", h, params["w_up"])
    if act == "silu":
        gate = jnp.einsum("ecd,edf->ecf", h, params["w_gate"])
        mid = jax.nn.silu(gate) * up
    else:
        mid = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", mid, params["w_down"])


def _router(params: Params, x2d: jax.Array, k: int,
            ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    logits = (x2d.astype(jnp.float32) @ params["router"])      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                        # (T, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)         # renorm
    return logits, probs, topv, topi


def _aux_loss(probs: jax.Array, topi: jax.Array, n_experts: int,
              ) -> jax.Array:
    """Switch-style load-balance loss: E * Σ_e f_e · P_e."""
    assign = jax.nn.one_hot(topi[..., 0], n_experts, dtype=jnp.float32)
    f = jnp.mean(assign, axis=0)
    p = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * p)


def moe_dense(params: Params, x: jax.Array, *, k: int, act: str,
              ) -> Tuple[jax.Array, jax.Array]:
    """Oracle: all experts on all tokens, gate-masked combine."""
    T = x.shape[:-1]
    d = x.shape[-1]
    x2d = x.reshape(-1, d)
    _, probs, topv, topi = _router(params, x2d, k)
    n_experts = params["w_up"].shape[0]
    outs = _expert_ffn(params, jnp.broadcast_to(
        x2d[None], (n_experts,) + x2d.shape), act)              # (E, T, d)
    weights = jnp.zeros((x2d.shape[0], n_experts), x.dtype)
    for j in range(k):
        weights = weights + jax.nn.one_hot(
            topi[:, j], n_experts, dtype=x.dtype) * topv[:, j:j + 1].astype(x.dtype)
    y = jnp.einsum("etd,te->td", outs, weights)
    return y.reshape(*T, d), _aux_loss(probs, topi, n_experts)


def moe_scatter(params: Params, x: jax.Array, *, k: int, act: str,
                capacity_factor: float = 1.25,
                ) -> Tuple[jax.Array, jax.Array]:
    """Capacity-buffer dispatch (production path)."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2d = x.reshape(-1, d)
    T = x2d.shape[0]
    n_experts = params["w_up"].shape[0]
    _, probs, topv, topi = _router(params, x2d, k)

    e_flat = topi.reshape(-1)                                   # (T*k,)
    w_flat = topv.reshape(-1).astype(x.dtype)
    tok_idx = jnp.repeat(jnp.arange(T), k)

    onehot = jax.nn.one_hot(e_flat, n_experts, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                   # count before me
    pos_in_e = jnp.sum(pos * onehot, axis=-1)                   # (T*k,)
    capacity = max(int(T * k / n_experts * capacity_factor), k)
    keep = pos_in_e < capacity
    slot = e_flat * capacity + pos_in_e                         # (T*k,)
    slot = jnp.where(keep, slot, n_experts * capacity)          # overflow row

    buf = jnp.zeros((n_experts * capacity + 1, d), x.dtype)
    buf = buf.at[slot].add(x2d[tok_idx] * keep[:, None].astype(x.dtype))
    h = _expert_ffn(params, buf[:-1].reshape(n_experts, capacity, d), act)
    h = h.reshape(n_experts * capacity, d)
    gathered = h[jnp.where(keep, slot, 0)] * keep[:, None].astype(x.dtype)
    y2d = jnp.zeros_like(x2d).at[tok_idx].add(
        gathered * w_flat[:, None])
    return y2d.reshape(*lead, d), _aux_loss(probs, topi, n_experts)


def moe(params: Params, x: jax.Array, *, k: int, act: str,
        impl: str = "scatter", capacity_factor: float = 1.25,
        ) -> Tuple[jax.Array, jax.Array]:
    if impl == "dense":
        return moe_dense(params, x, k=k, act=act)
    if impl == "scatter":
        return moe_scatter(params, x, k=k, act=act,
                           capacity_factor=capacity_factor)
    raise ValueError(f"unknown moe impl {impl!r}")
