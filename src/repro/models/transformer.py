"""Unified transformer covering all assigned architecture families.

``build_model(cfg)`` returns a :class:`Model` exposing

    init(key)                      -> params
    forward(params, batch)         -> logits              (teacher forcing)
    loss(params, batch)            -> (scalar, metrics)
    init_cache(B, max_len, ...)    -> cache (zeros)
    prefill(params, batch, cache)  -> (logits, cache)
    decode_step(params, cache, tokens, pos) -> (logits, cache)

Layer stacks compile as a single ``lax.scan`` over stacked parameters
when every layer has the same structure (uniform mode — all dense
archs, MoE archs, Mamba-2 and Whisper), and as an unrolled loop for
heterogeneous patterns (RecurrentGemma's (R,R,A), Llama-3.2-Vision's
every-5th cross-attention layer).  Attention *metadata* — per-layer
sliding window and RoPE base — stays data, so gemma3's 5:1
local:global pattern remains uniform.

KV caches are ring buffers: slot = position mod cache_len, with an
explicit per-slot absolute-position array used for masking.  With
``cache_len == max_len`` this degenerates to the ordinary linear cache;
with ``cache_len == window`` it is the sliding-window cache used for
the long_500k shapes (DESIGN.md §4).

``init_cache(page_size=...)`` instead builds the **paged** cache for
the continuous-batching engine: a shared physical page pool addressed
through per-slot block tables, with ``prefill_paged`` /
vector-position ``decode_step`` as the compiled entry points (see
``init_cache`` and ``repro.serving.kv_pool`` for the layout).  The
paged pool is held as **per-layer buffers run through an unrolled
layer loop** (``_run_paged_layers``), never through the layer scan's
carry — the scan would copy the whole pool every compiled step.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name

from .attention import flash_attention
from .common import (Params, dense_init, embed_init, layer_norm, mlp, init_mlp,
                     proj, rms_norm, unembed)
from .config import ModelConfig
from .moe import init_moe, moe
from .recurrent import RGLRUState, init_rglru_block, rglru_block
from .ssm import SSDState, init_ssd, ssd_block


# ----------------------------------------------------------------------
# parameter init
# ----------------------------------------------------------------------

def _init_norm(cfg: ModelConfig, d: int) -> Params:
    p: Params = {"g": jnp.zeros((d,), cfg.dtype)}
    if cfg.arch_type == "audio":  # whisper uses LayerNorm with bias
        p = {"g": jnp.ones((d,), cfg.dtype), "b": jnp.zeros((d,), cfg.dtype)}
    return p


def _apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if "b" in p:
        return layer_norm(x, p["g"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["g"], cfg.norm_eps)


def _init_attn(key: jax.Array, cfg: ModelConfig, *, cross: bool) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    qdim, kvdim = cfg.n_heads * hd, cfg.n_kv_heads * hd
    ks = jax.random.split(key, 6)
    p: Params = {
        "w_q": dense_init(ks[0], d, qdim, cfg.dtype),
        "w_k": dense_init(ks[1], d, kvdim, cfg.dtype),
        "w_v": dense_init(ks[2], d, kvdim, cfg.dtype),
        "w_o": dense_init(ks[3], qdim, d, cfg.dtype),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((qdim,), cfg.dtype)
        p["b_k"] = jnp.zeros((kvdim,), cfg.dtype)
        p["b_v"] = jnp.zeros((kvdim,), cfg.dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), cfg.dtype)
        p["k_norm"] = jnp.zeros((hd,), cfg.dtype)
    if cross:
        p["gate"] = jnp.zeros((), cfg.dtype)   # tanh-gated cross-attn
    return p


def _init_ffn(key: jax.Array, cfg: ModelConfig) -> Params:
    if cfg.n_experts:
        return {"moe": init_moe(key, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                cfg.act, cfg.dtype)}
    return {"mlp": init_mlp(key, cfg.d_model, cfg.d_ff, cfg.act, cfg.dtype)}


def _init_layer(key: jax.Array, cfg: ModelConfig, kind: str, *,
                decoder_cross: bool = False) -> Params:
    ks = jax.random.split(key, 5)
    p: Params = {"ln1": _init_norm(cfg, cfg.d_model)}
    if kind == "attn":
        p["attn"] = _init_attn(ks[0], cfg, cross=False)
        if decoder_cross:  # whisper decoder: self + cross in every layer
            p["ln_x"] = _init_norm(cfg, cfg.d_model)
            p["xattn"] = _init_attn(ks[1], cfg, cross=True)
        p["ln2"] = _init_norm(cfg, cfg.d_model)
        p.update(_init_ffn(ks[2], cfg))
    elif kind == "xattn":
        p["xattn"] = _init_attn(ks[0], cfg, cross=True)
        p["ln2"] = _init_norm(cfg, cfg.d_model)
        p.update({"mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act,
                                  cfg.dtype)})
    elif kind == "rglru":
        p["rglru"] = init_rglru_block(ks[0], cfg.d_model,
                                      cfg.lru_width or cfg.d_model,
                                      cfg.ssm_conv, cfg.dtype)
        p["ln2"] = _init_norm(cfg, cfg.d_model)
        p.update(_init_ffn(ks[2], cfg))
    elif kind == "ssd":
        p["ssd"] = init_ssd(ks[0], cfg.d_model, n_heads=cfg.ssm_heads,
                            head_dim=cfg.ssm_head_dim, d_state=cfg.ssm_state,
                            n_groups=cfg.ssm_groups, conv_width=cfg.ssm_conv,
                            dtype=cfg.dtype)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return p


# ----------------------------------------------------------------------
# attention forward (shared by self/cross, train/prefill/decode)
# ----------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, ap: Params, xq: jax.Array,
                 xkv: jax.Array, qmm=None,
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    hd = cfg.resolved_head_dim
    q = proj(xq, ap["w_q"], qmm)
    k = proj(xkv, ap["w_k"], qmm)
    v = proj(xkv, ap["w_v"], qmm)
    if cfg.qkv_bias:
        q, k, v = q + ap["b_q"], k + ap["b_k"], v + ap["b_v"]
    Bq, Sq = xq.shape[:2]
    Bk, Sk = xkv.shape[:2]
    q = q.reshape(Bq, Sq, cfg.n_heads, hd)
    k = k.reshape(Bk, Sk, cfg.n_kv_heads, hd)
    v = v.reshape(Bk, Sk, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, ap["q_norm"], cfg.norm_eps)
        k = rms_norm(k, ap["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope(cfg: ModelConfig, x: jax.Array, positions: jax.Array,
          theta: jax.Array) -> jax.Array:
    d = x.shape[-1]
    exponents = jnp.arange(0, d, 2, dtype=jnp.float32) / d
    freqs = jnp.power(jnp.asarray(theta, jnp.float32), -exponents)
    angles = positions[..., None].astype(jnp.float32) * freqs
    sin = jnp.sin(angles)[..., None, :]
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def _write_cache(cache_k: jax.Array, cache_v: jax.Array,
                 cache_pos: jax.Array, k: jax.Array, v: jax.Array,
                 positions: jax.Array):
    """Ring-buffer write. cache_* (B,M,H,D), positions (S,) absolute."""
    M = cache_k.shape[1]
    S = k.shape[1]
    if S >= M:  # keep only the last M tokens (static shapes)
        k, v = k[:, -M:], v[:, -M:]
        positions = positions[-M:]
    slots = positions % M
    if k.shape[1] == 1:
        # single-token decode: dynamic_update_slice keeps a sharded
        # sequence axis local (a scatter would make GSPMD gather it)
        slot = slots[0]
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, 1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, 1)
        cache_pos = jax.lax.dynamic_update_slice_in_dim(
            cache_pos, positions, slot, 0)
    else:
        cache_k = cache_k.at[:, slots].set(k)
        cache_v = cache_v.at[:, slots].set(v)
        cache_pos = cache_pos.at[slots].set(positions)
    return cache_k, cache_v, cache_pos


# ----------------------------------------------------------------------
# layer forward
# ----------------------------------------------------------------------

ATTN_CHUNK = 512


def _remat_policy(cfg: ModelConfig):
    if cfg.remat_save_gather:
        return jax.checkpoint_policies.save_only_these_names("block_out")
    return jax.checkpoint_policies.nothing_saveable


def _paged_attn(cfg: ModelConfig, q: jax.Array, k: jax.Array, v: jax.Array,
                window: jax.Array, cache: Dict[str, jax.Array],
                paged: Dict[str, Any],
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Slot-mapped cache write + block-table attention read.

    ``cache['k']/['v']`` are THIS layer's flat page-pool buffers
    ((n_pages * page_size, Hkv, D)); ``paged`` carries the per-call slot
    mapping (see ``Model.init_cache`` docstring).  Prefill (S > 1)
    scatters the fresh K/V rows to their physical slots; a one-shot
    prefill of a fresh sequence attends over the fresh K/V directly
    (the cache was empty, identical maths), while a *resumed* prefill
    chunk (``paged['prefill_ctx']`` present — chunked prefill or a
    prefix-cached prompt) gathers the full context through the block
    table and attends with absolute-position causal masking.  Decode
    (S == 1) scatters one row per sequence and attends through the
    block table with the gather-based paged kernel.

    An **int8 page pool** (``init_cache(kv_dtype="int8")``) is detected
    by its ``k_scale``/``v_scale`` buffers: fresh K/V rows are
    quantized per (token, kv head) before the scatter (codes + scale
    land in the same physical slots), and reads dequantize *after* the
    gather — the resumed-prefill context gather here, the block-table
    gather inside the paged decode read — so the pool is never
    dequantized wholesale (``repro.quant.kv_int8``).  The fresh
    one-shot prefill still attends over the exact fp32 K/V (the maths
    needs nothing from the pool); later reads see the quantized rows.
    """
    from ..kernels.ops import paged_gqa_decode_attention
    from ..quant.kv_int8 import dequantize_rows, quantize_rows
    B, S = q.shape[:2]
    ps = paged["page_size"]
    write_slots = paged["write_slots"]
    quantized = "k_scale" in cache
    new_cache: Dict[str, jax.Array] = {}

    def write(rows_k, rows_v):
        """Scatter this call's fresh rows ((n, Hkv, D) at write_slots)."""
        if not quantized:
            new_cache["k"] = cache["k"].at[write_slots].set(rows_k)
            new_cache["v"] = cache["v"].at[write_slots].set(rows_v)
            return
        qk_, sk_ = quantize_rows(rows_k)
        qv_, sv_ = quantize_rows(rows_v)
        new_cache["k"] = cache["k"].at[write_slots].set(qk_)
        new_cache["v"] = cache["v"].at[write_slots].set(qv_)
        new_cache["k_scale"] = cache["k_scale"].at[write_slots].set(sk_)
        new_cache["v_scale"] = cache["v_scale"].at[write_slots].set(sv_)

    if "verify" in paged:                # speculative multi-token verify
        # Scatter ALL B*S fresh rows first (draft rows included) — the
        # caller's write_slots already routes idle lanes and past-draft
        # columns to the scratch page — then score each draft offset
        # with the SAME decode kernel a sequential step would run:
        # offset s attends with kv_len + s, exactly the rows visible to
        # a non-speculative decode at that position, so the per-position
        # logits (and hence greedy acceptance) are bitwise-identical to
        # plain decode.  Window masking, softcap and int8 page dequant
        # all ride through the kernel unchanged.
        Hkv, D = k.shape[2], k.shape[3]
        write(k.reshape(B * S, Hkv, D), v.reshape(B * S, Hkv, D))
        outs = []
        for s in range(S):
            kv_len_s = jnp.where(paged["kv_len"] > 0,
                                 paged["kv_len"] + s, 0)
            outs.append(paged_gqa_decode_attention(
                q[:, s:s + 1], new_cache["k"], new_cache["v"],
                paged["block_tables"], kv_len_s, window, page_size=ps,
                softcap=cfg.attn_logit_softcap,
                k_scale=new_cache.get("k_scale"),
                v_scale=new_cache.get("v_scale")))
        out = jnp.concatenate(outs, axis=1)
    elif S > 1:                               # prefill: one sequence
        write(k[0], v[0])
        ck, cv = new_cache["k"], new_cache["v"]
        ctx = paged.get("prefill_ctx")
        if ctx is not None:
            # resumed chunk: earlier tokens' K/V are already resident in
            # the pool (written by prior chunks, shared prefix pages, or
            # a copy-on-write clone) — gather them *after* this chunk's
            # write so q sees [0, kv_len) at absolute positions
            if quantized:
                kctx = dequantize_rows(ck[ctx["phys"]],
                                       new_cache["k_scale"][ctx["phys"]],
                                       q.dtype)[None]
                vctx = dequantize_rows(cv[ctx["phys"]],
                                       new_cache["v_scale"][ctx["phys"]],
                                       q.dtype)[None]
            else:
                kctx = ck[ctx["phys"]][None]
                vctx = cv[ctx["phys"]][None]
            out = flash_attention(q, kctx, vctx, causal=True,
                                  window=window, q_offset=ctx["q_offset"],
                                  kv_len=ctx["kv_len"], chunk=ATTN_CHUNK,
                                  softcap=cfg.attn_logit_softcap)
        else:
            out = flash_attention(q, k, v, causal=True, window=window,
                                  chunk=ATTN_CHUNK,
                                  softcap=cfg.attn_logit_softcap)
    else:                                     # decode: one token per slot
        write(k[:, 0], v[:, 0])
        out = paged_gqa_decode_attention(
            q, new_cache["k"], new_cache["v"], paged["block_tables"],
            paged["kv_len"], window, page_size=ps,
            softcap=cfg.attn_logit_softcap,
            k_scale=new_cache.get("k_scale"),
            v_scale=new_cache.get("v_scale"))
    return out, new_cache


def _self_attn(cfg: ModelConfig, ap: Params, x: jax.Array,
               positions: jax.Array, theta: jax.Array, window: jax.Array,
               cache: Optional[Dict[str, jax.Array]], *, causal: bool,
               decode_hook=None, act_constraint=None, paged=None, qmm=None,
               ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    B, S, d = x.shape
    q, k, v = _project_qkv(cfg, ap, x, x, qmm)
    if act_constraint is not None:
        # batch-only pinning stops GSPMD from "helpfully" splitting the
        # replicated-head attention contraction over the model axis and
        # psum-ing every score chunk (measured: 893 GB/step on gemma3
        # prefill_32k — EXPERIMENTS §Perf)
        q, k, v = act_constraint(q), act_constraint(k), act_constraint(v)
    q = _rope(cfg, q, positions, theta)
    k = _rope(cfg, k, positions, theta)
    new_cache = None
    if cache is not None and paged is not None:
        out, new_cache = _paged_attn(cfg, q, k, v, window, cache, paged)
        merge = paged.get("head_merge")
        if merge is not None:
            # head-sharded TP serving (launch.shardings.make_paged_head
            # _merge): ``out`` holds this shard's local query heads —
            # merge to the full head set (one psum, the layer's only
            # collective) so the replicated w_o below sees the same
            # operand as the single-shard engine, bit for bit
            out = merge(out)
    elif cache is not None and decode_hook is not None and S == 1:
        # sequence-sharded flash-decoding with local cache write
        # (launcher-installed; see launch.shardings.make_decode_attn_hook)
        out, ck, cv, cp = decode_hook(q, k, v, cache["k"], cache["v"],
                                      cache["pos"], window, positions[0])
        new_cache = {"k": ck, "v": cv, "pos": cp}
    elif cache is not None:
        ck, cv, cp = _write_cache(cache["k"], cache["v"], cache["pos"],
                                  k, v, positions)
        new_cache = {"k": ck, "v": cv, "pos": cp}
        if S > 1:
            # prefill: the cache was empty, so attending over the fresh
            # (batch-sharded, model-replicated) k/v is identical maths
            # and independent of the cache's storage sharding
            out = flash_attention(
                q, k, v, causal=True, window=window, chunk=ATTN_CHUNK,
                softcap=cfg.attn_logit_softcap)
        else:
            out = flash_attention(
                q, ck, cv, causal=True, window=window,
                q_offset=positions[0], kv_positions=cp, chunk=ATTN_CHUNK,
                softcap=cfg.attn_logit_softcap)
    else:
        # training path: rematerialise the blockwise attention in the
        # backward pass — the kv-chunk scan would otherwise save its
        # (out, m, l) carries for every chunk (≈ S/chunk copies of the
        # output; measured 8.6 GB/layer on llama-vision train_4k)
        def attn_fn(q_, k_, v_, w_):
            return flash_attention(
                q_, k_, v_, causal=causal, window=w_, chunk=ATTN_CHUNK,
                softcap=cfg.attn_logit_softcap)
        out = jax.checkpoint(attn_fn)(q, k, v, window)
    if act_constraint is not None:
        out = act_constraint(out)
    return proj(out.reshape(B, S, -1), ap["w_o"], qmm), new_cache


def _cross_attn(cfg: ModelConfig, ap: Params, x: jax.Array,
                memory: jax.Array) -> jax.Array:
    """Cross-attention over memory embeddings (B, M, d_model).

    K/V are projected on the fly (their cost is negligible next to the
    self-attention cache traffic; caching them is a recorded perf
    candidate in EXPERIMENTS.md §Perf)."""
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    M = memory.shape[1]
    q = x @ ap["w_q"]
    k = memory @ ap["w_k"]
    v = memory @ ap["w_v"]
    if cfg.qkv_bias:
        q, k, v = q + ap["b_q"], k + ap["b_k"], v + ap["b_v"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, M, cfg.n_kv_heads, hd)
    v = v.reshape(B, M, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, ap["q_norm"], cfg.norm_eps)
        k = rms_norm(k, ap["k_norm"], cfg.norm_eps)
    out = flash_attention(q, k, v, causal=False, chunk=ATTN_CHUNK)
    out = out.reshape(B, S, -1) @ ap["w_o"]
    if "gate" in ap:
        out = jnp.tanh(ap["gate"].astype(jnp.float32)).astype(out.dtype) * out
    return out


def _ffn(cfg: ModelConfig, lp: Params, x: jax.Array,
         moe_hook=None, qmm=None) -> Tuple[jax.Array, jax.Array]:
    if "moe" in lp:
        if moe_hook is not None:   # launcher-installed shard_map dispatch
            return moe_hook(lp["moe"], x)
        y, aux = moe(lp["moe"], x, k=cfg.experts_per_token, act=cfg.act,
                     impl=cfg.moe_impl, capacity_factor=cfg.capacity_factor)
        return y, aux
    return mlp(lp["mlp"], x, cfg.act, qmm), jnp.zeros((), jnp.float32)


def _layer_forward(cfg: ModelConfig, kind: str, lp: Params, x: jax.Array,
                   positions: jax.Array, theta: jax.Array,
                   window: jax.Array, cache: Optional[Dict[str, Any]],
                   memory: Optional[Dict[str, jax.Array]], *,
                   causal: bool, decoder_cross: bool = False,
                   single_step: bool = False, moe_hook=None,
                   decode_hook=None, act_constraint=None, paged=None,
                   qmm=None,
                   ) -> Tuple[jax.Array, Optional[Dict[str, Any]], jax.Array]:
    """One block. Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[Dict[str, Any]] = None
    h = _apply_norm(cfg, lp["ln1"], x)
    if kind == "attn":
        a, kv = _self_attn(cfg, lp["attn"], h, positions, theta, window,
                           None if cache is None else cache.get("self"),
                           causal=causal, decode_hook=decode_hook,
                           act_constraint=act_constraint, paged=paged,
                           qmm=qmm)
        # post-Gather activations are remat save-points: recomputing
        # them would repeat the TP psum in the backward pass
        x = x + checkpoint_name(a, "block_out")
        new_cache = {} if cache is not None else None
        if kv is not None:
            assert new_cache is not None
            new_cache["self"] = kv
        if decoder_cross:
            hx = _apply_norm(cfg, lp["ln_x"], x)
            assert memory is not None
            x = x + _cross_attn(cfg, lp["xattn"], hx, memory)
        h2 = _apply_norm(cfg, lp["ln2"], x)
        f, aux = _ffn(cfg, lp, h2, moe_hook, qmm)
        x = x + checkpoint_name(f, "block_out")
    elif kind == "xattn":
        assert memory is not None
        x = x + _cross_attn(cfg, lp["xattn"], h, memory)
        h2 = _apply_norm(cfg, lp["ln2"], x)
        x = x + mlp(lp["mlp"], h2, cfg.act)
        new_cache = {} if cache is not None else None
    elif kind == "rglru":
        st = None if cache is None else RGLRUState(**cache["rglru"])
        y, new_st = rglru_block(lp["rglru"], h, state=st,
                                single_step=single_step)
        x = x + y
        h2 = _apply_norm(cfg, lp["ln2"], x)
        f, aux = _ffn(cfg, lp, h2, moe_hook)
        x = x + f
        if cache is not None:
            new_cache = {"rglru": new_st._asdict()}
    elif kind == "ssd":
        st = None if cache is None else SSDState(**cache["ssd"])
        y, new_st = ssd_block(lp["ssd"], h, n_heads=cfg.ssm_heads,
                              head_dim=cfg.ssm_head_dim,
                              d_state=cfg.ssm_state, n_groups=cfg.ssm_groups,
                              chunk=cfg.ssm_chunk, state=st)
        x = x + y
        if cache is not None:
            new_cache = {"ssd": new_st._asdict()}
    else:
        raise ValueError(kind)
    return x, new_cache, aux


# ----------------------------------------------------------------------
# the Model
# ----------------------------------------------------------------------

class Model:
    """Unified model over a ModelConfig (see module docstring)."""

    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg
        self.kinds = cfg.layer_kinds
        self.uniform = cfg.uniform
        self.decoder_cross = cfg.is_encoder_decoder
        # periodic pattern archs scan over super-blocks (period p):
        # llama-vision (4 attn + 1 xattn), recurrentgemma (R,R,A) —
        # real per-block remat + O(p) HLO instead of O(n_layers)
        self.block_period = 0
        if not self.uniform:
            p = (len(cfg.block_pattern) if cfg.block_pattern
                 else cfg.cross_attn_every)
            if p and cfg.n_layers >= 2 * p:
                self.block_period = p
        self.n_full_blocks = (cfg.n_layers // self.block_period
                              if self.block_period else 0)
        self.n_tail = (cfg.n_layers - self.n_full_blocks * self.block_period
                       if self.block_period else cfg.n_layers)
        #: optional sharding hooks installed by the launcher
        #: (repro.launch.shardings): per-layer weight unshard constraint
        #: (FSDP) and activation batch constraint.
        self.param_constraint = None
        self.act_constraint = None
        self.moe_hook = None
        self.decode_attn_hook = None
        self.cache_constraint = None
        self.attn_act_constraint = None   # pin q/k/v only for
                                          # replicated-attention archs
        #: TP serving hook: merges a shard's local attention-head
        #: outputs back to the full head set inside the paged path
        #: (installed by serving.runner in mesh mode; the model itself
        #: is then a per-shard "local" model with divided head counts)
        self.paged_head_merge = None
        #: quantized-matmul hook for the paged serving path (installed
        #: by serving.runner under ``QuantPolicy(weights="q4")`` —
        #: ``repro.quant.policy.make_qmm``).  Dense params pass through
        #: it untouched (plain ``x @ w``), so it is safe to leave
        #: installed; None keeps the hook-free matmul everywhere else.
        self.qmm = None

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        params: Params = {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                cfg.dtype),
            "final_norm": _init_norm(cfg, cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(ks[1], cfg.d_model,
                                           cfg.vocab_size, cfg.dtype)
        if self.uniform:
            keys = jax.random.split(ks[2], cfg.n_layers)
            params["layers"] = jax.vmap(
                lambda k: _init_layer(k, cfg, self.kinds[0],
                                      decoder_cross=self.decoder_cross)
            )(keys)
        elif self.block_period:
            p_ = self.block_period
            nb = self.n_full_blocks
            keys = jax.random.split(ks[2], cfg.n_layers)
            blocks = []
            for j in range(p_):
                kind = self.kinds[j]
                pos_keys = jnp.stack([keys[b * p_ + j] for b in range(nb)])
                blocks.append(jax.vmap(
                    lambda k, kind=kind: _init_layer(k, cfg, kind)
                )(pos_keys))
            tail = [_init_layer(keys[nb * p_ + t], cfg,
                                self.kinds[nb * p_ + t])
                    for t in range(self.n_tail)]
            params["layers"] = {"blocks": blocks, "tail": tail}
        else:
            keys = jax.random.split(ks[2], cfg.n_layers)
            params["layers"] = [
                _init_layer(keys[i], cfg, kind)
                for i, kind in enumerate(self.kinds)]
        if cfg.is_encoder_decoder:
            ekeys = jax.random.split(ks[3], cfg.n_encoder_layers)
            enc_cfg = dataclasses.replace(cfg, n_experts=0)
            params["encoder"] = {
                "layers": jax.vmap(
                    lambda k: _init_layer(k, enc_cfg, "attn"))(ekeys),
                "final_norm": _init_norm(cfg, cfg.d_model),
            }
        return params

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def _layer_cache(self, kind: str, batch: int, cache_len: int,
                     dtype: Any) -> Dict[str, Any]:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        if kind in ("attn",):
            return {"self": {
                "k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, hd), dtype),
                "pos": jnp.full((cache_len,), -1, jnp.int32)}}
        if kind == "xattn":
            return {}
        if kind == "rglru":
            width = cfg.lru_width or cfg.d_model
            return {"rglru": {
                "h": jnp.zeros((batch, width), jnp.float32),
                "conv": jnp.zeros((batch, cfg.ssm_conv - 1, width), dtype)}}
        if kind == "ssd":
            d_inner = cfg.ssm_heads * cfg.ssm_head_dim
            conv_ch = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            return {"ssd": {
                "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                                    cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch),
                                  dtype)}}
        raise ValueError(kind)

    def init_cache(self, batch: int, max_len: int, *,
                   cache_len: Optional[int] = None,
                   memory_len: int = 0,
                   page_size: Optional[int] = None,
                   n_pages: Optional[int] = None,
                   kv_dtype: str = "fp32") -> Dict[str, Any]:
        """Zero cache.  ``cache_len`` < max_len -> sliding ring buffer.

        ``page_size`` switches to the **paged slot/block-table view**
        used by the continuous-batching engine: instead of one dense
        (batch, cache_len) ring per sequence, all sequences share one
        physical pool of ``n_pages`` fixed-size pages per layer and are
        addressed through it —

        * ``layers[i].self.k/v``  (n_pages * page_size, Hkv, D) flat
          page-pool buffer of layer ``i`` (page 0 is reserved scratch:
          idle batch slots and padded prefill positions write there).
          The layers are a **Python list of independent buffers**, not
          one stacked (L, ...) array: each buffer is its own jit
          argument/result, so the compiled step never threads the pool
          through a ``lax.scan`` carry (which would copy O(pool bytes)
          per call) and buffer donation lets XLA scatter the touched
          rows in place — per-step cache traffic is O(touched bytes);
        * ``block_tables`` (batch, ceil(max_len / page_size)) int32 —
          physical page of each sequence's logical page, 0 = unmapped.
          Owned by the host-side allocator (``repro.serving.kv_pool``),
          overwritten between steps without touching K/V bytes.

        ``kv_dtype="int8"`` (paged only) allocates **quantized pages**:
        the per-layer K/V buffers hold int8 codes and gain
        ``k_scale``/``v_scale`` companions ((n_pages * page_size, Hkv)
        f32, one scale per token row per kv head — see
        ``repro.quant.kv_int8``).  Bytes per page drop from
        ``2·L·ps·Hkv·D·4`` to ``2·L·ps·Hkv·(D + 4)`` — the capacity
        lever ``serving.kv_pool.KVPoolConfig.page_bytes`` accounts for.

        Per-slot lengths are host state (the scheduler's), passed into
        each call as the position vector — the paged cache carries no
        device-side length array.

        Here ``batch`` is the number of *slots* of the running batch —
        which request occupies a slot changes step to step (join/evict)
        with no shape change, hence no recompilation.
        """
        cfg = self.cfg
        if kv_dtype != "fp32" and page_size is None:
            raise ValueError("kv_dtype applies to the paged cache only "
                             "(pass page_size=...)")
        if page_size is not None:
            if not (self.uniform and self.kinds[0] == "attn"
                    and not self.decoder_cross and not cfg.cross_attn_every):
                raise NotImplementedError(
                    "paged KV cache requires a uniform self-attention "
                    f"stack (arch {cfg.name!r} has kinds {self.kinds[:4]})")
            max_pages = -(-max_len // page_size)
            if n_pages is None:
                n_pages = 1 + batch * max_pages   # page 0 is scratch
            hd = cfg.resolved_head_dim
            rows = n_pages * page_size

            def layer():
                if kv_dtype == "int8":
                    return {"self": {
                        "k": jnp.zeros((rows, cfg.n_kv_heads, hd),
                                       jnp.int8),
                        "v": jnp.zeros((rows, cfg.n_kv_heads, hd),
                                       jnp.int8),
                        "k_scale": jnp.zeros((rows, cfg.n_kv_heads),
                                             jnp.float32),
                        "v_scale": jnp.zeros((rows, cfg.n_kv_heads),
                                             jnp.float32)}}
                if kv_dtype != "fp32":
                    raise ValueError(f"kv_dtype={kv_dtype!r}: "
                                     "choose 'fp32' or 'int8'")
                return {"self": {
                    "k": jnp.zeros((rows, cfg.n_kv_heads, hd), cfg.dtype),
                    "v": jnp.zeros((rows, cfg.n_kv_heads, hd), cfg.dtype)}}

            return {
                "block_tables": jnp.zeros((batch, max_pages), jnp.int32),
                "layers": [layer() for _ in range(cfg.n_layers)],
            }
        cl = min(cache_len or max_len, max_len)
        cache: Dict[str, Any] = {"length": jnp.zeros((), jnp.int32)}
        if self.uniform:
            one = self._layer_cache(self.kinds[0], batch, cl, cfg.dtype)
            cache["layers"] = jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None], (cfg.n_layers,) + x.shape).copy(), one)
        elif self.block_period:
            p_, nb = self.block_period, self.n_full_blocks
            blocks = []
            for j in range(p_):
                one = self._layer_cache(self.kinds[j], batch, cl, cfg.dtype)
                blocks.append(jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (nb,) + x.shape).copy(), one))
            tail = [self._layer_cache(self.kinds[nb * p_ + t], batch, cl,
                                      cfg.dtype)
                    for t in range(self.n_tail)]
            cache["layers"] = {"blocks": blocks, "tail": tail}
        else:
            cache["layers"] = [
                self._layer_cache(kind, batch, cl, cfg.dtype)
                for kind in self.kinds]
        if memory_len:
            cache["memory"] = jnp.zeros((batch, memory_len, cfg.d_model),
                                        cfg.dtype)
        return cache

    # ------------------------------------------------------------------
    # layer stack runners
    # ------------------------------------------------------------------
    def _stack_meta(self):
        cfg = self.cfg
        windows = jnp.asarray(cfg.layer_windows(0), jnp.int32)
        thetas = jnp.asarray(cfg.layer_thetas(), jnp.float32)
        return windows, thetas

    def _run_uniform(self, layers: Params, x: jax.Array,
                     positions: jax.Array, caches: Optional[Params],
                     memory: Optional[jax.Array], *, causal: bool,
                     single_step: bool, window_override: Optional[int],
                     decoder_cross: bool, kind: str,
                     ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
        cfg = self.cfg
        windows, thetas = self._stack_meta()
        if window_override is not None:
            windows = jnp.full_like(windows, window_override)

        fwd = functools.partial(
            _layer_forward, cfg, kind, causal=causal,
            decoder_cross=decoder_cross, single_step=single_step,
            moe_hook=self.moe_hook, decode_hook=self.decode_attn_hook,
            act_constraint=self.attn_act_constraint)
        if cfg.remat and caches is None:   # checkpoint each layer (train)
            fwd = jax.checkpoint(fwd, policy=_remat_policy(cfg))

        if caches is None:
            def body(carry, xs):
                h, aux = carry
                lp, window, theta = xs
                if self.param_constraint is not None:
                    lp = self.param_constraint(lp)
                h, _, a = fwd(lp, h, positions, theta, window, None, memory)
                return (h, aux + a), None

            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)),
                (layers, windows, thetas))
            return x, None, aux

        def body(carry, xs):
            h, aux = carry
            lp, window, theta, cache = xs
            if self.param_constraint is not None:
                lp = self.param_constraint(lp)
            h, new_cache, a = fwd(lp, h, positions, theta, window, cache,
                                  memory)
            return (h, aux + a), new_cache

        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (layers, windows, thetas, caches))
        return x, new_caches, aux

    def _run_blocks(self, layers: Params, x: jax.Array,
                    positions: jax.Array, caches, memory, *, causal: bool,
                    single_step: bool, window_override: Optional[int],
                    ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
        """Scan over super-blocks of a periodic pattern (see __init__)."""
        cfg = self.cfg
        p_, nb = self.block_period, self.n_full_blocks
        windows = list(cfg.layer_windows(0))
        thetas = list(cfg.layer_thetas())
        if window_override is not None:
            windows = [window_override] * cfg.n_layers
        win_rows = jnp.asarray(
            [[windows[b * p_ + j] for j in range(p_)] for b in range(nb)],
            jnp.int32)                                   # (nb, p)
        theta_rows = jnp.asarray(
            [[thetas[b * p_ + j] for j in range(p_)] for b in range(nb)],
            jnp.float32)

        fwd = functools.partial(
            _layer_forward, cfg, causal=causal, single_step=single_step,
            moe_hook=self.moe_hook, decode_hook=self.decode_attn_hook,
            act_constraint=self.attn_act_constraint)

        def block_body(carry, xs):
            h, aux = carry
            lps, wrow, trow, crow = xs
            new_crow = [] if crow is not None else None
            for j in range(p_):
                lp = lps[j]
                if self.param_constraint is not None:
                    lp = self.param_constraint(lp)
                cache_j = None if crow is None else crow[j]
                h, nc, a = fwd(self.kinds[j], lp, h, positions, trow[j],
                               wrow[j], cache_j, memory)
                aux = aux + a
                if new_crow is not None:
                    new_crow.append(nc if nc is not None else {})
            return (h, aux), new_crow

        body = block_body
        if cfg.remat and caches is None:
            body = jax.checkpoint(block_body, policy=_remat_policy(cfg))

        blocks = layers["blocks"]
        cache_blocks = None if caches is None else caches["blocks"]

        def scan_body(carry, xs):
            if caches is None:
                lps, wrow, trow = xs
                return body(carry, (lps, wrow, trow, None))
            lps, wrow, trow, crow = xs
            return body(carry, (lps, wrow, trow, crow))

        xs = ((blocks, win_rows, theta_rows) if caches is None
              else (blocks, win_rows, theta_rows, cache_blocks))
        (x, aux), new_blocks = jax.lax.scan(
            scan_body, (x, jnp.zeros((), jnp.float32)), xs)

        # unrolled remainder layers
        new_tail = None if caches is None else []
        for t in range(self.n_tail):
            i = nb * p_ + t
            lp = layers["tail"][t]
            if self.param_constraint is not None:
                lp = self.param_constraint(lp)
            cache_t = None if caches is None else caches["tail"][t]
            x, nc, a = fwd(self.kinds[i], lp, x, positions,
                           jnp.asarray(thetas[i], jnp.float32),
                           jnp.asarray(windows[i], jnp.int32), cache_t,
                           memory)
            aux = aux + a
            if new_tail is not None:
                new_tail.append(nc if nc is not None else {})
        new_caches = (None if caches is None
                      else {"blocks": new_blocks, "tail": new_tail})
        return x, new_caches, aux

    def _run_pattern(self, layers: List[Params], x: jax.Array,
                     positions: jax.Array, caches: Optional[List],
                     memory: Optional[jax.Array], *, causal: bool,
                     single_step: bool, window_override: Optional[int],
                     ) -> Tuple[jax.Array, Optional[List], jax.Array]:
        cfg = self.cfg
        windows = cfg.layer_windows(0)
        thetas = cfg.layer_thetas()
        aux = jnp.zeros((), jnp.float32)
        new_caches: Optional[List] = None if caches is None else []
        for i, kind in enumerate(self.kinds):
            w = window_override if window_override is not None else windows[i]
            cache_i = None if caches is None else caches[i]
            lp_i = layers[i]
            if self.param_constraint is not None:
                lp_i = self.param_constraint(lp_i)
            fwd = functools.partial(
                _layer_forward, cfg, kind, causal=causal,
                single_step=single_step, moe_hook=self.moe_hook,
                decode_hook=self.decode_attn_hook,
                act_constraint=self.attn_act_constraint)
            if cfg.remat and caches is None:   # per-layer remat (train)
                fwd = jax.checkpoint(fwd)
            x, nc, a = fwd(
                lp_i, x, positions,
                jnp.asarray(thetas[i], jnp.float32),
                jnp.asarray(w, jnp.int32), cache_i, memory)
            aux = aux + a
            if new_caches is not None:
                new_caches.append(nc if nc is not None else {})
        return x, new_caches, aux

    def _run_paged_layers(self, params: Params, x: jax.Array,
                          positions: jax.Array, caches: List, *,
                          single_step: bool,
                          window_override: Optional[int], paged,
                          ) -> Tuple[jax.Array, List, jax.Array]:
        """Unrolled layer loop for the **paged** cache (uniform attn
        stacks only, enforced by ``init_cache``).

        ``caches`` is the per-layer buffer list: every layer's K/V pool
        buffer enters and leaves the jit as its own argument/result
        instead of riding a ``lax.scan`` carry.  The scan variant would
        copy the whole stacked pool once per compiled call (an O(pool
        bytes) floor on every decode step / prefill chunk — ROADMAP:
        measured to dominate chunked prefill at 641 pages); unrolled,
        each buffer's only write is a row scatter, so with the engine's
        buffer donation XLA updates the pool in place and the step costs
        O(touched bytes).  Layer *parameters* stay stacked (L, ...) —
        the per-layer static slice below is the touched-bytes read XLA
        fuses into the layer's matmuls.
        """
        cfg = self.cfg
        windows = list(cfg.layer_windows(0))
        thetas = list(cfg.layer_thetas())
        if window_override is not None:
            windows = [window_override] * cfg.n_layers
        fwd = functools.partial(
            _layer_forward, cfg, self.kinds[0], causal=True,
            single_step=single_step, moe_hook=self.moe_hook,
            decode_hook=self.decode_attn_hook,
            act_constraint=self.attn_act_constraint, paged=paged,
            qmm=self.qmm)
        layers = params["layers"]
        aux = jnp.zeros((), jnp.float32)
        new_caches: List = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a, i=i: a[i], layers)
            if self.param_constraint is not None:
                lp = self.param_constraint(lp)
            x, nc, a = fwd(lp, x, positions,
                           jnp.asarray(thetas[i], jnp.float32),
                           jnp.asarray(windows[i], jnp.int32),
                           caches[i], None)
            aux = aux + a
            new_caches.append(nc if nc is not None else {})
        return x, new_caches, aux

    def _run_layers(self, params: Params, x: jax.Array,
                    positions: jax.Array, caches, memory, *, causal: bool,
                    single_step: bool = False,
                    window_override: Optional[int] = None):
        if self.uniform:
            return self._run_uniform(
                params["layers"], x, positions, caches, memory,
                causal=causal, single_step=single_step,
                window_override=window_override,
                decoder_cross=self.decoder_cross, kind=self.kinds[0])
        if self.block_period:
            return self._run_blocks(
                params["layers"], x, positions, caches, memory,
                causal=causal, single_step=single_step,
                window_override=window_override)
        return self._run_pattern(
            params["layers"], x, positions, caches, memory,
            causal=causal, single_step=single_step,
            window_override=window_override)

    def _encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """Whisper-style encoder over stub frame embeddings (B, F, d)."""
        cfg = self.cfg
        enc = params["encoder"]
        positions = jnp.arange(frames.shape[1])
        windows = jnp.zeros((cfg.n_encoder_layers,), jnp.int32)
        thetas = jnp.full((cfg.n_encoder_layers,), cfg.rope_theta,
                          jnp.float32)
        fwd = functools.partial(_layer_forward, cfg, "attn", causal=False,
                                decoder_cross=False, single_step=False)

        def body(carry, xs):
            lp, window, theta = xs
            h, _, _ = fwd(lp, carry, positions, theta, window, None, None)
            return h, None

        x, _ = jax.lax.scan(body, frames, (enc["layers"], windows, thetas))
        return _apply_norm(cfg, enc["final_norm"], x)

    def _memory_from_batch(self, params: Params, batch: Dict[str, Any],
                           ) -> Optional[jax.Array]:
        if self.cfg.is_encoder_decoder:
            return self._encode(params, batch["frames"])
        if self.cfg.cross_attn_every:
            return batch["image_embeds"]
        return None

    def _logits(self, params: Params, x: jax.Array) -> jax.Array:
        x = _apply_norm(self.cfg, params["final_norm"], x)
        head = (params["embed"] if self.cfg.tie_embeddings
                else params["lm_head"])
        return unembed(head, x, tied=self.cfg.tie_embeddings)

    # ------------------------------------------------------------------
    # public entry points
    # ------------------------------------------------------------------
    def _hidden_states(self, params: Params, batch: Dict[str, Any],
                       ) -> Tuple[jax.Array, jax.Array]:
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.act_constraint is not None:
            x = self.act_constraint(x)
        positions = jnp.arange(tokens.shape[1])
        memory = self._memory_from_batch(params, batch)
        x, _, aux = self._run_layers(params, x, positions, None, memory,
                                     causal=True)
        return _apply_norm(self.cfg, params["final_norm"], x), aux

    def forward(self, params: Params, batch: Dict[str, Any],
                ) -> Tuple[jax.Array, jax.Array]:
        """Teacher-forcing logits over the whole sequence (train)."""
        h, aux = self._hidden_states(params, batch)
        head = (params["embed"] if self.cfg.tie_embeddings
                else params["lm_head"])
        return unembed(head, h, tied=self.cfg.tie_embeddings), aux

    LOSS_CHUNK = 512

    def loss(self, params: Params, batch: Dict[str, Any],
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Chunked cross entropy: logits are materialised only one
        sequence chunk at a time — full (B, S, V) fp32 logits of a
        256k-vocab model would dwarf every other activation."""
        h, aux = self._hidden_states(params, batch)
        head = (params["embed"] if self.cfg.tie_embeddings
                else params["lm_head"])
        labels = batch["labels"]
        B, S, d = h.shape
        C = min(self.LOSS_CHUNK, S)
        pad = (-S) % C
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)),
                             constant_values=-100)
        n_chunks = (S + pad) // C
        hc = jnp.moveaxis(h.reshape(B, n_chunks, C, d), 1, 0)
        yc = jnp.moveaxis(labels.reshape(B, n_chunks, C), 1, 0)

        def body(carry, xs):
            nll_sum, count = carry
            h_i, y_i = xs
            if self.act_constraint is not None:
                h_i = self.act_constraint(h_i)
            logits = unembed(head, h_i, tied=self.cfg.tie_embeddings)
            lf = logits.astype(jnp.float32)
            logz = jax.nn.logsumexp(lf, axis=-1)
            gold = jnp.take_along_axis(
                lf, jnp.clip(y_i, 0)[..., None], axis=-1)[..., 0]
            mask = (y_i != -100).astype(jnp.float32)
            nll_sum = nll_sum + jnp.sum((logz - gold) * mask)
            count = count + jnp.sum(mask)
            return (nll_sum, count), None

        (nll_sum, count), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hc, yc))
        ce = nll_sum / jnp.maximum(count, 1.0)
        total = ce + self.cfg.router_aux_coef * aux
        return total, {"ce": ce, "aux": aux}

    def prefill(self, params: Params, batch: Dict[str, Any],
                cache: Dict[str, Any], *,
                window_override: Optional[int] = None,
                ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Process the prompt, fill the cache, return last-token logits."""
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.act_constraint is not None:
            x = self.act_constraint(x)
        positions = jnp.arange(tokens.shape[1])
        memory = self._memory_from_batch(params, batch)
        x, new_layers, _ = self._run_layers(
            params, x, positions, cache["layers"], memory, causal=True,
            window_override=window_override)
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
        new_cache["length"] = jnp.asarray(tokens.shape[1], jnp.int32)
        if memory is not None:
            new_cache["memory"] = memory
        return self._logits(params, x[:, -1:]), new_cache

    def prefill_paged(self, params: Params, batch: Dict[str, Any],
                      cache: Dict[str, Any], slot: jax.Array,
                      plen: jax.Array, *, page_size: int,
                      start: Optional[jax.Array] = None,
                      ctx_pages: Optional[int] = None,
                      window_override: Optional[int] = None,
                      ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Prefill ONE sequence chunk into batch slot ``slot`` of a
        paged cache.

        ``batch['tokens']`` is (1, Sp) right-padded to any convenient
        bucket length; ``plen`` (traced scalar) is the real chunk
        length, so one compilation per Sp serves every shorter chunk.
        K/V rows land in the physical pages ``cache['block_tables'][slot]``
        maps (padded positions fall through unmapped entries to the
        scratch page).  Returns logits of the *last real* token.

        ``start`` (traced scalar) resumes prefill at an arbitrary
        absolute position offset: the chunk's tokens sit at positions
        ``[start, start + plen)`` and attention runs over the whole
        resident context ``[0, start + plen)``, gathered through the
        block table from ``ctx_pages`` leading pages (static — the
        caller buckets it; pages past the table or past ``kv_len`` are
        masked out).  This is the entry point for **chunked prefill**
        and for resuming after a **prefix-cache** hit, where positions
        ``[0, start)`` were filled by earlier chunks, shared pages, or
        a copy-on-write clone.  ``start=None`` is the one-shot fresh
        path (attends over its own K/V only — identical maths, cheaper).
        """
        tokens = batch["tokens"]
        Sp = tokens.shape[1]
        slot = jnp.asarray(slot, jnp.int32)
        plen = jnp.asarray(plen, jnp.int32)
        x = jnp.take(params["embed"], tokens, axis=0)
        offsets = jnp.arange(Sp)
        bt_row = cache["block_tables"][slot]              # (max_pages,)
        if start is None:
            positions = offsets
        else:
            positions = jnp.asarray(start, jnp.int32) + offsets
        phys = bt_row[positions // page_size] * page_size \
            + positions % page_size
        # padding rows go to the scratch page unconditionally: when the
        # padded bucket overruns max_pages * page_size the block-table
        # gather above clamps to the LAST page — a real one — and would
        # clobber cached prompt tokens
        write_slots = jnp.where(offsets < plen, phys,
                                offsets % page_size)
        paged: Dict[str, Any] = {"page_size": page_size,
                                 "write_slots": write_slots}
        if self.paged_head_merge is not None:
            paged["head_merge"] = self.paged_head_merge
        if start is not None:
            if ctx_pages is None:
                raise ValueError("resumed prefill needs static ctx_pages")
            ctx_pos = jnp.arange(ctx_pages * page_size)
            paged["prefill_ctx"] = {
                "phys": bt_row[ctx_pos // page_size] * page_size
                        + ctx_pos % page_size,
                "kv_len": jnp.asarray(start, jnp.int32) + plen,
                "q_offset": jnp.asarray(start, jnp.int32),
            }
        x, new_layers, _ = self._run_paged_layers(
            params, x, positions, cache["layers"], single_step=False,
            window_override=window_override, paged=paged)
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
        last = jax.lax.dynamic_slice_in_dim(x, plen - 1, 1, axis=1)
        return self._logits(params, last), new_cache

    def decode_step(self, params: Params, cache: Dict[str, Any],
                    tokens: jax.Array, pos: jax.Array, *,
                    window_override: Optional[int] = None,
                    page_size: Optional[int] = None,
                    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """One decode step.  tokens (B, 1).

        Ring cache: ``pos`` is a *scalar* absolute position shared by
        the whole (lockstep) batch.  Paged cache (``page_size`` given):
        ``pos`` is a **vector** (B,) of per-request absolute positions —
        requests in different decode phases share one step; ``pos[b] < 0``
        marks an idle slot (its write goes to the scratch page and its
        attention is fully masked).
        """
        if page_size is not None:
            return self._decode_step_paged(
                params, cache, tokens, pos, page_size=page_size,
                window_override=window_override)
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.cache_constraint is not None:
            cache = self.cache_constraint(cache)
        positions = pos + jnp.arange(1)
        memory = cache.get("memory")
        x, new_layers, _ = self._run_layers(
            params, x, positions, cache["layers"], memory, causal=True,
            single_step=True, window_override=window_override)
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
        new_cache["length"] = (pos + 1).astype(jnp.int32)
        if self.cache_constraint is not None:
            new_cache = self.cache_constraint(new_cache)
        return self._logits(params, x), new_cache

    def _decode_step_paged(self, params: Params, cache: Dict[str, Any],
                           tokens: jax.Array, pos: jax.Array, *,
                           page_size: int,
                           window_override: Optional[int] = None,
                           ) -> Tuple[jax.Array, Dict[str, Any]]:
        pos = jnp.asarray(pos, jnp.int32)                 # (B,)
        safe_pos = jnp.maximum(pos, 0)
        x = jnp.take(params["embed"], tokens, axis=0)     # (B, 1, d)
        bt = cache["block_tables"]
        B = bt.shape[0]
        phys = bt[jnp.arange(B), safe_pos // page_size] * page_size \
            + safe_pos % page_size                        # (B,)
        # idle lanes (pos < 0) MUST land on the scratch page even when
        # their slot's block table is populated (a sequence that was
        # prefilled this step but isn't decoding yet would otherwise get
        # its first page clobbered by the lane's garbage write)
        write_slots = jnp.where(pos >= 0, phys, safe_pos % page_size)
        kv_len = jnp.maximum(pos + 1, 0)
        paged = {"page_size": page_size, "write_slots": write_slots,
                 "block_tables": bt, "kv_len": kv_len}
        if self.paged_head_merge is not None:
            paged["head_merge"] = self.paged_head_merge
        positions = safe_pos[:, None]                     # (B, 1) for RoPE
        x, new_layers, _ = self._run_paged_layers(
            params, x, positions, cache["layers"], single_step=True,
            window_override=window_override, paged=paged)
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
        return self._logits(params, x), new_cache

    def verify_step(self, params: Params, cache: Dict[str, Any],
                    tokens: jax.Array, pos: jax.Array, n_fed: jax.Array, *,
                    page_size: int,
                    window_override: Optional[int] = None,
                    ) -> Tuple[jax.Array, Dict[str, Any]]:
        """Speculative multi-token verify against the paged cache.

        ``tokens`` (B, S) feeds each lane its last sampled token plus up
        to S - 1 draft tokens; ``pos`` (B,) is the absolute position of
        column 0 (the last token's write position, as in decode;
        ``pos[b] < 0`` marks an idle lane); ``n_fed`` (B,) is how many
        leading columns of the lane are real (1 = plain decode riding
        along, 1 + m = m draft tokens).  Columns past ``n_fed`` and idle
        lanes write to the scratch page and their logits are garbage the
        caller must ignore.

        Returns logits (B, S, vocab): column j scores position
        ``pos + j`` having seen exactly the context a sequential decode
        would have — the attention read at offset j uses
        ``kv_len = pos + 1 + j`` over rows this same call scattered —
        so ``argmax(logits[b, j])`` equals the token a non-speculative
        engine would emit after accepting the first j draft tokens.
        That identity is the byte-parity guarantee of ``--spec-decode``.
        """
        pos = jnp.asarray(pos, jnp.int32)                 # (B,)
        n_fed = jnp.asarray(n_fed, jnp.int32)             # (B,)
        safe_pos = jnp.maximum(pos, 0)
        x = jnp.take(params["embed"], tokens, axis=0)     # (B, S, d)
        bt = cache["block_tables"]
        B, S = tokens.shape
        offs = jnp.arange(S, dtype=jnp.int32)[None, :]    # (1, S)
        positions = safe_pos[:, None] + offs              # (B, S)
        phys = bt[jnp.arange(B)[:, None], positions // page_size] \
            * page_size + positions % page_size           # (B, S)
        # scratch-route the same lanes decode does (idle slots) PLUS the
        # columns past each lane's real feed — a lane drafting m < S - 1
        # tokens has no page grant (and no token) for the tail columns
        valid = (pos[:, None] >= 0) & (offs < n_fed[:, None])
        write_slots = jnp.where(valid, phys,
                                positions % page_size).reshape(B * S)
        kv_len = jnp.maximum(pos + 1, 0)
        paged = {"page_size": page_size, "write_slots": write_slots,
                 "block_tables": bt, "kv_len": kv_len, "verify": True}
        if self.paged_head_merge is not None:
            paged["head_merge"] = self.paged_head_merge
        x, new_layers, _ = self._run_paged_layers(
            params, x, positions, cache["layers"], single_step=False,
            window_override=window_override, paged=paged)
        new_cache = dict(cache)
        new_cache["layers"] = new_layers
        return self._logits(params, x), new_cache


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
