"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` drives every family (dense / moe / ssm / hybrid /
vlm / audio).  The per-layer structure is a tuple of *layer kinds*:

    "attn"   — self-attention + FFN (FFN is MLP or MoE per ``n_experts``)
    "xattn"  — cross-attention + FFN (VLM image layers, Whisper decoder
               handles cross-attention inside "attn" when
               ``is_encoder_decoder``)
    "rglru"  — Griffin/RecurrentGemma recurrent block + FFN
    "ssd"    — Mamba-2 SSD mixer block (no separate FFN)

If every layer has the same kind the stack is compiled as a
``lax.scan`` over stacked parameters (uniform mode — cheap to compile
even at 80 layers); otherwise layers are built individually (pattern
mode — used by RecurrentGemma's (R,R,A) pattern and Llama-3.2-Vision's
every-5th cross-attention layer).

Attention *metadata* (sliding window, RoPE base) is per-layer data, not
structure, so gemma3's 5-local:1-global pattern stays in uniform mode.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax.numpy as jnp


GLOBAL_ATTENTION = 0  # sentinel window size: full causal attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # -- attention ------------------------------------------------------
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen2
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0          # gemma3 global layers (1e6)
    sliding_window: int = GLOBAL_ATTENTION  # window for "local" layers
    local_global_pattern: Tuple[int, int] = (0, 0)  # (n_local, n_global) cycle
    attn_logit_softcap: float = 0.0

    # -- FFN / MoE -------------------------------------------------------
    act: str = "silu"              # silu | gelu
    n_experts: int = 0
    experts_per_token: int = 0
    moe_impl: str = "scatter"      # scatter | dense (reference)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # -- SSM (Mamba-2 SSD) ------------------------------------------------
    ssm_state: int = 0             # N — state size per head
    ssm_heads: int = 0             # H — SSD heads
    ssm_head_dim: int = 64         # P — channels per head
    ssm_groups: int = 1            # B/C projection groups
    ssm_chunk: int = 64            # SSD chunk length
    ssm_conv: int = 4              # depthwise conv width

    # -- hybrid (RG-LRU) ---------------------------------------------------
    lru_width: int = 0
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru","rglru","attn")

    # -- VLM ---------------------------------------------------------------
    cross_attn_every: int = 0      # every k-th layer is cross-attention
    n_image_tokens: int = 0        # stub vision embeddings per sample

    # -- audio / encoder-decoder --------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_audio_frames: int = 0        # stub frame embeddings per sample
    encoder_causal: bool = False

    # -- numerics / misc ------------------------------------------------------
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    remat: bool = False            # activation checkpointing per layer
    remat_save_gather: bool = True # keep post-Gather outputs (no psum
                                   # recompute in bwd; costs 2 saved
                                   # tensors/layer — EXPERIMENTS §Perf)
    # long-context decode handling: "native" (SSM/hybrid/sliding archs) or
    # "sliding_window" (full-attention archs run long_500k only under an
    # explicit window — DESIGN.md §Arch-applicability)
    long_context: str = "native"
    long_context_window: int = 16_384
    source: str = ""               # citation for the config

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        kinds = []
        for i in range(self.n_layers):
            if self.block_pattern:
                kinds.append(self.block_pattern[i % len(self.block_pattern)])
            elif self.arch_type == "ssm":
                kinds.append("ssd")
            elif (self.cross_attn_every
                  and (i + 1) % self.cross_attn_every == 0):
                kinds.append("xattn")
            else:
                kinds.append("attn")
        return tuple(kinds)

    @property
    def uniform(self) -> bool:
        kinds = self.layer_kinds
        return all(k == kinds[0] for k in kinds)

    def layer_windows(self, seq_len: int) -> Tuple[int, ...]:
        """Per-layer sliding window (0 = full/global) for decoder layers."""
        n_local, n_global = self.local_global_pattern
        out = []
        for i, kind in enumerate(self.layer_kinds):
            if kind not in ("attn", "xattn"):
                out.append(0)
                continue
            if n_local and n_global:
                cycle = n_local + n_global
                is_local = (i % cycle) < n_local
                out.append(self.sliding_window if is_local else 0)
            elif self.sliding_window:
                out.append(self.sliding_window)
            else:
                out.append(0)
        return tuple(out)

    def layer_thetas(self) -> Tuple[float, ...]:
        """Per-layer RoPE base (gemma3 uses 1e6 on global layers)."""
        out = []
        windows = self.layer_windows(0)
        for w in windows:
            if w == 0 and self.rope_theta_global:
                out.append(self.rope_theta_global)
            else:
                out.append(self.rope_theta)
        return tuple(out)

    # -- parameter counting (for roofline MODEL_FLOPS) -------------------
    def param_count(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        qdim, kvdim = self.n_heads * hd, self.n_kv_heads * hd
        attn = d * qdim + 2 * d * kvdim + qdim * d
        if self.qkv_bias:
            attn += qdim + 2 * kvdim
        n_mlp_mats = 3 if self.act == "silu" else 2
        mlp = n_mlp_mats * d * self.d_ff
        moe = self.n_experts * n_mlp_mats * d * self.d_ff + d * self.n_experts
        d_in = self.lru_width or d
        rglru = (2 * d * d_in + d_in * d            # branches + out
                 + self.ssm_conv * d_in + 3 * d_in)  # conv + gates/Lambda
        ssd_inner = (self.ssm_heads * self.ssm_head_dim) or 2 * d
        ssd = (d * (2 * ssd_inner + 2 * self.ssm_groups * self.ssm_state
                    + self.ssm_heads)
               + ssd_inner * d + 3 * self.ssm_heads
               + self.ssm_conv * (ssd_inner + 2 * self.ssm_groups
                                  * self.ssm_state))
        total = 0
        for kind in self.layer_kinds:
            if kind == "attn":
                total += attn + (moe if self.n_experts else mlp) + 2 * d
            elif kind == "xattn":
                total += attn + mlp + 3 * d
            elif kind == "rglru":
                total += rglru + mlp + 2 * d
            elif kind == "ssd":
                total += ssd + 2 * d
        if self.is_encoder_decoder:
            # encoder stack + decoder cross-attention
            total += self.n_encoder_layers * (attn + mlp + 2 * d)
            total += self.n_layers * (attn + d)
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top-k of the experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        n_mlp_mats = 3 if self.act == "silu" else 2
        moe_total = self.n_layers * self.n_experts * n_mlp_mats \
            * self.d_model * self.d_ff
        moe_active = self.n_layers * self.experts_per_token * n_mlp_mats \
            * self.d_model * self.d_ff
        return full - moe_total + moe_active
