"""Attention: blockwise (flash-style) pure-JAX implementation.

One code path serves train, prefill and decode across all assigned
architectures:

* **blockwise online softmax** over KV chunks (``lax.scan``) keeps the
  activation footprint O(S·chunk) instead of O(S²) — required for the
  32k/500k shapes to fit the dry-run memory analysis;
* **GQA** by folding the query-head group into the einsum;
* **sliding window / local-global** via per-layer window metadata
  (0 = full causal);
* **decode** is the same function with Sq=1 and ``kv_len`` masking —
  flash-decoding over the cache;
* **sequence-sharded decode** (long_500k, batch=1): each shard runs
  blockwise attention over its KV slice and returns (out, m, lsum); the
  partials merge with an LSE-weighted psum (``combine_partials``) —
  ArcLight's Gather, applied to the sequence axis (beyond-paper
  optimisation, DESIGN.md §5).

The Pallas kernel in ``repro.kernels.decode_attention`` implements the
same contract for the TPU hot path; ``repro.kernels.ref`` ties the two
together in tests.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


class AttnPartial(NamedTuple):
    """Un-normalised blockwise attention state (for cross-shard merge)."""

    out: jax.Array   # (B, Sq, Hq, D), fp32, = Σ exp(s - m) v
    m: jax.Array     # (B, Sq, Hq) running max
    lsum: jax.Array  # (B, Sq, Hq) running denominator


def _chunk_mask(qpos: jax.Array, kpos: jax.Array, *, causal: bool,
                window: jax.Array, kv_len: Optional[jax.Array],
                kpos_valid: Optional[jax.Array] = None) -> jax.Array:
    """(Sq, C) validity mask. window: scalar int32, 0 = unlimited."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    m &= (window <= 0) | (kpos[None, :] > qpos[:, None] - window)
    if kv_len is not None:
        m &= kpos[None, :] < kv_len
    if kpos_valid is not None:
        m &= kpos_valid[None, :]
    return m


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True,
    window: Any = 0,
    q_offset: Any = 0,
    kv_offset: Any = 0,
    kv_len: Optional[Any] = None,
    kv_positions: Optional[jax.Array] = None,
    chunk: int = 512,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    return_partial: bool = False,
) -> jax.Array | AttnPartial:
    """Blockwise attention.

    q (B,Sq,Hq,D); k,v (B,Skv,Hkv,D); Hq % Hkv == 0.
    ``q_offset``: absolute position of q[0] (decode: current length).
    ``kv_offset``: absolute position of k[0] (sequence-sharded caches).
    ``kv_len``: number of *globally* valid kv tokens (cache fill level).
    ``kv_positions``: explicit absolute position of every kv slot
    (ring-buffer caches); entries < 0 are masked invalid and override
    the ``kv_offset`` arithmetic.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not divisible by Hkv={Hkv}")
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    chunk = min(chunk, Skv)
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None and kv_positions is None:
            # positions are global: this shard's valid range ends at
            # kv_offset + Skv (not Skv — kv_offset > 0 for seq shards)
            kv_len = jnp.asarray(kv_offset) + Skv
        if kv_positions is not None:
            kv_positions = jnp.pad(kv_positions, (0, pad),
                                   constant_values=-1)
    qg = q.reshape(B, Sq, Hkv, G, D)
    qpos = jnp.asarray(q_offset) + jnp.arange(Sq)
    window = jnp.asarray(window, jnp.int32)
    kv_len_arr = None if kv_len is None else jnp.asarray(kv_len)
    pos_chunks = (None if kv_positions is None
                  else kv_positions.reshape(n_chunks, chunk))

    kc = k.reshape(B, n_chunks, chunk, Hkv, D)
    vc = v.reshape(B, n_chunks, chunk, Hkv, D)

    def body(carry, inputs):
        out, m, lsum = carry
        ci, kci, vci = inputs[:3]
        if pos_chunks is not None:
            kpos = inputs[3]
            kvalid = kpos >= 0
        else:
            kpos = jnp.asarray(kv_offset) + ci * chunk + jnp.arange(chunk)
            kvalid = None
        s = jnp.einsum("bqhgd,bchd->bqhgc", qg, kci,
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        mask = _chunk_mask(qpos, kpos, causal=causal, window=window,
                           kv_len=kv_len_arr, kpos_valid=kvalid)  # (Sq, C)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))      # (B,Sq,Hkv,G)
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = lsum * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhgc,bchd->bqhgd", p,
                        vci.astype(jnp.float32))
        out_new = out * alpha[..., None] + pv
        return (out_new, m_new, l_new), None

    out0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    m0 = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    xs = [jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0)]
    if pos_chunks is not None:
        xs.append(pos_chunks)
    (out, m, lsum), _ = jax.lax.scan(body, (out0, m0, l0), tuple(xs))

    out = out.reshape(B, Sq, Hq, D)
    m = m.reshape(B, Sq, Hq)
    lsum = lsum.reshape(B, Sq, Hq)
    if return_partial:
        return AttnPartial(out=out, m=m, lsum=lsum)
    safe_l = jnp.where(lsum > 0, lsum, 1.0)
    return (out / safe_l[..., None]).astype(q.dtype)


def combine_partials(p: AttnPartial, axis_name: str,
                     out_dtype: Any) -> jax.Array:
    """Merge per-shard blockwise partials across a mesh axis (the
    sequence-sharded flash-decoding Gather)."""
    m_glob = jax.lax.pmax(p.m, axis_name)
    w = jnp.exp(p.m - m_glob)
    num = jax.lax.psum(p.out * w[..., None], axis_name)
    den = jax.lax.psum(p.lsum * w, axis_name)
    den = jnp.where(den > 0, den, 1.0)
    return (num / den[..., None]).astype(out_dtype)


def attention_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        q_offset: int = 0, kv_len: Optional[int] = None,
                        softcap: float = 0.0,
                        scale: Optional[float] = None) -> jax.Array:
    """O(S²) dense oracle for tests."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None] > qpos[:, None] - window
    if kv_len is not None:
        mask &= kpos[None] < kv_len
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)
