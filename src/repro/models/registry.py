"""Model registry: config -> Model, plus reduced smoke variants."""

from __future__ import annotations

import dataclasses
from typing import Dict

from .config import ModelConfig
from .transformer import Model, build_model


def reduced_config(cfg: ModelConfig, *, n_layers: int = 2,
                   d_model: int = 128, vocab: int = 512) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests (≤2 layers,
    d_model ≤ 512, ≤4 experts)."""
    hd = max(d_model // max(cfg.n_heads, 1), 16)
    n_heads = max(min(cfg.n_heads, d_model // hd), 1)
    n_kv = max(min(cfg.n_kv_heads, n_heads), 1)
    while n_heads % n_kv:
        n_kv -= 1
    changes: Dict = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=hd,
        d_ff=d_model * 3,
        vocab_size=vocab,
    )
    if cfg.n_experts:
        changes.update(n_experts=min(cfg.n_experts, 4))
    if cfg.arch_type == "ssm":
        changes.update(ssm_heads=4, ssm_head_dim=32, ssm_state=16,
                       ssm_groups=1, ssm_chunk=8)
    if cfg.lru_width:
        changes.update(lru_width=d_model)
    if cfg.block_pattern:
        changes.update(n_layers=len(cfg.block_pattern))
    if cfg.cross_attn_every:
        # keep one cross-attention layer in the reduced stack
        changes.update(n_layers=cfg.cross_attn_every,
                       n_image_tokens=min(cfg.n_image_tokens, 16))
    if cfg.is_encoder_decoder:
        changes.update(n_encoder_layers=2,
                       n_audio_frames=min(cfg.n_audio_frames, 24))
    if cfg.sliding_window:
        changes.update(sliding_window=min(cfg.sliding_window, 16))
    return dataclasses.replace(cfg, **changes)


__all__ = ["Model", "build_model", "reduced_config"]
