"""repro.models — shardable JAX model zoo for the assigned architectures."""

from .config import GLOBAL_ATTENTION, ModelConfig
from .registry import Model, build_model, reduced_config

__all__ = ["GLOBAL_ATTENTION", "Model", "ModelConfig", "build_model",
           "reduced_config"]
