"""Mamba-2 SSD — state-space duality, chunked (arXiv:2405.21060).

The SSD recurrence per head:  h_t = exp(dt_t·A)·h_{t-1} + dt_t·B_t x_tᵀ,
y_t = C_t·h_t + D·x_t, with scalar A per head (A < 0), B/C shared over
head groups.  Training/prefill uses the chunked dual form (intra-chunk
quadratic attention-like term + inter-chunk state passing); decode is
the O(1) recurrent update on the (B, H, P, N) state.

The chunk scan is a ``lax.scan`` over chunk states — on the mesh the
sequence stays whole per device (ArcLight's technique applies to the
projections, not the scan; DESIGN.md §Arch-applicability), while heads/
channels shard over ``model``.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Params, dense_init


class SSDState(NamedTuple):
    state: jax.Array   # (B, H, P, N) recurrent state
    conv: jax.Array    # (B, W-1, conv_channels) causal-conv tail


def init_ssd(key: jax.Array, d_model: int, *, n_heads: int, head_dim: int,
             d_state: int, n_groups: int, conv_width: int,
             dtype: Any) -> Params:
    d_inner = n_heads * head_dim
    conv_ch = d_inner + 2 * n_groups * d_state
    ks = jax.random.split(key, 6)
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "in_proj": dense_init(
            ks[0], d_model,
            2 * d_inner + 2 * n_groups * d_state + n_heads, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_width, conv_ch),
                                     jnp.float32)
                   / math.sqrt(conv_width)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads,
                                      dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(
                ks[3], (n_heads,), jnp.float32,
                math.log(1e-3), math.log(1e-1))))),
        "norm_gain": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[4], d_inner, d_model, dtype),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """x (..., L) -> (..., L, L) with out[i,j] = sum x[j+1..i], -inf j>i."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 tail: Optional[jax.Array] = None,
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x (B,T,C), w (W,C). Returns (y, new_tail)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)             # (B, T+W-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_tail = xp[:, -(W - 1):] if W > 1 else tail
    return jax.nn.silu(y + b), new_tail


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, *, chunk: int,
                initial_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x (B,T,H,P); dt (B,T,H) (post-softplus); A (H,) negative;
    Bm, Cm (B,T,G,N) with H % G == 0.  Returns (y (B,T,H,P),
    final_state (B,H,P,N)).
    """
    Bsz, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)                    # (B,T,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)
    pad = (-T) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Tp = T + pad
    nC = Tp // chunk

    def to_chunks(a):
        return a.reshape(Bsz, nC, chunk, *a.shape[2:])

    xc = to_chunks(x * dt[..., None].astype(x.dtype))   # u = dt * x
    dAc = to_chunks(dt) * A[None, None, None, :]        # (B,c,l,H) log-decay
    Bc, Cc = to_chunks(Bh), to_chunks(Ch)

    dAc_t = jnp.moveaxis(dAc, -1, 2)                    # (B,c,H,l)
    A_cum = jnp.cumsum(dAc_t, axis=-1)                  # (B,c,H,l)

    # intra-chunk (diagonal blocks): Y_diag = (L ∘ C Bᵀ) u
    L = jnp.exp(_segsum(dAc_t))                         # (B,c,H,l,l)
    scores = jnp.einsum("bclhn,bcshn->bchls", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    y_diag = jnp.einsum("bchls,bchls,bcshp->bclhp", scores, L,
                        xc.astype(jnp.float32))

    # chunk-final states: S_c = Σ_s exp(A_cum_last - A_cum_s) B_s u_sᵀ
    decay = jnp.exp(A_cum[..., -1:] - A_cum)            # (B,c,H,l)
    states = jnp.einsum("bchl,bclhn,bclhp->bchpn", decay,
                        Bc.astype(jnp.float32), xc.astype(jnp.float32))

    # inter-chunk recurrence over c
    chunk_decay = jnp.exp(A_cum[..., -1])               # (B,c,H)
    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))

    def scan_fn(carry, inp):
        dec, st = inp                                   # (B,H), (B,H,P,N)
        new = carry * dec[..., None, None] + st
        return new, carry                               # emit state *before* chunk

    final, prev_states = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (B,c,H,P,N)

    # inter-chunk contribution: Y_off = C_t · exp(A_cum_t) · S_{c-1}
    state_decay = jnp.exp(A_cum)                        # (B,c,H,l)
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp",
                       Cc.astype(jnp.float32), prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, Tp, H, P)[:, :T]
    return y.astype(x.dtype), final


def ssd_reference(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                  Cm: jax.Array,
                  initial_state: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Step-by-step recurrent oracle (slow, for tests)."""
    Bsz, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    h = (jnp.zeros((Bsz, H, P, N), jnp.float32)
         if initial_state is None else initial_state.astype(jnp.float32))
    ys = []
    for t in range(T):
        dA = jnp.exp(dt[:, t] * A[None, :])             # (B,H)
        u = (x[:, t] * dt[:, t][..., None]).astype(jnp.float32)
        h = h * dA[..., None, None] + jnp.einsum("bhp,bhn->bhpn", u, Bh[:, t])
        ys.append(jnp.einsum("bhpn,bhn->bhp", h, Ch[:, t]))
    return jnp.stack(ys, axis=1).astype(x.dtype), h


def ssd_decode_step(state: jax.Array, x_t: jax.Array, dt_t: jax.Array,
                    A: jax.Array, B_t: jax.Array, C_t: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence.  x_t (B,H,P), dt_t (B,H), B_t/C_t (B,G,N)."""
    H = x_t.shape[1]
    rep = H // B_t.shape[1]
    Bh = jnp.repeat(B_t, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C_t, rep, axis=1).astype(jnp.float32)
    dA = jnp.exp(dt_t * A[None, :])
    u = (x_t * dt_t[..., None]).astype(jnp.float32)
    new_state = (state * dA[..., None, None]
                 + jnp.einsum("bhp,bhn->bhpn", u, Bh))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x_t.dtype), new_state


# ----------------------------------------------------------------------
# full block (proj -> conv -> SSD -> gate -> out proj)
# ----------------------------------------------------------------------

def _split_proj(proj: jax.Array, *, d_inner: int, n_groups: int,
                d_state: int, n_heads: int):
    sizes = [d_inner, d_inner, n_groups * d_state, n_groups * d_state,
             n_heads]
    idx = [sum(sizes[:i + 1]) for i in range(len(sizes) - 1)]
    return jnp.split(proj, idx, axis=-1)


def ssd_block(params: Params, x: jax.Array, *, n_heads: int, head_dim: int,
              d_state: int, n_groups: int, chunk: int,
              state: Optional[SSDState] = None,
              ) -> Tuple[jax.Array, SSDState]:
    """Full Mamba-2 block on (B, T, d_model).  Returns (y, new_state)."""
    from .common import rms_norm

    Bsz, T, _ = x.shape
    d_inner = n_heads * head_dim
    proj = x @ params["in_proj"]
    z, xs, Bf, Cf, dt = _split_proj(proj, d_inner=d_inner,
                                    n_groups=n_groups, d_state=d_state,
                                    n_heads=n_heads)
    conv_in = jnp.concatenate([xs, Bf, Cf], axis=-1)
    tail = state.conv if state is not None else None
    conv_out, new_tail = _causal_conv(conv_in, params["conv_w"],
                                      params["conv_b"], tail)
    xs, Bf, Cf = jnp.split(
        conv_out, [d_inner, d_inner + n_groups * d_state], axis=-1)
    xh = xs.reshape(Bsz, T, n_heads, head_dim)
    Bm = Bf.reshape(Bsz, T, n_groups, d_state)
    Cm = Cf.reshape(Bsz, T, n_groups, d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    prev = state.state if state is not None else None
    y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, chunk=chunk,
                                 initial_state=prev)
    y = y + params["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(Bsz, T, d_inner)
    y = rms_norm(y, params["norm_gain"]) * jax.nn.silu(z)
    return y @ params["out_proj"], SSDState(state=final_state,
                                            conv=new_tail)
