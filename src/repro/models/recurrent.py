"""RG-LRU recurrent block — RecurrentGemma / Griffin (arXiv:2402.19427).

Block structure (Griffin "recurrent block"):

    x ── linear ─ GeLU ──────────────┐
    x ── linear ─ causal conv1d(4) ─ RG-LRU ─┤ ⊙ ── linear ─ out

RG-LRU recurrence (per channel):
    r_t = σ(W_a x_t + b_a)            recurrence gate
    i_t = σ(W_x x_t + b_x)            input gate
    a_t = a^(c·r_t),  a = σ(Λ), c = 8
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Computed with an associative scan over T (prefill/train) or a one-step
update (decode).  The recurrence width shards over the ``model`` axis
(channel-wise — the technique's row-partitioning applied to the
recurrence; DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Params, dense_init
from .ssm import _causal_conv


_C = 8.0  # Griffin's fixed exponent scale


class RGLRUState(NamedTuple):
    h: jax.Array      # (B, W) recurrent state (fp32)
    conv: jax.Array   # (B, conv_width-1, W) conv tail


def init_rglru_block(key: jax.Array, d_model: int, width: int,
                     conv_width: int, dtype: Any) -> Params:
    ks = jax.random.split(key, 7)
    # Λ init so that a = σ(Λ) ∈ (0.9, 0.999) — Griffin's init
    u = jax.random.uniform(ks[5], (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1 / _C) / (1 - u ** (1 / _C)))
    return {
        "w_y": dense_init(ks[0], d_model, width, dtype),      # GeLU branch
        "w_x": dense_init(ks[1], d_model, width, dtype),      # recurrent branch
        "conv_w": (jax.random.normal(ks[2], (conv_width, width), jnp.float32)
                   / math.sqrt(conv_width)).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "w_a": dense_init(ks[3], width, width, dtype),        # recurrence gate
        "b_a": jnp.zeros((width,), dtype),
        "w_i": dense_init(ks[4], width, width, dtype),        # input gate
        "b_i": jnp.zeros((width,), dtype),
        "Lambda": lam,
        "w_out": dense_init(ks[6], width, d_model, dtype),
    }


def _gates(params: Params, x: jax.Array):
    """log(a_t) and scaled input; x (B,T,W) or (B,W)."""
    r = jax.nn.sigmoid((x @ params["w_a"] + params["b_a"])
                       .astype(jnp.float32))
    i = jax.nn.sigmoid((x @ params["w_i"] + params["b_i"])
                       .astype(jnp.float32))
    log_a_base = jax.nn.log_sigmoid(params["Lambda"])          # (W,)
    log_a = _C * r * log_a_base                                # ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12))
    u = beta * (i * x.astype(jnp.float32))
    return a, u


def rglru_scan(params: Params, x: jax.Array,
               h0: Optional[jax.Array] = None,
               ) -> Tuple[jax.Array, jax.Array]:
    """Associative-scan RG-LRU over (B, T, W). Returns (y, h_T)."""
    B, T, W = x.shape
    a, u = _gates(params, x)                                    # fp32
    if h0 is not None:
        # fold the carried state into the first step:
        # h_1 = a_1 h_0 + u_1  ->  u_1 += a_1 * h_0
        u = u.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, u), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(params: Params, x_t: jax.Array, h: jax.Array,
               ) -> Tuple[jax.Array, jax.Array]:
    """One decode step. x_t (B, W), h (B, W) fp32."""
    a, u = _gates(params, x_t)
    h_new = a * h.astype(jnp.float32) + u
    return h_new.astype(x_t.dtype), h_new


def rglru_block(params: Params, x: jax.Array, *,
                state: Optional[RGLRUState] = None,
                single_step: bool = False,
                ) -> Tuple[jax.Array, RGLRUState]:
    """Full Griffin recurrent block on (B, T, d_model)."""
    y_branch = jax.nn.gelu(x @ params["w_y"])
    r = x @ params["w_x"]
    tail = state.conv if state is not None else None
    r, new_tail = _causal_conv(r, params["conv_w"], params["conv_b"], tail)
    h0 = state.h if state is not None else None
    if single_step:
        out_t, h_new = rglru_step(params, r[:, 0],
                                  h0 if h0 is not None
                                  else jnp.zeros(r[:, 0].shape, jnp.float32))
        rec = out_t[:, None]
    else:
        rec, h_new = rglru_scan(params, r, h0)
    y = (y_branch * rec) @ params["w_out"]
    return y, RGLRUState(h=h_new, conv=new_tail)
