"""Shared layers: norms, RoPE, MLP, initializers.

Weight layout convention (matches ``core.tp.PartitionPlan``): every
projection is stored ``(d_in, d_out)`` and applied as ``x @ w``.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp


Params = Dict[str, Any]


# ----------------------------------------------------------------------
# initializers
# ----------------------------------------------------------------------

def dense_init(key: jax.Array, d_in: int, d_out: int,
               dtype: Any) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d: int, dtype: Any) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------

def rms_norm(x: jax.Array, gain: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)).astype(dt)
            * (1.0 + gain.astype(dt)))


def layer_norm(x: jax.Array, gain: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * gain.astype(dt) + bias.astype(dt)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,D/2)
    sin = jnp.sin(angles)[..., None, :]                # (...,S,1,D/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------

def init_mlp(key: jax.Array, d: int, f: int, act: str, dtype: Any) -> Params:
    ks = jax.random.split(key, 3)
    if act == "silu":
        return {"w_gate": dense_init(ks[0], d, f, dtype),
                "w_up": dense_init(ks[1], d, f, dtype),
                "w_down": dense_init(ks[2], f, d, dtype)}
    return {"w_up": dense_init(ks[0], d, f, dtype),
            "w_down": dense_init(ks[1], f, d, dtype)}


def proj(x: jax.Array, w: Any, qmm=None) -> jax.Array:
    """``x @ w`` with an optional quantized-matmul hook: the serving
    runner's Q4_0 mode passes ``qmm`` (``repro.quant.policy.make_qmm``)
    so projection leaves may be packed-code subtrees instead of dense
    arrays; every other path leaves ``qmm=None`` and pays nothing."""
    return x @ w if qmm is None else qmm(x, w)


def mlp(params: Params, x: jax.Array, act: str, qmm=None) -> jax.Array:
    if act == "silu":
        h = jax.nn.silu(proj(x, params["w_gate"], qmm)) \
            * proj(x, params["w_up"], qmm)
    else:
        h = jax.nn.gelu(proj(x, params["w_up"], qmm))
    return proj(h, params["w_down"], qmm)


# ----------------------------------------------------------------------
# logits
# ----------------------------------------------------------------------

def unembed(embed_or_head: jax.Array, x: jax.Array, *,
            tied: bool) -> jax.Array:
    if tied:
        return x @ embed_or_head.T
    return x @ embed_or_head


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  ignore_id: int = -100) -> jax.Array:
    """Mean token-level cross entropy, fp32, with label masking."""
    lf = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(
        lf, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
