"""Roofline analysis (deliverable g).

Three terms per (arch × shape × mesh), derived from the compiled
dry-run artifact — no wall clock on this CPU-only container:

    compute    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
    memory     = HLO_bytes / (chips × 819 GB/s HBM)
    collective = collective_bytes / (chips × 50 GB/s ICI)

``compiled.cost_analysis()`` and the HLO text describe the PER-DEVICE
SPMD program, so HLO_FLOPs/HLO_bytes/collective_bytes are already the
per-chip share — the formulas above reduce to per-device value ÷
per-chip rate (the ``chips ×`` in the denominator cancels against the
implicit ``÷ chips`` in the numerator).  Collective bytes are parsed
from the HLO text (all-gather, all-reduce, reduce-scatter, all-to-all,
collective-permute — summed over output operand sizes).
MODEL_FLOPS = 6·N·D training (N = active params for MoE), 2·N·D for
forward-only inference steps; the useful-flops ratio compares it to the
global ``HLO_FLOPs × chips``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional

from ..models.config import ModelConfig
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")

# `%x.1 = bf16[8,128]{1,0} all-gather(...)` — possibly a tuple type
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-operand bytes of every collective op in the HLO."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)", s)
        if not m:
            continue
        rhs = m.group(1)
        for coll in _COLLECTIVES:
            # match the op name as the instruction (not in metadata)
            if re.search(rf"\)?\s{coll}(-start|-done)?\(", " " + rhs):
                type_part = rhs.split(coll)[0]
                out[coll] = out.get(coll, 0) + _shape_bytes(type_part)
                break
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                 # per-device
    hlo_bytes: float                 # per-device
    coll_bytes: float                # per-device
    coll_breakdown: Dict[str, int]
    model_flops: float               # global
    t_compute: float
    t_memory: float
    t_collective: float
    bytes_per_device: Optional[float] = None
    note: str = ""

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["useful_flops_ratio"] = self.useful_flops_ratio
        return d


def model_flops_for(cfg: ModelConfig, *, kind: str, tokens: int) -> float:
    """MODEL_FLOPS: 6·N·D training, 2·N·D forward-only (prefill/decode)."""
    n_active = cfg.active_param_count()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens


def analyse(compiled, *, cfg: ModelConfig, arch: str, shape_name: str,
            mesh_name: str, chips: int, kind: str, tokens: int,
            hlo_text: Optional[str] = None) -> RooflineReport:
    from .hlo_cost import analyse_hlo

    text = hlo_text if hlo_text is not None else compiled.as_text()
    # while-trip-adjusted per-device costs: XLA:CPU's cost_analysis
    # counts scan bodies once (see hlo_cost docstring), so FLOPs and
    # collective bytes come from the HLO walk instead.
    walked = analyse_hlo(text)
    flops = walked.flops
    coll = {k: int(v) for k, v in walked.coll_breakdown.items()}
    total_coll = float(walked.coll_bytes)

    # memory term: artifact byte footprint per step — every argument
    # (params/cache/batch), output and temp byte crosses HBM >= once.
    mem = None
    byts = 0.0
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            byts = float(getattr(ma, "argument_size_in_bytes", 0)
                         + getattr(ma, "output_size_in_bytes", 0)
                         + getattr(ma, "temp_size_in_bytes", 0))
            mem = byts
    except Exception:
        pass
    # NOTE: raw cost_analysis 'bytes accessed' is NOT used for the
    # memory term — it counts pre-fusion operand bytes and misses scan
    # trip counts, so it is inconsistent between scanned (uniform) and
    # unrolled (pattern) archs.  The artifact sizes above are uniform.

    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, coll_bytes=total_coll,
        coll_breakdown=coll,
        model_flops=model_flops_for(cfg, kind=kind, tokens=tokens),
        # per-device numerators -> divide by per-chip rates
        t_compute=flops / PEAK_FLOPS_BF16,
        t_memory=byts / HBM_BW,
        t_collective=total_coll / ICI_BW,
        bytes_per_device=mem)


def format_table(reports: List[RooflineReport]) -> str:
    hdr = (f"| {'arch':22s} | {'shape':11s} | {'mesh':9s} | "
           f"{'compute_s':>10s} | {'memory_s':>10s} | {'coll_s':>10s} | "
           f"{'dominant':10s} | {'useful':>6s} | {'GiB/dev':>8s} |")
    sep = "|" + "|".join("-" * (len(c) + 2)
                         for c in hdr.split("|")[1:-1]) + "|"
    rows = [hdr, sep]
    for r in reports:
        gib = (f"{r.bytes_per_device / 2**30:8.2f}"
               if r.bytes_per_device else "     n/a")
        rows.append(
            f"| {r.arch:22s} | {r.shape:11s} | {r.mesh:9s} | "
            f"{r.t_compute:10.3e} | {r.t_memory:10.3e} | "
            f"{r.t_collective:10.3e} | {r.dominant:10s} | "
            f"{r.useful_flops_ratio:6.2f} | {gib} |")
    return "\n".join(rows)
