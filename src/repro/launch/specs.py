"""Dry-run case builder: step function + abstract inputs + shardings.

``build_case(arch, shape, mesh)`` assembles, WITHOUT allocating
anything (ShapeDtypeStruct only):

    train_4k     -> train_step(params, opt_state, batch)
    prefill_32k  -> prefill_step(params, batch, cache)
    decode_32k   -> serve_step(params, cache, tokens, pos)
    long_500k    -> serve_step with a seq-sharded / windowed cache

plus the in/out shardings from the §3.2 partition plan.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_config
from ..models import build_model
from ..models.config import ModelConfig
from ..training.loop import make_train_step
from ..training.optimizer import AdamWConfig, AdamWState, adamw_init
from . import shardings as shd
from .shardings import Policy


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ModelConfig, batch: int, seq: int,
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    out = {"tokens": _sds((batch, seq), jnp.int32)}
    out["labels"] = _sds((batch, seq), jnp.int32)
    if cfg.is_encoder_decoder:
        out["frames"] = _sds((batch, cfg.n_audio_frames, cfg.d_model),
                             cfg.dtype)
    if cfg.cross_attn_every:
        out["image_embeds"] = _sds((batch, cfg.n_image_tokens, cfg.d_model),
                                   cfg.dtype)
    return out


def _memory_len(cfg: ModelConfig) -> int:
    if cfg.is_encoder_decoder:
        return cfg.n_audio_frames
    if cfg.cross_attn_every:
        return cfg.n_image_tokens
    return 0


def decode_cache_plan(cfg: ModelConfig, seq_len: int,
                      ) -> Tuple[int, Optional[int], str]:
    """(cache_len, window_override, note) for a decode shape."""
    windows = [w for k, w in zip(cfg.layer_kinds,
                                 cfg.layer_windows(seq_len))
               if k in ("attn", "xattn")]
    has_global = any(w == 0 for w in windows)
    if seq_len > 65_536 and cfg.long_context == "sliding_window":
        w = cfg.long_context_window
        return w, w, "SW"  # flagged sliding-window variant (DESIGN.md §4)
    if windows and not has_global:
        return min(max(windows), seq_len), None, "native-window"
    return seq_len, None, "native"


@dataclasses.dataclass
class DryRunCase:
    arch: str
    shape_name: str
    kind: str
    tokens: int                      # tokens processed per step
    cfg: ModelConfig
    step_fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    note: str = ""


def _opt_shardings(params_sh, mesh: Mesh) -> AdamWState:
    return AdamWState(step=shd.replicated(mesh), m=params_sh, v=params_sh)


def build_case(arch: str, shape_name: str, mesh: Mesh,
               policy: Optional[Policy] = None,
               cfg_override: Optional[ModelConfig] = None) -> DryRunCase:
    policy = policy or Policy()
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train" and not cfg.remat:
        # every production-size train step needs per-layer remat
        cfg = dataclasses.replace(cfg, remat=True)
    if shape.kind == "prefill" and policy.head_aligned:
        # prefill is compute-bound: replicated-attention redundancy
        # costs more than the head-split gathers (EXPERIMENTS W1/W2)
        policy = dataclasses.replace(policy, head_aligned=False)
    if shape.kind != "train" and policy.fsdp:
        # FSDP exists to shard optimizer state; for inference it only
        # adds a per-layer weight all-gather every token (measured:
        # 22.9 GB/step on qwen2 decode_32k) — params at bf16/16-way TP
        # always fit without it
        policy = dataclasses.replace(policy, fsdp=False)
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    # sharding hooks: FSDP weight-unshard per layer + activation batch pin
    model.param_constraint = shd.make_layer_constraint(cfg, mesh, policy)
    model.act_constraint = shd.make_activation_constraint(mesh,
                                                          batch_size=B)
    model.moe_hook = shd.make_moe_hook(cfg, mesh, policy, batch_size=B)
    if policy.head_aligned and cfg.n_heads % mesh.shape.get("model", 1):
        # replicated-attention archs: stop GSPMD re-partitioning the
        # attention contraction over the idle model axis
        model.attn_act_constraint = model.act_constraint

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    params_sh = shd.params_shardings(cfg, params_shapes, mesh, policy)
    repl = shd.replicated(mesh)
    dp = shd.batch_shardings(cfg, {"x": _sds((B, 1), jnp.int32)}, mesh,
                             batch_size=B)["x"].spec
    # vocab axis of the logits shards over "model" only when divisible
    vocab_ax = ("model" if cfg.vocab_size % mesh.shape.get("model", 1) == 0
                else None)

    if shape.kind == "train":
        batch = batch_specs(cfg, B, S)
        batch_sh = shd.batch_shardings(cfg, batch, mesh, batch_size=B)
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        opt_sh = _opt_shardings(params_sh, mesh)
        step = make_train_step(model, AdamWConfig(),
                               microbatches=policy.microbatches)
        metrics_sh = {k: repl for k in
                      ("ce", "aux", "lr", "grad_norm", "loss")}
        return DryRunCase(
            arch=arch, shape_name=shape_name, kind="train",
            tokens=B * S, cfg=cfg, step_fn=step,
            args=(params_shapes, opt_shapes, batch),
            in_shardings=(params_sh, opt_sh, batch_sh),
            out_shardings=(params_sh, opt_sh, metrics_sh))

    if shape.kind == "prefill":
        batch = batch_specs(cfg, B, S)
        batch.pop("labels")
        batch_sh = shd.batch_shardings(cfg, batch, mesh, batch_size=B)
        cache_shapes = jax.eval_shape(
            functools.partial(model.init_cache, B, S,
                              memory_len=_memory_len(cfg)))
        cache_sh = shd.cache_shardings(cfg, cache_shapes, mesh, policy,
                                       batch_size=B, long_context=False)
        logits_sh = NamedSharding(mesh, P(dp[0] if dp else None, None,
                                          vocab_ax))

        def prefill_step(params, batch_, cache):
            return model.prefill(params, batch_, cache)

        return DryRunCase(
            arch=arch, shape_name=shape_name, kind="prefill",
            tokens=B * S, cfg=cfg, step_fn=prefill_step,
            args=(params_shapes, batch, cache_shapes),
            in_shardings=(params_sh, batch_sh, cache_sh),
            out_shardings=(logits_sh, cache_sh))

    # decode
    cache_len, window_override, note = decode_cache_plan(cfg, S)
    long_ctx = shape_name == "long_500k"
    hook = shd.make_decode_attn_hook(cfg, mesh, policy, batch_size=B,
                                     cache_len=cache_len)
    if hook is not None:
        model.decode_attn_hook = hook
        note_extra = "+seqshard"
    else:
        note_extra = ""
    cache_shapes = jax.eval_shape(
        functools.partial(model.init_cache, B, S, cache_len=cache_len,
                          memory_len=_memory_len(cfg)))
    cache_sh = shd.cache_shardings(cfg, cache_shapes, mesh, policy,
                                   batch_size=B, long_context=long_ctx)
    tokens_spec = _sds((B, 1), jnp.int32)
    tokens_sh = NamedSharding(mesh, P(dp[0] if dp else None, None))
    logits_sh = NamedSharding(mesh, P(dp[0] if dp else None, None,
                                      vocab_ax))

    def constrain_cache(c):
        return jax.tree.map(jax.lax.with_sharding_constraint, c, cache_sh)
    model.cache_constraint = constrain_cache

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos,
                                 window_override=window_override)

    return DryRunCase(
        arch=arch, shape_name=shape_name, kind="decode",
        tokens=B, cfg=cfg, step_fn=serve_step,
        args=(params_shapes, cache_shapes, tokens_spec,
              _sds((), jnp.int32)),
        in_shardings=(params_sh, cache_sh, tokens_sh, repl),
        out_shardings=(logits_sh, cache_sh),
        note=note + note_extra)
