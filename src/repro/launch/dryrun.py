import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

DOC = """Multi-pod dry-run (deliverable e) — the two lines above MUST
run before any jax import (jax locks the device count on first init).

For every (architecture × input shape × mesh):

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...) \
                      .lower(*input_specs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

Usage:
    python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import json
import sys
import time
import traceback
from typing import Optional

import jax

from ..configs import SHAPES, list_archs
from .mesh import CHIPS_PER_POD, make_production_mesh
from .roofline import analyse
from .shardings import Policy
from .specs import build_case


def run_case(arch: str, shape: str, *, multi_pod: bool = False,
             policy: Optional[Policy] = None, verbose: bool = True,
             save_hlo: Optional[str] = None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else CHIPS_PER_POD
    t0 = time.time()
    case = build_case(arch, shape, mesh, policy=policy)
    with mesh:
        jitted = jax.jit(case.step_fn,
                         in_shardings=case.in_shardings,
                         out_shardings=case.out_shardings)
        lowered = jitted.lower(*case.args)
        compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per program
        cost = cost[0] if cost else {}
    if verbose:
        print(f"== {arch} x {shape} x {mesh_name} "
              f"(compile {t_compile:.1f}s, note={case.note or '-'})")
        print(f"   memory_analysis: {mem}")
        print("   cost_analysis: flops={:.3e} bytes={:.3e}".format(
            float((cost or {}).get('flops', 0)),
            float((cost or {}).get('bytes accessed', 0))))
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    report = analyse(compiled, cfg=case.cfg, arch=arch, shape_name=shape,
                     mesh_name=mesh_name, chips=chips, kind=case.kind,
                     tokens=case.tokens, hlo_text=hlo)
    report.note = case.note
    d = report.to_dict()
    d["compile_s"] = t_compile
    if verbose:
        print(f"   roofline: compute={report.t_compute:.3e}s "
              f"memory={report.t_memory:.3e}s "
              f"collective={report.t_collective:.3e}s "
              f"dominant={report.dominant} "
              f"useful={report.useful_flops_ratio:.2f}")
        print(f"   collectives: {report.coll_breakdown}")
    return d


def main() -> int:
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) baseline")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh")
    ap.add_argument("--out", default=None, help="JSON output path")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    policy = Policy(fsdp=not args.no_fsdp,
                    expert_parallel=args.expert_parallel,
                    seq_shard_cache=not args.no_seq_shard)

    results = []
    failures = []
    if args.all:
        pairs = [(a, s) for a in list_archs() for s in SHAPES]
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape required unless --all")
        pairs = [(args.arch, args.shape)]

    for arch, shape in pairs:
        try:
            results.append(run_case(arch, shape, multi_pod=args.multi_pod,
                                    policy=policy,
                                    save_hlo=args.save_hlo))
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            traceback.print_exc()
            failures.append({"arch": arch, "shape": shape,
                             "error": f"{type(e).__name__}: {e}"})
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results, "failures": failures}, f,
                      indent=1)
        print(f"wrote {args.out}")
    print(f"\n{len(results)} ok, {len(failures)} failed")
    for f_ in failures:
        print("  FAIL", f_["arch"], f_["shape"], f_["error"][:200])
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
