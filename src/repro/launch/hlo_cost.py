"""While-aware HLO cost model.

XLA:CPU's ``compiled.cost_analysis()`` counts a while-loop body ONCE
(verified empirically — a 10-trip scan of a 128³ matmul reports 1/10 of
the true FLOPs), which silently zeroes out everything inside a
``lax.scan`` — i.e. the entire layer stack of every uniform arch.  This
module re-derives per-device costs by walking the optimized HLO text:

* dot FLOPs    = 2 × numel(output) × prod(contracted lhs dims)
* collective bytes = output bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute
* each computation's children (fusion ``calls=``, ``to_apply=``,
  while ``body=``/``condition=``, conditional branches) are resolved
  recursively; while bodies multiply by ``backend_config
  known_trip_count`` (the scan length).

The result feeds §Roofline's compute and collective terms; the memory
term uses the artifact's ``memory_analysis()`` sizes (argument + output
+ temp — every parameter/cache byte crosses HBM at least once per
step).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple


_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")


def _parse_shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype,
                        [int(d) for d in dims.split(",")] if dims else []))
    return out


def _numel(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _shape_bytes(type_str: str) -> int:
    return sum(_numel(dims) * _DTYPE_BYTES[dt]
               for dt, dims in _parse_shapes(type_str))


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    children: List[Tuple[str, float]] = dataclasses.field(
        default_factory=list)  # (computation name, multiplier)


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _trip_count(rhs: str) -> float:
    m = re.search(r'known_trip_count[\\"{:\s]+n[\\"\s:]+(\d+)', rhs)
    if m:
        return float(m.group(1))
    return 1.0


def _local_cost(lines: List[str]) -> CompCost:
    cost = CompCost()
    shapes: Dict[str, str] = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        type_part = rhs.split(" ", 1)[0]
        shapes[name] = rhs[: rhs.find(")") + 1] if "(" not in type_part \
            else type_part
        # keep the full type prefix (up to the op name) for byte parsing
    # second pass with operand shapes known
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        op_m = re.match(r"((?:[a-z0-9]+\[[\d,]*\]\{[\d,]*\}|"
                        r"[a-z0-9]+\[[\d,]*\]|\([^)]*\))\s+)+?"
                        r"([a-z][\w\-]*)\(", rhs)
        if not op_m:
            continue
        opname = op_m.group(2)
        type_prefix = rhs[: op_m.start(2)]

        if opname == "dot":
            out_shapes = _parse_shapes(type_prefix)
            if not out_shapes:
                continue
            out_numel = _numel(out_shapes[0][1])
            cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            # HLO dumps either inline the operand type
            # (``dot(f32[64,64]{1,0} %x, ...)``) or just name it
            # (``dot(%x, ...)``) — prefer the inline shape, fall back to
            # the definition table
            dims: List[int] = []
            inline = re.search(r"dot\(\s*([a-z0-9]+\[[\d,]*\])", rhs)
            if inline:
                ps = _parse_shapes(inline.group(1))
                if ps:
                    dims = ps[0][1]
            if not dims:
                lhs_m = re.search(
                    r"dot\(\s*(?:[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?\s+)?"
                    r"%([\w.\-]+)", rhs)
                if lhs_m and lhs_m.group(1) in shapes:
                    lhs_shapes = _parse_shapes(shapes[lhs_m.group(1)])
                    if lhs_shapes:
                        dims = lhs_shapes[0][1]
            contracted = 1
            if cd and dims:
                for idx in cd.group(1).split(","):
                    if idx and int(idx) < len(dims):
                        contracted *= dims[int(idx)]
            cost.flops += 2.0 * out_numel * contracted
        elif opname in ("convolution",):
            # rough: 2 * out_numel * (kernel numel / out_channels)
            out_shapes = _parse_shapes(type_prefix)
            if out_shapes:
                cost.flops += 2.0 * _numel(out_shapes[0][1])
        elif any(opname.startswith(c) for c in _COLLECTIVES):
            base = next(c for c in _COLLECTIVES if opname.startswith(c))
            if opname.endswith("-done"):
                continue  # counted at -start
            b = _shape_bytes(type_prefix)
            cost.coll_bytes += b
            cost.coll_breakdown[base] = (
                cost.coll_breakdown.get(base, 0.0) + b)

        if opname == "while":
            body = re.search(r"body=%?([\w.\-]+)", rhs)
            trips = _trip_count(rhs)
            if body:
                cost.children.append((body.group(1), trips))
            cond = re.search(r"condition=%?([\w.\-]+)", rhs)
            if cond:
                cost.children.append((cond.group(1), trips))
        elif opname in ("fusion", "call", "custom-call", "reduce",
                        "map", "sort", "scatter", "select-and-scatter",
                        "reduce-window", "all-reduce", "reduce-scatter"):
            for cm in re.finditer(
                    r"(?:calls|to_apply)=%?([\w.\-]+)", rhs):
                cost.children.append((cm.group(1), 1.0))
        elif opname == "conditional":
            for cm in re.finditer(
                    r"(?:branch_computations=\{([^}]*)\}|"
                    r"(?:true|false)_computation=%?([\w.\-]+))", rhs):
                names = cm.group(1) or cm.group(2) or ""
                for nm in re.split(r"[,\s]+", names):
                    nm = nm.strip().lstrip("%")
                    if nm:
                        cost.children.append((nm, 1.0))
    return cost


@dataclasses.dataclass
class HloCost:
    flops: float
    coll_bytes: float
    coll_breakdown: Dict[str, float]


def analyse_hlo(text: str, entry: Optional[str] = None) -> HloCost:
    comps = _split_computations(text)
    local = {name: _local_cost(lines) for name, lines in comps.items()}

    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}

    def total(name: str, stack=()) -> Tuple[float, float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name not in local or name in stack:
            return 0.0, 0.0, {}
        c = local[name]
        f, b = c.flops, c.coll_bytes
        bd = dict(c.coll_breakdown)
        for child, mult in c.children:
            cf, cb, cbd = total(child, stack + (name,))
            f += mult * cf
            b += mult * cb
            for k, v in cbd.items():
                bd[k] = bd.get(k, 0.0) + mult * v
        memo[name] = (f, b, bd)
        return memo[name]

    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        entry = m.group(1) if m else next(iter(comps))
    f, b, bd = total(entry)
    return HloCost(flops=f, coll_bytes=b, coll_breakdown=bd)
