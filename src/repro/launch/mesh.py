"""Production meshes (deliverable e).

Defined as FUNCTIONS so importing this module never touches jax device
state; ``dryrun.py`` sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import, tests/benches see the single real CPU device.

TPU v5e mapping (DESIGN.md §5): ``model`` is the NUMA-node analogue —
the axis the paper's §3.2 weight partitions live on; ``data`` carries
batch (and the KV sequence for long_500k); ``pod`` is cross-pod data
parallelism (2 pods × 256 chips).
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 has explicit mesh axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: every axis is implicitly Auto
    AxisType = None


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where supported.

    Single compat point for the whole repo — older jax releases have no
    ``axis_types`` kwarg (all axes behave as Auto there anyway)."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1) -> Mesh:
    """Small mesh over however many (possibly forced-host) devices exist
    — used by tests and examples."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(min(data, n // model), 1)
    return make_mesh((data, model), ("data", "model"))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The batch-carrying axes of a mesh ('pod' included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# Hardware constants (TPU v5e), used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12         # per chip
HBM_BW = 819e9                   # B/s per chip
ICI_BW = 50e9                    # B/s per link
CHIPS_PER_POD = 256
HBM_PER_CHIP = 16 * 2**30        # 16 GiB
