"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Brings up the decoding frontend on a REDUCED variant of the assigned
architecture (CPU host), optionally warm-trains it briefly so greedy
output isn't pure noise, then serves a batch of byte-level prompts and
prints the throughput report (the paper's §4 measurement protocol).

``--engine bucket`` (default) is the sequential length-bucket baseline;
``--engine continuous`` runs the paged-KV continuous-batching engine
(uniform self-attention archs only — the paged cache has no recurrent/
cross-attention state yet); ``--engine async`` serves the same stack
through the live ``AsyncEngine`` (submit/stream on a background
stepper thread) — add ``--interactive`` for a stdin demo that streams
each prompt's tokens as they are sampled.

Observability (paged engines): ``--metrics-json PATH`` writes the
metrics registry snapshot on exit, ``--trace PATH`` records per-request
trace spans as JSONL, ``--stats-every SECS`` prints a periodic metrics
line while the async engine serves (``docs/observability.md``).

Network serving (``docs/serving.md`` "HTTP serving front-end"):
``--http`` serves ``/v1/completions`` (SSE streaming) + ``/healthz`` +
``/metrics`` instead of running the batch demo.  ``--replicas 0``
(default) serves the in-process ``AsyncEngine``; ``--replicas N``
spawns N ``repro.serving.worker`` subprocesses under a supervisor and
routes across them with prefix-affinity placement
(``repro.serving.router``).  ``--port 0`` picks a free port;
``--port-file PATH`` writes the bound port for scripts
(``tools/check.sh --smoke``).

Examples:
    python -m repro.launch.serve --arch gemma3-1b --max-new 24
    python -m repro.launch.serve --arch qwen3-1.7b --engine continuous \\
        --max-running 4 --page-size 16
    python -m repro.launch.serve --arch qwen3-1.7b --engine async \\
        --interactive --warmup-steps 80
    python -m repro.launch.serve --arch recurrentgemma-2b \\
        --prompt "the scheduler binds" --temperature 0.7
    python -m repro.launch.serve --arch tiny --engine async --http \\
        --replicas 2 --port 8080
"""

import argparse
import dataclasses
import sys


def stream_interactive(eng, handle, write, *, decode=None,
                       timeout: float = 300.0) -> str:
    """Stream one interactive request through ``write``; returns
    ``"finished"`` / ``"failed"`` / ``"cancelled"``.

    A handle that lands FAILED raises ``AsyncEngineError`` out of
    ``stream()`` with the real error chained as ``__cause__`` — the
    interactive loop used to crash on it and drop the reason; here the
    chained cause is printed and the session keeps going
    (``tests/test_async_serving.py``).
    """
    from ..serving.async_engine import AsyncEngineError, RequestState
    decode = decode if decode is not None else str
    try:
        for t in eng.stream(handle, timeout=timeout):
            write(decode(t))
    except AsyncEngineError as e:
        cause = e.__cause__
        write(f"\n[request failed: {e}"
              + (f" — caused by {type(cause).__name__}: {cause}"
                 if cause is not None else "") + "]\n")
        return "failed"
    except TimeoutError as e:
        eng.cancel(handle)
        write(f"\n[request timed out: {e}]\n")
        return "failed"
    if handle.state is RequestState.CANCELLED:
        write("\n[request cancelled]\n")
        return "cancelled"
    write("\n")
    return "finished"


def _serve_http(fe, *, port_file=None, supervisor=None) -> int:
    """Run a started frontend until SIGTERM/SIGINT, then drain the
    backend (and, behind a router, the worker fleet)."""
    import signal
    import threading

    from ..serving import faults
    faults.load_env()       # REPRO_FAULTS chaos harness (no-op unset)
    if port_file:
        with open(port_file, "w") as f:
            f.write(str(fe.port))
    print(f"serving http on {fe.url} "
          "(/v1/completions /healthz /metrics)", flush=True)
    stop = threading.Event()
    for s in (signal.SIGTERM, signal.SIGINT):
        signal.signal(s, lambda *_: stop.set())
    stop.wait()
    fe.close(shutdown_backend=True)
    if supervisor is not None:
        supervisor.shutdown()
    print("http serving stopped", flush=True)
    return 0


def _serve_replicated(args) -> int:
    """``--http --replicas N``: front-door process holds only the
    supervisor + router + frontend — no model is built here; each
    worker subprocess builds its own engine + page pool."""
    from ..data.tokenizer import ByteTokenizer
    from ..serving.http import HttpFrontend
    from ..serving.router import Router
    from ..serving.supervisor import Supervisor
    worker_args = ["--arch", args.arch, "--max-running",
                   str(args.max_running), "--page-size",
                   str(args.page_size), "--seed", "0"]
    if args.n_pages is not None:
        worker_args += ["--n-pages", str(args.n_pages)]
    if args.prefill_chunk is not None:
        worker_args += ["--prefill-chunk", str(args.prefill_chunk)]
    if args.no_prefix_cache:
        worker_args += ["--no-prefix-cache"]
    if args.quant != "none":
        worker_args += ["--quant", args.quant]
    if args.kv_dtype != "fp32":
        worker_args += ["--kv-dtype", args.kv_dtype]
    if args.spec_decode:
        worker_args += ["--spec-decode", str(args.spec_decode)]
    sup = Supervisor(args.replicas, worker_args, host=args.host,
                     max_respawns=args.max_respawns)
    print(f"starting {args.replicas} engine workers "
          f"(--arch {args.arch}) ...", flush=True)
    clients = sup.start()
    router = Router(clients, page_size=args.page_size,
                    breaker_threshold=args.breaker_threshold)
    # the self-healing loop: death drains the replica from the ring;
    # a successful respawn re-admits it (docs/serving.md)
    sup.on_death = lambda rid, rc: router.mark_dead(rid)
    sup.on_respawn = lambda rid, client: router.readmit(rid, client)
    for rid, c in sorted(clients.items()):
        print(f"  worker {rid}: {c.describe()}", flush=True)
    fe = HttpFrontend(router, tokenizer=ByteTokenizer(), host=args.host,
                      port=args.port, max_inflight=args.max_inflight,
                      max_queue_depth=args.max_queue_depth).start()
    return _serve_http(fe, port_file=args.port_file, supervisor=sup)


def _print_shard_stats(pool) -> None:
    """Per-shard / per-node KV pool residency under --tp-shards: every
    shard reserves its head slice of every node's pages."""
    shard = pool.capacity_bytes_per_shard()
    node = pool.capacity_bytes_per_node()
    live = pool.live_bytes_per_node()
    print("tp pool: "
          + ", ".join(f"shard{s} {b / 1024:.0f} KiB"
                      for s, b in sorted(shard.items())))
    print("tp pages: "
          + ", ".join(f"node{n} {live.get(n, 0) / 1024:.0f}"
                      f"/{b / 1024:.0f} KiB live"
                      for n, b in sorted(node.items())))


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--prompt", action="append", default=None)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--engine", choices=("bucket", "continuous", "async"),
                    default="bucket")
    ap.add_argument("--interactive", action="store_true",
                    help="async engine: read prompts from stdin and "
                         "stream tokens as they are sampled")
    ap.add_argument("--max-running", type=int, default=4,
                    help="continuous engine: running-batch slots")
    ap.add_argument("--page-size", type=int, default=16,
                    help="continuous engine: KV page token slots")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="continuous engine: KV pool pages "
                         "(default: no-preemption sizing)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="continuous engine: prefill at most this many "
                         "prompt tokens per step (default: one-shot)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="continuous engine: disable prompt-prefix "
                         "page sharing")
    ap.add_argument("--quant", choices=("none", "q4"), default="none",
                    help="continuous/async engines: weight format — "
                         "'q4' packs attention/MLP projections to Q4_0 "
                         "at load (docs/quantization.md)")
    ap.add_argument("--kv-dtype", choices=("fp32", "int8"),
                    default="fp32",
                    help="continuous/async engines: KV page format — "
                         "'int8' stores quantized pages with per-row "
                         "scales, fitting >=1.9x the pages in the same "
                         "pool bytes (docs/quantization.md)")
    ap.add_argument("--spec-decode", type=int, default=0, metavar="K",
                    help="continuous/async engines: self-speculative "
                         "decoding — draft up to K tokens per step by "
                         "prompt lookup and verify them in one batched "
                         "forward; greedy output stays byte-identical "
                         "to K=0 (docs/serving.md)")
    ap.add_argument("--tp-shards", type=int, default=1,
                    help="continuous/async engines: tensor-parallel "
                         "shards — forces that many host devices "
                         "(shard ≅ NUMA node), head-shards the KV page "
                         "pools over the mesh's 'model' axis")
    ap.add_argument("--warmup-steps", type=int, default=40,
                    help="brief LM warm-up so outputs aren't noise "
                         "(0 = random weights)")
    ap.add_argument("--metrics-json", metavar="PATH", default=None,
                    help="paged engines: write the metrics registry "
                         "snapshot (JSON, repro.obs schema) on exit")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="paged engines: record per-request trace "
                         "spans and write them as JSONL on exit")
    ap.add_argument("--stats-every", type=float, default=0.0,
                    metavar="SECS",
                    help="async engine: print a one-line metrics "
                         "summary every SECS seconds while serving")
    ap.add_argument("--http", action="store_true",
                    help="serve /v1/completions + /healthz + /metrics "
                         "over HTTP instead of the batch demo "
                         "(async engine)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="--http: bind address")
    ap.add_argument("--port", type=int, default=0,
                    help="--http: bind port (0 picks a free one)")
    ap.add_argument("--port-file", metavar="PATH", default=None,
                    help="--http: write the bound port here (scripts)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="--http: engine-worker subprocesses behind a "
                         "prefix-affinity router (0 = serve the "
                         "in-process engine)")
    ap.add_argument("--max-respawns", type=int, default=2,
                    help="--replicas: restarts the supervisor grants "
                         "each dead worker before it stays dead "
                         "(0 disables self-healing)")
    ap.add_argument("--max-inflight", type=int, default=None,
                    help="--http: admission cap — requests in flight "
                         "at the frontend; excess is shed with 429 + "
                         "Retry-After (docs/robustness.md)")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="--http: shed with 429 while the scheduler "
                         "queue is this deep (in-process engine only)")
    ap.add_argument("--breaker-threshold", type=int, default=3,
                    help="--replicas: consecutive worker failures that "
                         "open the router's circuit breaker")
    args = ap.parse_args()

    if args.engine == "bucket" and (args.metrics_json or args.trace
                                    or args.stats_every):
        ap.error("--metrics-json/--trace/--stats-every report the paged "
                 "serving stack; use --engine continuous or async")
    if args.engine == "bucket" and (args.quant != "none"
                                    or args.kv_dtype != "fp32"):
        ap.error("--quant/--kv-dtype serve through the paged engines; "
                 "use --engine continuous or async")
    if args.engine == "bucket" and args.spec_decode:
        ap.error("--spec-decode serves through the paged engines; "
                 "use --engine continuous or async")
    if args.spec_decode < 0:
        ap.error("--spec-decode must be >= 0")
    if args.replicas and not args.http:
        ap.error("--replicas needs --http")
    if args.max_respawns < 0:
        ap.error("--max-respawns must be >= 0")
    if args.http:
        if args.engine != "async":
            ap.error("--http serves through the async engine; add "
                     "--engine async")
        if args.interactive:
            ap.error("--http and --interactive are exclusive")
        if args.replicas:
            if args.tp_shards > 1:
                ap.error("--replicas spawns single-shard workers; "
                         "--tp-shards applies to --replicas 0")
            return _serve_replicated(args)

    import os
    import time

    if args.tp_shards > 1:
        # must land before the first jax import: device count is fixed
        # at backend initialisation
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.tp_shards}")

    import jax
    import jax.numpy as jnp

    from ..configs import get_config, list_archs
    from ..data.pipeline import (PackedLMDataset, stub_frames,
                                 stub_image_embeds)
    from ..data.tokenizer import ByteTokenizer
    from ..models import build_model, reduced_config
    from ..serving import (AsyncEngine, ContinuousServingEngine, Request,
                           ServingEngine, throughput_report)
    from ..serving.sampler import SamplingParams
    from ..training.loop import train
    from ..training.optimizer import AdamWConfig

    if args.arch == "tiny":
        # the benchmark suite's bench-tiny model: instant to build, the
        # smoke-test arch for --http
        from ..serving.worker import build_tiny
        model, params = build_tiny()
        cfg = model.cfg
    elif args.arch not in list_archs():
        ap.error(f"unknown arch; choose 'tiny' or one of {list_archs()}")
    else:
        cfg = reduced_config(get_config(args.arch))
        cfg = dataclasses.replace(cfg, dtype=jnp.float32,
                                  capacity_factor=4.0,
                                  vocab_size=max(cfg.vocab_size, 259))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
    tok = ByteTokenizer()
    print(f"arch={cfg.name} (reduced, {cfg.param_count() / 1e6:.1f}M)")

    mesh = None
    if args.tp_shards > 1:
        if args.engine == "bucket":
            ap.error("--tp-shards serves through the paged engines; "
                     "use --engine continuous or async")
        from .mesh import make_mesh
        if len(jax.devices()) < args.tp_shards:
            ap.error(f"{len(jax.devices())} devices for "
                     f"--tp-shards {args.tp_shards} (XLA_FLAGS was set "
                     "too late — is jax imported before main()?)")
        mesh = make_mesh((args.tp_shards,), ("model",))
        print(f"tp mesh: {args.tp_shards}-way 'model' axis over "
              f"{[d.platform for d in jax.devices()][0]} devices "
              "(shard ≅ NUMA node)")

    if args.warmup_steps:
        print(f"warm-up training ({args.warmup_steps} steps) ...")
        ds = PackedLMDataset(seq_len=64, n_docs=1000,
                             vocab_size=cfg.vocab_size)

        def extra_fn(step, bs):
            extra = {}
            if cfg.is_encoder_decoder:
                extra["frames"] = stub_frames(bs, cfg.n_audio_frames,
                                              cfg.d_model, seed=step)
            if cfg.cross_attn_every:
                extra["image_embeds"] = stub_image_embeds(
                    bs, cfg.n_image_tokens, cfg.d_model, seed=step)
            return extra

        params, _, _ = train(model, params, ds.batches(8, extra_fn=extra_fn),
                             AdamWConfig(lr=2e-3, warmup_steps=5,
                                         total_steps=args.warmup_steps),
                             steps=args.warmup_steps, log_every=20)

    prompts = args.prompt or ["the scheduler binds", "a numa node",
                              "the kv cache streams", "one thread gathers"]
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        max_new_tokens=args.max_new)
    reqs = []
    for i, p in enumerate(prompts):
        extra = {}
        if cfg.is_encoder_decoder:
            extra["frames"] = stub_frames(1, cfg.n_audio_frames,
                                          cfg.d_model)[0]
        if cfg.cross_attn_every:
            extra["image_embeds"] = stub_image_embeds(
                1, cfg.n_image_tokens, cfg.d_model)[0]
        reqs.append(Request(uid=i, prompt=tok.encode(p), sampling=sp,
                            extra=extra))
    max_len = max(len(r.prompt) for r in reqs) + args.max_new + 8
    tracer = None
    if args.trace:
        from ..obs import RequestTracer
        tracer = RequestTracer()
    #: metrics the periodic --stats-every line summarises
    stat_names = ("serving.steps", "scheduler.running",
                  "scheduler.queue_depth", "scheduler.preemptions",
                  "serving.tokens.decode", "kv_pool.pages_free")
    quant = None
    if args.quant != "none" or args.kv_dtype != "fp32":
        from ..quant.policy import QuantPolicy
        quant = QuantPolicy(weights=args.quant, kv_dtype=args.kv_dtype)
        print(f"quant: weights={quant.weights} kv_dtype={quant.kv_dtype} "
              "(docs/quantization.md)")
    if args.engine == "async":
        eng = AsyncEngine(
            model, params, max_len=max(max_len, 256 + args.max_new)
            if (args.interactive or args.http) else max_len,
            max_running=args.max_running, page_size=args.page_size,
            n_pages=args.n_pages, prefill_chunk=args.prefill_chunk,
            prefix_cache=not args.no_prefix_cache, mesh=mesh,
            n_nodes=max(args.tp_shards, 1), quant=quant,
            spec_decode=args.spec_decode, tracer=tracer)
        if args.http:        # --replicas 0: in-process engine over HTTP
            from ..serving.http import HttpFrontend
            fe = HttpFrontend(eng, tokenizer=tok, host=args.host,
                              port=args.port,
                              max_inflight=args.max_inflight,
                              max_queue_depth=args.max_queue_depth
                              ).start()
            return _serve_http(fe, port_file=args.port_file)
        if args.interactive:
            print("interactive async demo — one prompt per line, "
                  "empty line or EOF quits")
            while True:
                try:
                    line = input("> ")
                except EOFError:
                    break
                if not line.strip():
                    break
                handle = eng.submit(Request(uid=0,
                                            prompt=tok.encode(line),
                                            sampling=sp))
                stream_interactive(
                    eng, handle,
                    lambda s: print(s, end="", flush=True),
                    decode=lambda t: tok.decode([t]), timeout=300)
            eng.shutdown()
            return 0
        t_submit = []
        handles = []
        for r in reqs:          # live submission: all clients at once
            t_submit.append(time.perf_counter())
            handles.append(eng.submit(r))
        if args.stats_every:
            next_stat = time.perf_counter() + args.stats_every
            while not all(h.done for h in handles):
                time.sleep(min(0.05, args.stats_every))
                if time.perf_counter() >= next_stat:
                    print("stats:", eng.registry.stats_line(stat_names))
                    next_stat += args.stats_every
        comps = [eng.result(h, timeout=600) for h in handles]
        st = eng.core.pool.stats
        print(f"kv pool: {st['fresh_pages']} pages allocated, "
              f"{st['shared_pages']} shared, {st['cow_copies']} CoW, "
              f"{st['cached_tokens']} prompt tokens from cache, "
              f"{st['retention_hits']} retention hits")
        if mesh is not None:
            _print_shard_stats(eng.core.pool)
        ttft = sorted(c.t_first - ts for c, ts in zip(comps, t_submit))
        print(f"ttft: p50 {ttft[len(ttft) // 2] * 1e3:.1f} ms, "
              f"max {ttft[-1] * 1e3:.1f} ms")
        # TTFT decomposition: queue-wait (submit -> first slot) +
        # prefill (slot -> first token) — Completion.t_sched
        qw = sorted(c.t_sched - c.t0 for c in comps)
        print(f"queue-wait: p50 {qw[len(qw) // 2] * 1e3:.1f} ms, "
              f"max {qw[-1] * 1e3:.1f} ms")
        eng.shutdown()
    elif args.engine == "continuous":
        eng = ContinuousServingEngine(
            model, params, max_len=max_len, max_running=args.max_running,
            page_size=args.page_size, n_pages=args.n_pages,
            prefill_chunk=args.prefill_chunk,
            prefix_cache=not args.no_prefix_cache, mesh=mesh,
            n_nodes=max(args.tp_shards, 1), quant=quant,
            spec_decode=args.spec_decode, tracer=tracer)
        comps = eng.generate(reqs)
        st = eng.pool.stats
        print(f"kv pool: {st['fresh_pages']} pages allocated, "
              f"{st['shared_pages']} shared, {st['cow_copies']} CoW, "
              f"{st['cached_tokens']} prompt tokens served from cache")
        if mesh is not None:
            _print_shard_stats(eng.pool)
    else:
        eng = ServingEngine(model, params, max_len=max_len)
        comps = eng.generate(reqs, max_batch=args.max_batch)
    for c, p in zip(comps, prompts):
        print(f"[{c.uid}] {p!r} -> {tok.decode(c.tokens)!r}")
    # async completions carry t0/t1 stamps; sync engines report their
    # own phase times
    phase = getattr(eng, "last_phase_s", None) or {}
    rep = throughput_report(comps, **phase)
    print("throughput:", {k: round(v, 2) if isinstance(v, float) else v
                          for k, v in rep.items()})
    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            f.write(eng.registry.snapshot_json())
        print(f"metrics snapshot -> {args.metrics_json}")
    if tracer is not None:
        n = tracer.write_jsonl(args.trace)
        print(f"trace: {n} events -> {args.trace}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
