"""Sharding policy: the paper's §3.2 partition plan on the mesh.

Weight rules (name-driven, rank-aware — stacked layers carry a leading
L axis that stays unsharded unless FSDP is active):

    row-partitioned  (d_in, d_out): w_q w_k w_v w_gate w_up w_y w_x
                                    in_proj           -> P(fsdp, "model")
    col-partitioned  (d_in, d_out): w_o w_down w_out out_proj
                                    -> P("model", fsdp)
    vocab-partitioned: embed (V, d) -> P("model", fsdp);
                       lm_head (d, V) -> P(fsdp, "model")
    MoE experts (E, d, f): baseline TP inside every expert
                       w_gate/w_up -> P(ep, fsdp, "model"),
                       w_down (E, f, d) -> P(ep, "model", fsdp)
                       (ep = "model"-sharded expert axis in the
                       expert-parallel variant, None in baseline)
    everything else (norm gains, biases, A_log, conv, router): replicated

``fsdp`` is the "data" axis for the big archs (those with remat=True),
else None — the capacity analogue of ArcLight's per-node pools.

Activation rules: batch over ("pod","data"); KV caches shard batch over
"data" and head_dim over "model"; long_500k (batch=1) shards the cache
*sequence* over "data" instead.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig


ROW_NAMES = ("w_q", "w_k", "w_v", "w_gate", "w_up", "w_y", "w_x",
             "in_proj")
COL_NAMES = ("w_o", "w_down", "w_out", "out_proj")


@dataclasses.dataclass(frozen=True)
class Policy:
    """Knobs the perf hillclimb sweeps (EXPERIMENTS.md §Perf)."""

    fsdp: bool = True               # shard big-arch params over "data"
    fsdp_threshold: float = 2e10    # params above this get FSDP
    expert_parallel: bool = False   # experts over "model" (vs TP inside)
    seq_shard_cache: bool = True    # long_500k: cache seq over "data"
    shard_cache_head_dim: bool = True
    microbatches: int = 1           # gradient accumulation (train)
    head_aligned: bool = True       # replicate attn weights when Hq
                                    # doesn't divide the model axis
                                    # (§3.2 "partitioned by attention
                                    # heads"); disabled for prefill

    def fsdp_active(self, cfg: ModelConfig) -> bool:
        return self.fsdp and cfg.param_count() > self.fsdp_threshold


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_spec(cfg: ModelConfig, path: str, ndim: int, shape, mesh: Mesh,
               policy: Policy, *, use_time: bool = False) -> P:
    """``use_time=True`` drops the FSDP axis — the spec a weight must
    have at its point of use (the per-layer unshard constraint that
    makes GSPMD all-gather the WEIGHTS, never the activation batch)."""
    name = path.split("/")[-1]
    fsdp = ("data" if (not use_time and policy.fsdp_active(cfg)
                       and "data" in mesh.axis_names) else None)
    n_model = mesh.shape.get("model", 1)
    n_data = mesh.shape.get("data", 1)

    def ok(dim_size: int, axis: Optional[str]) -> Optional[str]:
        if axis is None:
            return None
        n = mesh.shape.get(axis, 1)
        return axis if dim_size % n == 0 else None

    # expert tensors (E, d, f) / (E, f, d)
    if ndim == 3 + ("layers/" in path and cfg.uniform) and name in (
            "w_gate", "w_up", "w_down") and "moe" in path:
        # strip optional leading L: operate on the last 3 dims
        lead = ndim - 3
        E, a, b = shape[lead:]
        ep = "model" if (policy.expert_parallel and E % n_model == 0) \
            else None
        if ep:  # expert-parallel: whole experts per shard
            spec = [None] * lead + [ep, None, None]
            if fsdp:
                spec[lead + 1] = ok(a, fsdp)
            return P(*spec)
        if name == "w_down":   # (E, f, d): f is the contracted/sharded dim
            return P(*([None] * lead + [None, ok(a, "model"),
                                        ok(b, fsdp)]))
        return P(*([None] * lead + [None, ok(a, fsdp), ok(b, "model")]))

    if ndim >= 2:
        lead = ndim - 2
        a, b = shape[lead:]
        if name == "embed":
            return P(ok(a, "model"), ok(b, fsdp))
        if name == "lm_head":
            return P(ok(a, fsdp), ok(b, "model"))
        # paper §3.2: "W_q, W_k, W_v are partitioned BY ATTENTION HEADS"
        # — when the *query* heads don't divide the model axis (gemma3:
        # 4 heads / 16 shards) GSPMD must gather mid-softmax; replicate
        # the whole attention block instead (MLP still TP).  Archs with
        # divisible Hq keep the standard split (replicating only K/V
        # breaks the GQA reshape sharding — measured, EXPERIMENTS W1b).
        attn_names = ("w_q", "w_k", "w_v", "w_o")
        if (name in attn_names and "attn" in path and policy.head_aligned
                and cfg.n_heads % n_model):
            if name == "w_o":
                return P(*([None] * lead + [None, ok(b, fsdp)]))
            return P(*([None] * lead + [ok(a, fsdp), None]))
        if name in ROW_NAMES:
            return P(*([None] * lead + [ok(a, fsdp), ok(b, "model")]))
        if name in COL_NAMES:
            return P(*([None] * lead + [ok(a, "model"), ok(b, fsdp)]))
    return P()


def params_shardings(cfg: ModelConfig, params_shapes: Any, mesh: Mesh,
                     policy: Policy) -> Any:
    def f(path, leaf):
        spec = param_spec(cfg, _path_str(path), leaf.ndim, leaf.shape,
                          mesh, policy)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, params_shapes)


def make_layer_constraint(cfg: ModelConfig, mesh: Mesh, policy: Policy):
    """Per-layer weight unshard constraint for FSDP archs (see
    ``param_spec(use_time=True)``); None when FSDP is off."""
    if not policy.fsdp_active(cfg) or "data" not in mesh.axis_names:
        return None

    def constrain(layer_params):
        def f(path, leaf):
            spec = param_spec(cfg, _path_str(path), leaf.ndim, leaf.shape,
                              mesh, policy, use_time=True)
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, spec))
        return jax.tree_util.tree_map_with_path(f, layer_params)

    return constrain


def make_moe_hook(cfg: ModelConfig, mesh: Mesh, policy: Policy, *,
                  batch_size: int):
    """Run MoE dispatch inside shard_map over the data axis.

    Under plain GSPMD the capacity-buffer scatter uses *global* token
    indices, which the solver can only honour by replicating the
    (E, C, d) buffers and all-reducing them — ~10 TB of collectives per
    step for phi3.5 train_4k (measured; EXPERIMENTS.md §Perf).  Inside
    shard_map each data shard dispatches its own tokens with local
    indices (zero dispatch collectives), expert FFNs run TP over
    ``model`` (w_up/w_gate row-sharded on f, w_down col-sharded), and
    one psum per block implements the paper's Gather.

    This is exactly ArcLight's Scatter/Gather applied to experts: the
    thread-group (= data-shard) owns its tokens, the node-local weights
    (= f-slices) never move, synchronisation happens once per block.
    """
    if not cfg.n_experts:
        return None
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if not dp or batch_size % n_dp:
        return None
    n_model = mesh.shape.get("model", 1)
    if cfg.d_ff % n_model:
        return None
    from jax.experimental.shard_map import shard_map
    from ..models.moe import moe as moe_fn

    ep = policy.expert_parallel and cfg.n_experts % n_model == 0
    if ep:
        w_specs = {"router": P(), "w_gate": P("model", None, None),
                   "w_up": P("model", None, None),
                   "w_down": P("model", None, None)}
    else:
        w_specs = {"router": P(), "w_gate": P(None, None, "model"),
                   "w_up": P(None, None, "model"),
                   "w_down": P(None, "model", None)}
    if cfg.act != "silu":
        w_specs.pop("w_gate")
    x_spec = P(dp, None, None)

    def body(mp, x):
        if ep:
            y, aux = _moe_expert_parallel(
                mp, x, k=cfg.experts_per_token, act=cfg.act,
                capacity_factor=cfg.capacity_factor, axis="model")
        else:
            y, aux = moe_fn(mp, x, k=cfg.experts_per_token, act=cfg.act,
                            impl="scatter",
                            capacity_factor=cfg.capacity_factor)
            y = jax.lax.psum(y, "model")          # Gather (§3.3)
        for a in dp:
            aux = jax.lax.pmean(aux, a)
        return y, aux

    def hook(moe_params, x):
        return shard_map(body, mesh=mesh, in_specs=(w_specs, x_spec),
                         out_specs=(x_spec, P()), check_rep=False)(
                             moe_params, x)

    return hook


def _moe_expert_parallel(mp, x, *, k: int, act: str,
                         capacity_factor: float, axis: str):
    """Expert-parallel dispatch: each ``axis`` (model) shard owns
    E/n whole experts at FULL width (no f-split, better MXU shapes);
    tokens are replicated over ``axis`` inside the data shard, so each
    shard slices its experts\' capacity rows, runs them, and one psum
    over ``axis`` merges the combine (the optimized §Perf variant —
    trades ~k*capacity_factor x psum bytes for unsplit expert GEMMs).
    """
    import jax.numpy as jnp
    n = jax.lax.psum(1, axis)
    m_idx = jax.lax.axis_index(axis)
    E_local = mp["w_up"].shape[0]
    E = E_local * n
    lead = x.shape[:-1]
    d = x.shape[-1]
    x2d = x.reshape(-1, d)
    T = x2d.shape[0]
    logits = (x2d.astype(jnp.float32) @ mp["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    cap = max(int(T * k / E * capacity_factor), k)
    e_flat = topi.reshape(-1)
    w_flat = topv.reshape(-1).astype(x.dtype)
    tok_idx = jnp.repeat(jnp.arange(T), k)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_in_e = jnp.sum(pos * onehot, axis=-1)
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_flat * cap + pos_in_e, E * cap)
    buf = jnp.zeros((E * cap + 1, d), x.dtype)
    buf = buf.at[slot].add(x2d[tok_idx] * keep[:, None].astype(x.dtype))
    buf = buf[:-1].reshape(E, cap, d)

    mine = jax.lax.dynamic_slice_in_dim(buf, m_idx * E_local, E_local, 0)
    up = jnp.einsum("ecd,edf->ecf", mine, mp["w_up"])
    if act == "silu":
        up = jax.nn.silu(jnp.einsum("ecd,edf->ecf", mine,
                                    mp["w_gate"])) * up
    out_mine = jnp.einsum("ecf,efd->ecd", up, mp["w_down"])

    out = jnp.zeros((E, cap, d), x.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, out_mine,
                                              m_idx * E_local, 0)
    out = jax.lax.psum(out, axis)                       # Gather (§3.3)
    out = jnp.concatenate([out.reshape(E * cap, d),
                           jnp.zeros((1, d), x.dtype)], axis=0)
    gathered = out[jnp.where(keep, slot, E * cap)] \
        * keep[:, None].astype(x.dtype)
    y2d = jnp.zeros_like(x2d).at[tok_idx].add(gathered * w_flat[:, None])
    assign = jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32)
    aux = E * jnp.sum(jnp.mean(assign, axis=0) * jnp.mean(probs, axis=0))
    return y2d.reshape(*lead, d), aux


def seq_shard_axes(mesh: Mesh, batch_size: int, cache_len: int,
                   n_kv_heads: int):
    """Tiered cache-sequence sharding decision, shared by the cache
    specs and the decode hook so they can never diverge.

    Returns (axes, batch_sharded): the axes the cache sequence shards
    over — ("model",) for batch-sharded caches, up to data x model for
    long-context batch=1 — or () when whole-kv-head sharding is free
    (no collective at all) or local slices would drop below one
    512-slot attention chunk (merge overhead beats locality; rg-2b
    measured)."""
    n_data = mesh.shape.get("data", 1)
    n_model = mesh.shape.get("model", 1)
    batch_sharded = batch_size % max(n_data, 1) == 0 and n_data > 1
    if batch_sharded:
        if n_kv_heads % max(n_model, 1) == 0:
            return (), True
        if (n_model > 1 and cache_len % n_model == 0
                and cache_len // n_model >= 512):
            return ("model",), True
        return (), True
    for cand in (("data", "model"), ("data",), ("model",)):
        axes = tuple(a for a in cand if mesh.shape.get(a, 1) > 1)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if axes and n > 1 and cache_len % n == 0 and cache_len // n >= 512:
            return axes, False
    return (), False


def make_decode_attn_hook(cfg: ModelConfig, mesh: Mesh, policy: Policy, *,
                          batch_size: int, cache_len: int):
    """Sequence-sharded flash-decoding with fully-local cache updates.

    The KV cache's sequence axis shards over "model" (batch-sharded
    caches) or over data x model (long-context, batch=1).  Under plain
    GSPMD the attention chunk-scan is sequential, so the solver either
    all-gathers the cache every token or head_dim-shards it and psums
    every score chunk (both measured; EXPERIMENTS §Perf).  This hook is
    the paper's Scatter/Gather applied to the cache sequence:

    * write: the one new KV lands on the single shard that owns its
      ring slot (a masked dynamic_update_slice — no resharding at all);
    * attend: every shard runs blockwise attention over its local slice
      (un-normalised partials);
    * Gather: one LSE-weighted psum (``combine_partials``).
    """
    if not policy.seq_shard_cache:
        return None
    seq_axes, batch_sharded = seq_shard_axes(mesh, batch_size, cache_len,
                                             cfg.n_kv_heads)
    if not seq_axes:
        return None
    bspec = (tuple(a for a in ("pod", "data") if a in mesh.axis_names)
             if batch_sharded else None)
    n_shards = 1
    for a in seq_axes:
        n_shards *= mesh.shape[a]
    from jax.experimental.shard_map import shard_map
    from ..models.attention import combine_partials, flash_attention

    local = cache_len // n_shards

    def body(q, kn, vn, ck, cv, cp, window, pos):
        idx = jax.lax.axis_index(seq_axes[0])
        for a in seq_axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        import jax.numpy as jnp
        slot = pos % cache_len
        local_slot = slot - idx * local
        own = (local_slot >= 0) & (local_slot < local)
        safe = jnp.clip(local_slot, 0, local - 1)
        ck_new = jax.lax.dynamic_update_slice_in_dim(ck, kn, safe, 1)
        cv_new = jax.lax.dynamic_update_slice_in_dim(cv, vn, safe, 1)
        ck = jnp.where(own, ck_new, ck)
        cv = jnp.where(own, cv_new, cv)
        cp = jax.lax.dynamic_update_slice(cp, pos[None], (slot,))
        p_local = jax.lax.dynamic_slice(cp, (idx * local,), (local,))
        part = flash_attention(
            q, ck, cv, causal=True, window=window, q_offset=pos,
            kv_positions=p_local, chunk=min(512, local),
            return_partial=True, softcap=cfg.attn_logit_softcap)
        out = combine_partials(part, seq_axes, q.dtype)
        return out, ck, cv, cp

    seq_dim_spec = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    seq_spec = P(bspec, seq_dim_spec, None, None)
    q_spec = P(bspec, None, None, None)

    seq_ns = NamedSharding(mesh, seq_spec)

    def hook(q, kn, vn, ck, cv, cp, window, pos):
        # pin inputs/outputs so the surrounding scan cannot pick a
        # different (e.g. head_dim-sharded) layout for its ys and
        # all-to-all the cache every layer
        ck = jax.lax.with_sharding_constraint(ck, seq_ns)
        cv = jax.lax.with_sharding_constraint(cv, seq_ns)
        out, ck, cv, cp = shard_map(
            body, mesh=mesh,
            in_specs=(q_spec, q_spec, q_spec, seq_spec, seq_spec, P(),
                      P(), P()),
            out_specs=(q_spec, seq_spec, seq_spec, P()),
            check_rep=False)(q, kn, vn, ck, cv, cp, window, pos)
        ck = jax.lax.with_sharding_constraint(ck, seq_ns)
        cv = jax.lax.with_sharding_constraint(cv, seq_ns)
        return out, ck, cv, cp

    return hook


def make_activation_constraint(mesh: Mesh, *, batch_size: int):
    """Pin the batch axis of activations to ('pod','data')."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if not dp or batch_size % n_dp:
        return None

    def constrain(x):
        spec = P(*((dp,) + (None,) * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return constrain


def batch_shardings(cfg: ModelConfig, batch_shapes: Dict[str, Any],
                    mesh: Mesh, *, batch_size: int) -> Dict[str, Any]:
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    bspec = dp if (dp and batch_size % n_dp == 0) else None

    out = {}
    for k, v in batch_shapes.items():
        spec = [bspec] + [None] * (v.ndim - 1)
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def cache_shardings(cfg: ModelConfig, cache_shapes: Any, mesh: Mesh,
                    policy: Policy, *, batch_size: int,
                    long_context: bool) -> Any:
    """KV/state cache shardings.

    Leaf layouts (uniform archs have a leading L):
      k/v   (L, B, M, Hkv, hd)   pos (L, M)
      ssd.state (L, B, H, P, N)  conv tails (L, B, W-1, C)
      memory (B, M, d)
    """
    n_data = mesh.shape.get("data", 1)
    n_model = mesh.shape.get("model", 1)
    batch_ax = "data" if batch_size % n_data == 0 else None
    # long-context, unshardable batch: shard the cache sequence over
    # EVERY axis (data x model) — the flash-decoding hook reduces over
    # both (DESIGN.md §5, EXPERIMENTS.md §Perf hillclimb 3)
    seq_ax = (("data", "model") if (long_context and policy.seq_shard_cache
                                    and batch_ax is None) else None)

    def f(path, leaf):
        p = _path_str(path)
        name = p.split("/")[-1]
        nd = leaf.ndim
        # stacked leading layer dim: uniform scan, or block-scan stacks
        lead = 1 if (("blocks" in p) or (cfg.uniform and "layers" in p)) \
            else 0
        spec: list = [None] * nd
        if name in ("k", "v") and nd == lead + 4:
            B, M, H, hd = leaf.shape[lead:]
            axes, bs = seq_shard_axes(mesh, batch_size, M, H)
            if bs:
                spec[lead] = "data"
                if axes:
                    # sequence over "model": the decode hook merges
                    # flash partials (W3); head_dim sharding would
                    # psum every score chunk instead
                    spec[lead + 1] = axes[0]
                elif H % n_model == 0:
                    # whole kv heads per shard: zero-collective (W6)
                    spec[lead + 2] = "model"
            elif axes and policy.seq_shard_cache:
                spec[lead + 1] = axes if len(axes) > 1 else axes[0]
        elif name in ("state", "h", "conv") and nd >= lead + 2:
            if batch_ax and leaf.shape[lead] % n_data == 0:
                spec[lead] = batch_ax
        elif name == "memory" and nd == 3:
            if batch_ax and leaf.shape[0] % n_data == 0:
                spec[0] = batch_ax
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(f, cache_shapes)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ----------------------------------------------------------------------
# tensor-parallel paged serving (head-sharded page pools)
# ----------------------------------------------------------------------
#
# The continuous engine's TP mode (``serving.runner.ModelRunner`` with
# ``mesh=``) runs the whole paged forward inside ``shard_map`` over the
# ``model`` axis — the paper's node≅shard mapping, with each mesh shard
# standing in for one NUMA node.  The layout mirrors
# ``make_decode_attn_hook``: per-shard attention over purely local KV
# state, then ONE collective per layer to merge the partial outputs.
# Here the cache splits by **kv head** instead of by sequence, so the
# merge needs no LSE weighting — head outputs are disjoint, and the
# Gather is a zero-padded psum (``make_paged_head_merge``).  Everything
# outside attention (norms, MLP, embed/lm_head) stays replicated: the
# per-layer collective budget is exactly one all-reduce, and no
# collective ever touches KV-page bytes.

#: attention leaves sharded on their output-feature (head) dim in the
#: serving TP plan — the §3.2 "partitioned by attention heads" rule
SERVING_TP_HEAD_SHARDED = ("w_q", "w_k", "w_v", "b_q", "b_k", "b_v")


def serving_tp_param_specs(params_shapes: Any, *, axis: str = "model",
                           ) -> Any:
    """PartitionSpec tree for the paged TP serving engine.

    ``w_q/w_k/w_v`` (L, d, heads*hd) and their biases (L, heads*hd)
    shard their last (head) dim over ``axis``; every other leaf — w_o,
    MLP, norms, embed, lm_head — is replicated, so the only partial
    values in the forward are per-shard attention-head outputs and the
    one psum of :func:`make_paged_head_merge` restores full replication
    before ``w_o``.

    Q4_0 weights (``--quant q4``) replace a projection leaf with a
    ``{"q4_packed", "q4_scales"}`` subtree (``repro.quant.policy``);
    both members keep the original column (N) layout in their last dim,
    and Q4_0 quantizes along K — so sharding that last dim by the
    *parent* weight's rule yields byte-identical blocks to quantizing
    the already-sharded weight, and the same one-psum-per-layer budget
    holds.
    """
    def f(path, leaf):
        p = _path_str(path)
        parts = p.split("/")
        name = parts[-1]
        if name in ("q4_packed", "q4_scales") and len(parts) >= 2:
            name = parts[-2]
        if name in SERVING_TP_HEAD_SHARDED and "attn" in p:
            return P(*([None] * (leaf.ndim - 1) + [axis]))
        return P()
    return jax.tree_util.tree_map_with_path(f, params_shapes)


def paged_cache_specs(cache_shapes: Any, *, axis: str = "model") -> Any:
    """PartitionSpec tree for the paged device cache under TP.

    Each per-layer flat pool buffer (rows, Hkv, D) shards its **kv-head
    dim** over ``axis`` — every shard holds its head slice of every
    page, so page allocation, sharing, CoW and eviction stay pure host
    bookkeeping with zero cross-shard byte traffic.  Block tables (and
    anything else host-written) replicate.

    Int8 pools (``--kv-dtype int8``) add ``k_scale``/``v_scale``
    buffers (rows, Hkv) whose head dim shards exactly like the code
    buffers, so each shard dequantizes its local heads with local
    scales — still zero cross-shard KV traffic.
    """
    def f(path, leaf):
        name = _path_str(path).split("/")[-1]
        if name in ("k", "v") and leaf.ndim == 3:
            return P(None, axis, None)
        if name in ("k_scale", "v_scale") and leaf.ndim == 2:
            return P(None, axis)
        return P()
    return jax.tree_util.tree_map_with_path(f, cache_shapes)


def make_paged_head_merge(n_heads: int, n_shards: int, *,
                          axis: str = "model"):
    """Gather for head-sharded paged attention (§3.3 applied to heads).

    Inside the shard_map body each shard's attention output holds its
    ``n_heads / n_shards`` local query heads.  The merge scatters that
    slice into a zero tensor of the full head set at the shard's head
    offset and psums over ``axis`` — head supports are disjoint, so the
    sum is an exact concatenation (``x + 0.0 == x``), making the merged
    tensor **bit-identical** to the single-shard attention output.  One
    psum per layer, the TP forward's only collective.
    """
    import jax.numpy as jnp
    if n_heads % n_shards:
        raise ValueError(
            f"{n_heads} query heads do not shard over {n_shards} shards")
    local = n_heads // n_shards

    def merge(out):                       # out: (B, S, H_local, D)
        idx = jax.lax.axis_index(axis)
        full = jnp.zeros(out.shape[:2] + (n_heads,) + out.shape[3:],
                         out.dtype)
        full = jax.lax.dynamic_update_slice_in_dim(
            full, out, idx * local, 2)
        return jax.lax.psum(full, axis)

    return merge
