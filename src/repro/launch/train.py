"""Training launcher: ``python -m repro.launch.train --arch <id>``.

Runs a REDUCED variant of the assigned architecture end-to-end on this
host (CPU) with the full production pipeline — data, model zoo, AdamW,
microbatching, checkpointing.  ``--full-config`` switches to the real
config (only sensible on real hardware); ``--devices N`` forces N host
devices for a small data-parallel mesh demo.

Examples:
    python -m repro.launch.train --arch qwen3-1.7b --steps 60
    python -m repro.launch.train --arch mamba2-370m --steps 40 \\
        --seq-len 64 --batch 8
    python -m repro.launch.train --arch phi3.5-moe-42b-a6.6b --steps 30 \\
        --devices 4   # 4-way data-parallel on host devices
"""

import argparse
import dataclasses
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--full-config", action="store_true",
                    help="use the production config (real hardware only)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (data-parallel demo)")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import get_config, list_archs
    from ..data.pipeline import PackedLMDataset, stub_frames, \
        stub_image_embeds
    from ..models import build_model, reduced_config
    from ..training.loop import make_train_step
    from ..training.optimizer import AdamWConfig, adamw_init
    from ..training.checkpoint import save_checkpoint
    from .mesh import make_host_mesh

    if args.arch not in list_archs():
        ap.error(f"unknown arch; choose from {list_archs()}")
    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduced_config(cfg)
        cfg = dataclasses.replace(cfg, dtype=jnp.float32,
                                  capacity_factor=4.0)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"(reduced={not args.full_config}) devices={len(jax.devices())}")

    params = model.init(jax.random.PRNGKey(0))
    ds = PackedLMDataset(seq_len=args.seq_len, n_docs=2000,
                         vocab_size=cfg.vocab_size)

    def extra_fn(step, bs):
        extra = {}
        if cfg.is_encoder_decoder:
            extra["frames"] = stub_frames(bs, cfg.n_audio_frames,
                                          cfg.d_model, seed=step)
        if cfg.cross_attn_every:
            extra["image_embeds"] = stub_image_embeds(
                bs, cfg.n_image_tokens, cfg.d_model, seed=step)
        return extra

    batches = ds.batches(args.batch, extra_fn=extra_fn)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                          total_steps=args.steps)
    step_fn = make_train_step(model, opt_cfg,
                              microbatches=args.microbatches)

    if args.devices and args.devices > 1:
        mesh = make_host_mesh(model=1, data=args.devices)
        dp = NamedSharding(mesh, P("data"))
        repl = NamedSharding(mesh, P())

        def shard_batch(b):
            return {k: jax.device_put(v, dp) for k, v in b.items()}
        with mesh:
            step_fn = jax.jit(step_fn)
    else:
        shard_batch = lambda b: b  # noqa: E731
        step_fn = jax.jit(step_fn)

    opt_state = adamw_init(params)
    import time
    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        params, opt_state, metrics = step_fn(params, opt_state,
                                             shard_batch(next(batches)))
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if step % 10 == 0 or step == args.steps - 1:
            print(f"  step {step:4d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({time.time() - t0:.1f}s)")
    print(f"loss {first:.3f} -> {last:.3f}")
    if args.ckpt:
        print("checkpoint:", save_checkpoint(args.ckpt, args.steps,
                                             {"params": params}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
