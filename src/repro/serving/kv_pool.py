"""NUMA-aware paged KV-cache pool (host-side allocator).

ArcLight's §2.3 memory discipline — pre-allocate node-bound pools at
startup, then *bind* rather than *allocate* at runtime — applied to the
serving KV cache.  The physical cache is a fixed pool of fixed-size
**pages** (``page_size`` token slots each, all layers of a page
co-resident on one NUMA node).  At runtime a sequence owns an ordered
list of pages (its *block table*); admission, growth, and eviction move
page *ownership* around on the host without ever moving cache bytes on
the device.

Placement is planned through :class:`repro.core.memory.MemoryManager`
(``plan_kv_pages``), so KV pages sit in the same per-node accounting as
weights and activations: pages stripe round-robin across node pools and
``MemoryManager.per_node_bytes`` reports the whole model's residency.
On TPU the "node" is a mesh shard; on CPU it is a NUMA node the engine
would ``mbind`` the page's carve-out to.

Invariants (property-tested in ``tests/test_serving_paged.py``):

* a physical page is owned by at most one live sequence (no aliasing);
* page 0 is never handed out — it is the device-side scratch page that
  idle batch slots and padded prefill positions write into;
* freed pages return to their node free-list and are reused (LIFO, so
  recently-touched — cache-warm — pages are preferred);
* per-node live-byte accounting never exceeds the planned capacity.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from ..core.memory import MemoryManager


@dataclasses.dataclass(frozen=True)
class KVPoolConfig:
    """Static shape of the physical page pool.

    ``n_pages`` includes the reserved scratch page 0; the usable pool is
    ``n_pages - 1`` pages.  ``page_bytes`` covers K and V for all layers
    of one page.
    """

    n_pages: int
    page_size: int
    n_layers: int
    n_kv_heads: int
    head_dim: int
    dtype_bytes: int = 4
    n_nodes: int = 1
    numa: bool = True

    @property
    def page_bytes(self) -> int:
        return (2 * self.n_layers * self.page_size * self.n_kv_heads
                * self.head_dim * self.dtype_bytes)

    @property
    def max_pages_per_seq(self) -> int:
        return self.n_pages - 1

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)


class KVCachePool:
    """Free-list page allocator with per-sequence block tables."""

    def __init__(self, cfg: KVPoolConfig,
                 mm: Optional[MemoryManager] = None) -> None:
        if cfg.n_pages < 2:
            raise ValueError("need at least one usable page besides scratch")
        self.cfg = cfg
        self.mm = mm if mm is not None else MemoryManager(
            cfg.n_nodes, numa=cfg.numa)
        self.mm.plan_kv_pages(cfg.n_pages, cfg.page_bytes)
        self._free: Dict[int, List[int]] = {}
        for pid in range(cfg.n_pages - 1, 0, -1):   # page 0 stays reserved
            self._free.setdefault(self.mm.kv_page_node(pid), []).append(pid)
        self._pages: Dict[int, List[int]] = {}      # seq uid -> logical order
        self._owner: Dict[int, int] = {}            # page id -> seq uid

    # ------------------------------------------------------------------
    def n_free(self) -> int:
        return sum(len(v) for v in self._free.values())

    def n_live(self) -> int:
        return len(self._owner)

    def can_grow(self, uid: int, n_tokens: int) -> bool:
        need = self.cfg.pages_for(n_tokens) - len(self._pages.get(uid, []))
        return need <= self.n_free()

    def _take_page(self, node_hint: int) -> int:
        """Pop a free page, preferring the hinted node's pool."""
        order = sorted(self._free, key=lambda n: (n != node_hint,
                                                  -len(self._free[n]), n))
        for node in order:
            if self._free[node]:
                return self._free[node].pop()
        raise RuntimeError("KV pool exhausted")

    # ------------------------------------------------------------------
    def grow(self, uid: int, n_tokens: int, *, node_hint: int = 0) -> bool:
        """Ensure ``uid`` owns pages covering ``n_tokens`` token slots.

        Returns False (allocating nothing) when the free pool cannot
        cover the growth — the scheduler then preempts somebody.
        """
        pages = self._pages.setdefault(uid, [])
        need = self.cfg.pages_for(n_tokens) - len(pages)
        if need <= 0:
            return True
        if self.cfg.pages_for(n_tokens) > self.cfg.max_pages_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens needs "
                f"{self.cfg.pages_for(n_tokens)} pages; pool only has "
                f"{self.cfg.max_pages_per_seq}")
        if need > self.n_free():
            return False
        for _ in range(need):
            pid = self._take_page(node_hint)
            self._owner[pid] = uid
            pages.append(pid)
        return True

    def free(self, uid: int) -> int:
        """Release all of a sequence's pages; returns how many."""
        pages = self._pages.pop(uid, [])
        for pid in pages:       # stack top = last-written (warmest) page
            del self._owner[pid]
            self._free[self.mm.kv_page_node(pid)].append(pid)
        return len(pages)

    def block_table(self, uid: int) -> List[int]:
        return list(self._pages.get(uid, []))

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    def live_bytes_per_node(self) -> Dict[int, int]:
        out = {n: 0 for n in self._free}
        for pid in self._owner:
            out[self.mm.kv_page_node(pid)] += self.cfg.page_bytes
        return out

    def capacity_bytes_per_node(self) -> Dict[int, int]:
        """Planned (pre-allocated) KV bytes per node, from the planner's
        pool peaks — what the node's carve-out actually reserves."""
        out: Dict[int, int] = {}
        for p in self.mm.kv_pools:
            out[p.node_id or 0] = out.get(p.node_id or 0, 0) + p.peak
        return out
