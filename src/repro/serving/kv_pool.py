"""NUMA-aware paged KV-cache pool with refcounted prefix sharing.

ArcLight's §2.3 memory discipline — pre-allocate node-bound pools at
startup, then *bind* rather than *allocate* at runtime — applied to the
serving KV cache.  The physical cache is a fixed pool of fixed-size
**pages** (``page_size`` token slots each, all layers of a page
co-resident on one NUMA node).  At runtime a sequence owns an ordered
list of pages (its *block table*); admission, growth, sharing and
eviction move page *references* around on the host without ever moving
cache bytes on the device (the one exception: copy-on-write, which
emits an explicit page-copy the engine applies).

Placement is planned through :class:`repro.core.memory.MemoryManager`
(``plan_kv_pages``), so KV pages sit in the same per-node accounting as
weights and activations: pages stripe round-robin across node pools and
``MemoryManager.per_node_bytes`` reports the whole model's residency.
On TPU the "node" is a mesh shard; on CPU it is a NUMA node the engine
would ``mbind`` the page's carve-out to.  Under **tensor-parallel
serving** (``KVPoolConfig.n_shards`` > 1, the engine's ``mesh=`` mode)
a page's rows still stripe across nodes, but its *bytes* split across
the mesh shards — each shard holds the page's local kv-head slice in a
per-(node, shard) pool (``kv_page_placement``).  Nothing else here
changes: page ids are global, so refcounts, the prefix map, retention
and CoW plans are shard-agnostic host bookkeeping.

Prefix caching: KV bytes are a pure
function of ``(token values, absolute positions)``, so two requests
whose prompts agree on a page-aligned prefix can point their block
tables at the *same* physical pages.  The pool keeps a **prompt-prefix
hash map** — a chain hash over full token blocks, so a block's key
commits to everything before it — from which admission resolves how
many resident pages a new prompt can reuse (:meth:`match_prefix` /
:meth:`adopt_prefix`).  When the new prompt diverges from the cached
content *mid-page*, the matching head of the divergent page is reused
by **copy-on-write**: a fresh page is allocated, a ``(src, dst)`` copy
is queued in :attr:`pending_copies`, and only the divergent suffix is
recomputed.

Retention (``retain=``, on by default with the prefix cache): a
prefix-indexed page whose refcount drops to 0 is not forgotten — it
moves to a **cached-free LRU** (:attr:`_retained`).  Its bytes stay
resident and its prefix-map entries stay valid, so a repeat prompt hits
the cache even after every sequence that wrote it has finished.
Retained pages still count as allocatable (:meth:`n_free` includes
them): when the true free lists run dry, :meth:`_take_page` evicts the
least-recently-retired page (forgetting its prefix entries) — caching
never costs capacity, only the reuse opportunity of whatever is
evicted.  Sharing a retained page *revives* it (back to refcount 1,
``retention_hits`` stat).

Invariants (property-tested in ``tests/test_serving_paged.py`` and
``tests/test_prefix_chunking.py``):

* **scratch-page rule** — page 0 is never handed out: it is the
  device-side scratch page that idle batch slots and padded prefill
  positions write into;
* **refcount lifecycle** — every page in any live block table has
  refcount >= 1; a page leaves the live set exactly when its refcount
  drops to 0 (to its node free-list, or to the retained LRU when it is
  prefix-indexed); ``release``/``free`` only ever decrement, so a
  shared page outlives any single owner;
* **immutability of shared pages** — a page with refcount > 1 is never
  written: writers go through :meth:`ensure_writable`, which swaps in a
  private copy-on-write page first;
* freed pages return to their node free-list and are reused (LIFO, so
  recently-touched — cache-warm — pages are preferred);
* per-node live-byte accounting never exceeds the planned capacity.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.memory import MemoryManager


@dataclasses.dataclass(frozen=True)
class KVPoolConfig:
    """Static shape of the physical page pool.

    ``n_pages`` includes the reserved scratch page 0; the usable pool is
    ``n_pages - 1`` pages.  ``page_bytes`` covers K and V for all layers
    of one page.
    """

    n_pages: int
    page_size: int
    n_layers: int
    n_kv_heads: int
    head_dim: int
    dtype_bytes: int = 4
    n_nodes: int = 1
    numa: bool = True
    #: tensor-parallel mesh shards the pool is head-sharded over: each
    #: page's bytes live 1/S on every shard (kv heads split S ways), so
    #: planning carves a per-(node, shard) region for every page
    n_shards: int = 1
    #: KV page element format ("fp32" | "int8" — the engine's
    #: ``--kv-dtype``).  "int8" pages hold 1-byte codes plus one f32
    #: scale per (token row, kv head) (``repro.quant.kv_int8``), so a
    #: token-head costs head_dim + 4 bytes instead of
    #: head_dim * dtype_bytes — the same pool byte budget holds
    #: ~dtype_bytes·D/(D+4) times the pages.  Page *accounting* (page
    #: ids, refcounts, prefix map, CoW, block tables) is byte-agnostic;
    #: only this byte arithmetic and the device buffers change.
    kv_dtype: str = "fp32"

    @property
    def page_bytes(self) -> int:
        if self.kv_dtype == "int8":
            # int8 codes + one f32 scale per (token row, kv head)
            per_row_head = self.head_dim + 4
        elif self.kv_dtype == "fp32":
            per_row_head = self.head_dim * self.dtype_bytes
        else:
            raise ValueError(f"kv_dtype={self.kv_dtype!r}: "
                             "choose 'fp32' or 'int8'")
        return (2 * self.n_layers * self.page_size * self.n_kv_heads
                * per_row_head)

    @property
    def page_shard_bytes(self) -> int:
        """One shard's slice of a page (its local kv-head block)."""
        return self.page_bytes // self.n_shards

    @property
    def max_pages_per_seq(self) -> int:
        return self.n_pages - 1

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Outcome of a prompt-prefix lookup.

    ``pages`` are resident full pages the prompt can share outright;
    ``cow_src``/``cow_len`` describe a mid-page divergence: the first
    ``cow_len`` tokens of the block *after* the shared pages match the
    resident page ``cow_src``, so a copy-on-write clone of it saves
    recomputing those tokens.  ``n_tokens`` is the total cached-token
    count (``len(pages) * page_size + cow_len``) — prefill resumes at
    this offset.
    """

    pages: Tuple[int, ...] = ()
    n_tokens: int = 0
    cow_src: Optional[int] = None
    cow_len: int = 0


_CHAIN_ROOT = 0x9E3779B97F4A7C15   # arbitrary non-zero chain seed


def prefix_chain_key(tokens: Sequence[int], page_size: int, *,
                     max_blocks: Optional[int] = None) -> Optional[int]:
    """Chain hash over the leading *full* ``page_size`` token blocks of
    a prompt — the same ``hash((chain, block))`` scheme
    :class:`PrefixCache` keys pages by, exposed for callers that need
    the *identity* of a shared prefix without a pool: the multi-replica
    router uses it to map shared-system-prompt requests onto the
    replica whose pool already holds those pages (prefix-affinity
    routing, ``repro.serving.router``).

    ``max_blocks`` caps how much of the prompt the key commits to (the
    router keys on the first block or two — the system prompt — so
    requests differing only in their user tail still share a key).
    Returns ``None`` when the prompt has no full block: there is no
    shareable page-aligned prefix to be affine to.
    """
    n = len(tokens) // page_size
    if max_blocks is not None:
        n = min(n, max_blocks)
    if n <= 0:
        return None
    h = _CHAIN_ROOT
    for i in range(n):
        h = hash((h, tuple(tokens[i * page_size:(i + 1) * page_size])))
    return h


class PrefixCache:
    """Prompt-prefix hash map: token-block chain hash -> physical page.

    Keys are *chain* hashes — block i's key commits to the contents of
    blocks 0..i — so one flat dict resolves "longest shared prefix" by
    walking the request's blocks in order.  ``_next`` maps a chain
    prefix to *some* resident page that follows it, which is what
    mid-page divergence (copy-on-write) compares against.  Entries are
    content-verified on hit (``_tokens``) so a hash collision can only
    cost a missed reuse, never a wrong one.

    The map points at **resident** pages: live (refcount >= 1) or
    retained (refcount 0, bytes intact, reclaimable).  The pool forgets
    a page's entries when the page's bytes stop being trustworthy —
    immediately at refcount 0 without retention, or at LRU eviction
    with it.
    """

    def __init__(self, page_size: int) -> None:
        self.page_size = page_size
        self._full: Dict[int, int] = {}    # chain hash -> page id
        self._next: Dict[int, int] = {}    # chain prefix -> following page
        self._tokens: Dict[int, Tuple[int, ...]] = {}  # page -> its tokens
        self._keys: Dict[int, List[Tuple[str, int]]] = {}  # page -> entries

    def __len__(self) -> int:
        return len(self._full)

    def register(self, tokens: Sequence[int],
                 pages: Sequence[int]) -> None:
        """Index every *full* token block of a resident prompt."""
        ps = self.page_size
        h = _CHAIN_ROOT
        for i in range(len(tokens) // ps):
            blk = tuple(tokens[i * ps:(i + 1) * ps])
            key = hash((h, blk))
            pid = pages[i]
            if key not in self._full:
                self._full[key] = pid
                self._tokens.setdefault(pid, blk)
                self._keys.setdefault(pid, []).append(("full", key))
            if h not in self._next:
                self._next[h] = pid
                self._tokens.setdefault(pid, blk)
                self._keys.setdefault(pid, []).append(("next", h))
            h = key

    def match(self, tokens: Sequence[int], limit: int) -> PrefixMatch:
        """Longest resident prefix of ``tokens[:limit]``, full pages
        first, then a token-wise compare inside the divergent block."""
        ps = self.page_size
        pages: List[int] = []
        h = _CHAIN_ROOT
        for i in range(limit // ps):
            blk = tuple(tokens[i * ps:(i + 1) * ps])
            key = hash((h, blk))
            pid = self._full.get(key)
            if pid is None or self._tokens.get(pid) != blk:
                break
            pages.append(pid)
            h = key
        matched = len(pages) * ps
        cand = self._next.get(h)
        cow_src, cow_len = None, 0
        if cand is not None and matched < limit:
            cand_toks = self._tokens.get(cand, ())
            tail = tokens[matched:limit]
            for a, b in zip(cand_toks, tail):
                if a != b:
                    break
                cow_len += 1
            if cow_len:
                cow_src = cand
        return PrefixMatch(pages=tuple(pages), n_tokens=matched + cow_len,
                           cow_src=cow_src, cow_len=cow_len)

    def is_indexed(self, pid: int) -> bool:
        """True when the map holds entries pointing at page ``pid`` —
        the retention test: only indexed pages are worth keeping."""
        return pid in self._keys

    def forget(self, pid: int) -> None:
        for kind, key in self._keys.pop(pid, []):
            table = self._full if kind == "full" else self._next
            if table.get(key) == pid:
                del table[key]
        self._tokens.pop(pid, None)


class KVCachePool:
    """Free-list page allocator with refcounted, prefix-shared block
    tables (see module docstring for the invariants)."""

    def __init__(self, cfg: KVPoolConfig,
                 mm: Optional[MemoryManager] = None, *,
                 prefix_cache: bool = True, retain: bool = True) -> None:
        if cfg.n_pages < 2:
            raise ValueError("need at least one usable page besides scratch")
        self.cfg = cfg
        if cfg.n_shards > 1 and cfg.n_kv_heads % cfg.n_shards:
            raise ValueError(
                f"{cfg.n_kv_heads} kv heads do not head-shard over "
                f"{cfg.n_shards} mesh shards")
        self.mm = mm if mm is not None else MemoryManager(
            cfg.n_nodes, numa=cfg.numa)
        self.mm.plan_kv_pages(cfg.n_pages, cfg.page_bytes,
                              n_shards=cfg.n_shards)
        self._free: Dict[int, List[int]] = {}
        for pid in range(cfg.n_pages - 1, 0, -1):   # page 0 stays reserved
            self._free.setdefault(self.mm.kv_page_node(pid), []).append(pid)
        self._pages: Dict[int, List[int]] = {}      # seq uid -> logical order
        self._ref: Dict[int, int] = {}              # page id -> refcount
        self.prefix = PrefixCache(cfg.page_size) if prefix_cache else None
        self.retain = retain and prefix_cache
        #: cached-free LRU: prefix-indexed pages at refcount 0, oldest
        #: retirement first — reclaimed by ``_take_page`` when the free
        #: lists run dry, revived by ``share_pages`` on a prefix hit
        self._retained: "OrderedDict[int, None]" = OrderedDict()
        #: device page copies the engine must apply before the next
        #: forward pass: list of (src page id, dst page id)
        self.pending_copies: List[Tuple[int, int]] = []
        self.stats: Dict[str, int] = {
            "fresh_pages": 0,      # pages handed out from the free lists
            "shared_pages": 0,     # block-table entries served by sharing
            "cow_copies": 0,       # copy-on-write page clones
            "cached_tokens": 0,    # prompt tokens whose prefill was skipped
            "retention_hits": 0,   # refcount-0 pages revived by sharing
            "retained_evictions": 0,   # retained pages reclaimed when dry
        }
        #: optional registry-backed twins of ``stats`` (``bind_registry``)
        self._stat_counters: Dict[str, object] = {}

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    #: stats key -> metric name (docs/observability.md catalogue)
    STAT_METRICS: Dict[str, str] = {
        "fresh_pages": "kv_pool.pages_fresh",
        "shared_pages": "kv_pool.pages_shared",
        "cow_copies": "kv_pool.cow_copies",
        "cached_tokens": "prefix_cache.hit_tokens",
        "retention_hits": "kv_pool.retention_hits",
        "retained_evictions": "kv_pool.retained_evictions",
    }

    def bind_registry(self, registry) -> None:
        """Mirror every ``stats`` increment into ``registry`` counters
        (the legacy ``stats`` ints stay authoritative as thin views —
        benches reset them per run without touching the registry)."""
        self._stat_counters = {
            key: registry.counter(
                name, f"KVCachePool stats[{key!r}] (cumulative)").labels()
            for key, name in self.STAT_METRICS.items()}

    def _stat(self, key: str, n: int = 1) -> None:
        self.stats[key] += n
        c = self._stat_counters.get(key)
        if c is not None:
            c.inc(n)

    def free_pages_by_node(self) -> Dict[int, int]:
        """Truly-free pages per node (retained pages excluded — they
        are reclaimable but their bytes still hold cached prefixes)."""
        return {n: len(v) for n, v in self._free.items()}

    # ------------------------------------------------------------------
    def n_free(self) -> int:
        """Allocatable pages: truly free + retained (reclaimable)."""
        return sum(len(v) for v in self._free.values()) + len(self._retained)

    def n_retained(self) -> int:
        return len(self._retained)

    def n_live(self) -> int:
        return len(self._ref)

    def refcount(self, pid: int) -> int:
        return self._ref.get(pid, 0)

    def can_grow(self, uid: int, n_tokens: int) -> bool:
        need = self.cfg.pages_for(n_tokens) - len(self._pages.get(uid, []))
        return need <= self.n_free()

    def _take_page(self, node_hint: int) -> int:
        """Pop a free page, preferring the hinted node's pool; when the
        free lists are dry, evict the least-recently-retired cached
        page (its prefix entries die with it)."""
        order = sorted(self._free, key=lambda n: (n != node_hint,
                                                  -len(self._free[n]), n))
        for node in order:
            if self._free[node]:
                return self._free[node].pop()
        if self._retained:
            pid, _ = self._retained.popitem(last=False)   # LRU order
            if self.prefix is not None:
                self.prefix.forget(pid)
            self._stat("retained_evictions")
            return pid
        raise RuntimeError("KV pool exhausted")

    # ------------------------------------------------------------------
    def grow(self, uid: int, n_tokens: int, *, node_hint: int = 0) -> bool:
        """Ensure ``uid``'s block table covers ``n_tokens`` token slots.

        Shared (prefix-adopted) pages count toward coverage, so only the
        uncached tail allocates.  Returns False (allocating nothing)
        when the free pool cannot cover the growth — the scheduler then
        preempts somebody.
        """
        pages = self._pages.setdefault(uid, [])
        need = self.cfg.pages_for(n_tokens) - len(pages)
        if need <= 0:
            return True
        if self.cfg.pages_for(n_tokens) > self.cfg.max_pages_per_seq:
            raise ValueError(
                f"sequence of {n_tokens} tokens needs "
                f"{self.cfg.pages_for(n_tokens)} pages; pool only has "
                f"{self.cfg.max_pages_per_seq}")
        if need > self.n_free():
            return False
        for _ in range(need):
            pid = self._take_page(node_hint)
            self._ref[pid] = 1
            self._stat("fresh_pages")
            pages.append(pid)
        return True

    def free(self, uid: int) -> int:
        """Drop all of ``uid``'s page references; returns how many pages
        left the live set (shared pages survive until their last
        reference is released).  Refcount-0 pages that are prefix-
        indexed retire to the retained LRU instead of the free list, so
        repeat prompts can still hit them (``retain=``)."""
        pages = self._pages.pop(uid, [])
        freed = 0
        for pid in pages:       # stack top = last-written (warmest) page
            if pid == 0:        # window-recycled entry (release_below)
                continue
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                del self._ref[pid]
                freed += 1
                if (self.retain and self.prefix is not None
                        and self.prefix.is_indexed(pid)):
                    self._retained[pid] = None      # most recent at end
                    continue
                if self.prefix is not None:
                    self.prefix.forget(pid)
                self._free[self.mm.kv_page_node(pid)].append(pid)
        if freed and self.pending_copies:
            # a queued clone whose target died (admission rollback,
            # same-step preemption) must not clobber the page's next owner
            self.pending_copies = [(s, d) for s, d in self.pending_copies
                                   if d in self._ref]
        return freed

    #: protocol alias — ``share_pages`` attaches references,
    #: ``release`` drops them
    release = free

    def block_table(self, uid: int) -> List[int]:
        return list(self._pages.get(uid, []))

    # ------------------------------------------------------------------
    # prefix sharing protocol
    # ------------------------------------------------------------------
    def share_pages(self, uid: int, pages: Sequence[int]) -> None:
        """Append references to resident ``pages`` onto ``uid``'s block
        table (refcount + 1 each).  The pages become immutable for every
        holder until refcounts fall back to 1 (`ensure_writable`).  A
        *retained* page (refcount 0, still indexed) is revived: it
        leaves the cached-free LRU and comes back at refcount 1 — the
        cross-request prefix hit retention exists for."""
        table = self._pages.setdefault(uid, [])
        for pid in pages:
            if pid in self._ref:
                self._ref[pid] += 1
            elif pid != 0 and pid in self._retained:
                del self._retained[pid]
                self._ref[pid] = 1
                self._stat("retention_hits")
            else:
                raise ValueError(f"page {pid} is not live (cannot share)")
            table.append(pid)
            self._stat("shared_pages")

    def match_prefix(self, tokens: Sequence[int]) -> PrefixMatch:
        """Longest reusable resident prefix of a prompt.

        Capped at ``len(tokens) - 1``: at least one prompt token is
        always left to prefill, so (a) there are logits to sample the
        first output token from and (b) the page receiving the next
        write is never a shared one.
        """
        if self.prefix is None or len(tokens) < 2:
            return PrefixMatch()
        return self.prefix.match(tokens, len(tokens) - 1)

    def adopt_prefix(self, uid: int, match: PrefixMatch, *,
                     node_hint: int = 0) -> bool:
        """Attach a :meth:`match_prefix` result to a fresh sequence:
        share the full pages and, on mid-page divergence, allocate the
        copy-on-write clone (queueing its device copy).  Returns False —
        leaving ``uid`` untouched — when the clone cannot be allocated.
        """
        if self._pages.get(uid):
            raise ValueError(f"uid {uid} already holds pages")
        if match.cow_src is not None and self.n_free() == 0:
            return False
        if match.pages:
            self.share_pages(uid, match.pages)
        if match.cow_src is not None:
            dst = self._take_page(node_hint)
            self._ref[dst] = 1
            self._stat("fresh_pages")
            self._stat("cow_copies")
            # a divergence inside the FIRST block matches no full page,
            # so the clone may be the table's very first entry
            self._pages.setdefault(uid, []).append(dst)
            self.pending_copies.append((match.cow_src, dst))
        self._stat("cached_tokens", match.n_tokens)
        return True

    def register_prefix(self, uid: int, tokens: Sequence[int]) -> None:
        """Index ``uid``'s now-resident prompt pages for future reuse
        (call once the prefill that filled them has run)."""
        if self.prefix is not None:
            self.prefix.register(tokens, self._pages.get(uid, []))

    def ensure_writable(self, uid: int, pos: int, *,
                        node_hint: int = 0) -> bool:
        """Copy-on-write guard: make the page holding token slot ``pos``
        private to ``uid`` before it is written.  No-op for refcount-1
        pages; for shared pages, swaps in a fresh clone and queues the
        device copy.  Returns False when the pool has no page for the
        clone (caller preempts, exactly like a failed ``grow``)."""
        table = self._pages.get(uid, [])
        li = pos // self.cfg.page_size
        if li >= len(table):
            raise ValueError(f"uid {uid} pos {pos} beyond its block table")
        pid = table[li]
        if self._ref[pid] == 1:
            return True
        if self.n_free() == 0:
            return False
        dst = self._take_page(node_hint)
        self._ref[dst] = 1
        self._stat("fresh_pages")
        self._stat("cow_copies")
        self._ref[pid] -= 1
        table[li] = dst
        self.pending_copies.append((pid, dst))
        return True

    def truncate_to(self, uid: int, n_tokens: int) -> int:
        """Speculative-grant rollback: shrink ``uid``'s block table to
        the pages covering ``n_tokens`` token slots, releasing every
        trailing over-allocation.

        The scheduler grows a speculating sequence's table for the
        *worst-case* ``k`` draft tokens before the verify step; when the
        model rejects part of the draft the tail pages were granted for
        positions that will now never be written this round — this
        returns them.  Refcount-aware exactly like :meth:`free`: each
        dropped entry is one reference, a page only leaves the live set
        at refcount 0, and a prefix-indexed page retires to the
        retention LRU (bytes intact) rather than the free list.
        Returns the number of references dropped (0 when the table
        already fits — the all-accepted fast path).
        """
        table = self._pages.get(uid, [])
        keep = self.cfg.pages_for(n_tokens)
        dropped = 0
        while len(table) > keep:
            pid = table.pop()
            if pid == 0:            # window-recycled scratch entry
                continue
            dropped += 1
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                del self._ref[pid]
                if (self.retain and self.prefix is not None
                        and self.prefix.is_indexed(pid)):
                    self._retained[pid] = None
                    continue
                if self.prefix is not None:
                    self.prefix.forget(pid)
                self._free[self.mm.kv_page_node(pid)].append(pid)
        if dropped and self.pending_copies:
            # same rule as free(): a queued clone whose target just left
            # the live set must not clobber the page's next owner
            self.pending_copies = [(s, d) for s, d in self.pending_copies
                                   if d in self._ref]
        return dropped

    def release_below(self, uid: int, pos: int) -> int:
        """Sliding-window page recycling: drop ``uid``'s references to
        every page that is **fully** below token position ``pos`` (all
        ``page_size`` slots < pos), i.e. pages a window of ``pos``
        onward can never attend over again.

        The recycled block-table entries are rewritten to the scratch
        page 0 — the table keeps its logical length, so position ->
        page arithmetic for the still-resident tail is untouched; the
        out-of-window positions resolve to scratch, which window
        masking already excludes.  Refcount-aware exactly like
        :meth:`free`: a shared page just loses one reference, and a
        prefix-indexed page whose refcount hits 0 retires to the
        retention LRU (bytes intact for future prefix hits) instead of
        the free list.  Returns the number of references dropped.
        """
        table = self._pages.get(uid, [])
        full_below = min(pos // self.cfg.page_size, len(table))
        dropped = 0
        for li in range(full_below):
            pid = table[li]
            if pid == 0:                    # already recycled
                continue
            table[li] = 0
            dropped += 1
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                del self._ref[pid]
                if (self.retain and self.prefix is not None
                        and self.prefix.is_indexed(pid)):
                    self._retained[pid] = None
                    continue
                if self.prefix is not None:
                    self.prefix.forget(pid)
                self._free[self.mm.kv_page_node(pid)].append(pid)
        if dropped and self.pending_copies:
            # same rule as free(): a queued clone whose target just left
            # the live set must not clobber the page's next owner
            self.pending_copies = [(s, d) for s, d in self.pending_copies
                                   if d in self._ref]
        return dropped

    def drain_copies(self) -> List[Tuple[int, int]]:
        """Hand the queued (src, dst) page copies to the engine."""
        out, self.pending_copies = self.pending_copies, []
        return out

    def copy_row_plan(self, copies: Sequence[Tuple[int, int]], *,
                      pad_to_pages: Optional[int] = None,
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Expand drained page copies into flat (src_rows, dst_rows)
        index vectors for ONE per-layer pool buffer.

        The device cache holds each layer's pool as an independent
        ``(n_pages * page_size, H, D)`` buffer (the scan-escape layout),
        so a page copy is the same row-index gather+scatter on every
        layer's buffer — one plan serves all layers.  ``pad_to_pages``
        pads the plan with scratch-page self-copies (row ``0 -> 0`` is a
        no-op write into the reserved scratch page) so the engine's
        compiled copier sees bucketed shapes and compiles a handful of
        times, not once per copy count.
        """
        ps = self.cfg.page_size
        n = pad_to_pages if pad_to_pages is not None else len(copies)
        if n < len(copies):
            raise ValueError(f"pad_to_pages={n} < {len(copies)} copies")
        src = np.zeros((n * ps,), np.int32)
        dst = np.zeros((n * ps,), np.int32)
        for i, (s, d) in enumerate(copies):
            src[i * ps:(i + 1) * ps] = np.arange(s * ps, (s + 1) * ps)
            dst[i * ps:(i + 1) * ps] = np.arange(d * ps, (d + 1) * ps)
        return src, dst

    # ------------------------------------------------------------------
    # accounting
    # ------------------------------------------------------------------
    def live_bytes_per_node(self) -> Dict[int, int]:
        out = {n: 0 for n in self._free}
        for pid in self._ref:
            out[self.mm.kv_page_node(pid)] += self.cfg.page_bytes
        return out

    def capacity_bytes_per_node(self) -> Dict[int, int]:
        """Planned (pre-allocated) KV bytes per node, from the planner's
        pool peaks — what the node's carve-out actually reserves.  Under
        TP this sums the node's per-shard pools (a page's bytes live 1/S
        on each shard)."""
        out: Dict[int, int] = {}
        for p in self.mm.kv_pools:
            out[p.node_id or 0] = out.get(p.node_id or 0, 0) + p.peak
        return out

    def capacity_bytes_per_shard(self) -> Dict[int, int]:
        """Planned KV bytes per mesh shard (``{0: total}`` without TP):
        every shard reserves its head slice of every node's pages."""
        out: Dict[int, int] = {}
        for p in self.mm.kv_pools:
            sid = p.shard_id or 0
            out[sid] = out.get(sid, 0) + p.peak
        return out
