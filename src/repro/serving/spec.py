"""Model-free self-speculative decoding: prompt-lookup drafting.

ArcLight's decode loop is memory-bound — each step streams the whole
model once to emit ONE token.  Speculative decoding amortises that
stream: a cheap **drafter** guesses the next ``k`` tokens, one batched
**verify** forward scores all ``k + 1`` positions against the paged KV
cache, and the engine accepts the longest prefix of the draft that
matches the model's own greedy choices.  Every accepted draft token is
a decode forward the hardware never ran.

This module is the drafter half, and it is deliberately *model-free*
("Inference Acceleration for Large Language Models on CPUs",
PAPERS.md): no second network, no extra weights resident — the draft
is a **prompt lookup**.  LLM output constantly re-quotes its own
context (code identifiers, retrieved passages, chat boilerplate), so
the best guess for what follows the current suffix n-gram is whatever
followed its last occurrence earlier in prompt + generated history.

Byte parity is the engine's contract, not ours: the verify step emits
only tokens the model itself would have produced greedily (accepted
drafts all equal the model's argmax; the first mismatch is *replaced*
by the model's argmax — the "bonus" token).  A useless drafter costs
throughput, never correctness.

Kept dependency-free (no jax) so the host-side scheduler can import
:func:`lookahead_for` without touching device code.
"""

from __future__ import annotations

from typing import List, Sequence

#: default n-gram window bounds for :func:`propose` — try the longest
#: suffix first (most specific context), fall back to shorter ones
MIN_NGRAM = 1
MAX_NGRAM = 3

#: per-sequence speculation auto-off (ROADMAP: "use the live
#: spec.accept_rate signal"): once a sequence has AUTO_OFF_WINDOW
#: verify steps of history and its windowed acceptance rate sits below
#: AUTO_OFF_THRESHOLD, drafting for that sequence is pure overhead —
#: every rejected draft row is a KV page grant + a verify lane the
#: hardware ran for nothing — so the engine flips it off for the rest
#: of the sequence's life (preemption-restart included: the text that
#: defeated the drafter is still the text).
AUTO_OFF_WINDOW = 4
AUTO_OFF_THRESHOLD = 0.25


def propose(context: Sequence[int], k: int, *,
            min_ngram: int = MIN_NGRAM,
            max_ngram: int = MAX_NGRAM) -> List[int]:
    """Draft up to ``k`` tokens by prompt lookup over ``context``.

    Scans for the **longest** suffix n-gram (``max_ngram`` down to
    ``min_ngram`` tokens) that also occurs earlier in ``context``,
    preferring the **most recent** earlier occurrence, and returns the
    tokens that followed it.  Returns ``[]`` when nothing in the
    history continues the current suffix — the engine then falls back
    to a plain one-token decode for this sequence.

    O(len(context) * max_ngram) worst case per call; contexts here are
    a single request's prompt + generation, so this stays host-cheap
    next to a model forward.
    """
    n = len(context)
    if k <= 0 or n < min_ngram + 1:
        return []
    ctx = list(context)
    hi = min(max_ngram, n - 1)
    for size in range(hi, min_ngram - 1, -1):
        pattern = ctx[n - size:]
        for start in range(n - size - 1, -1, -1):
            if ctx[start:start + size] == pattern:
                # start <= n - size - 1, so at least one continuation
                # token always exists
                return ctx[start + size:start + size + k]
    return []


def lookahead_for(seq, k: int, max_len: int) -> int:
    """Worst-case draft lookahead the engine may use for ``seq`` this
    step — the page-grant bound the scheduler grows block tables by,
    and the cap the engine clamps :func:`propose` results to.

    Zero (no speculation) when:

    * ``k`` is zero — speculation disabled;
    * the sequence tripped the acceptance auto-off
      (``seq.spec_disabled``, see :func:`note_accept`);
    * the lane samples (``temperature > 0``) — acceptance compares
      drafts against the greedy argmax, which is only the lane's real
      output when the lane itself is greedy.  Byte parity over lenient
      acceptance, per the ISSUE contract;
    * the sequence is still prefilling.

    Otherwise ``k`` clamped so that (a) every speculative KV row lands
    strictly inside ``max_len`` (highest written position is
    ``next_pos - 1 + k``) and (b) a fully-accepted step (``k + 1``
    emitted tokens) cannot overshoot the request's ``max_new_tokens``.
    """
    if k <= 0 or seq.is_prefilling:
        return 0
    if getattr(seq, "spec_disabled", False):
        return 0
    sp = seq.request.sampling
    if sp.temperature > 0.0:
        return 0
    room_len = max_len - seq.next_pos - 1
    room_new = sp.max_new_tokens - len(seq.generated) - 1
    return max(0, min(k, room_len, room_new))


def note_accept(seq, accepted: int, drafted: int, *,
                window: int = AUTO_OFF_WINDOW,
                threshold: float = AUTO_OFF_THRESHOLD) -> bool:
    """Record one verify step's (accepted, drafted) outcome on ``seq``
    and apply the auto-off policy over the last ``window`` steps.

    Returns True exactly once — on the step that trips the breaker
    (``seq.spec_disabled`` goes False -> True) — so the caller can count
    ``spec.auto_disabled`` without double-counting.  Steps that drafted
    nothing (empty :func:`propose` result) carry no acceptance signal
    and are ignored.
    """
    if drafted <= 0 or seq.spec_disabled:
        return False
    seq.spec_recent.append((accepted, drafted))
    if len(seq.spec_recent) > window:
        del seq.spec_recent[0]
    if len(seq.spec_recent) < window:
        return False
    a = sum(x for x, _ in seq.spec_recent)
    m = sum(x for _, x in seq.spec_recent)
    if a < threshold * m:
        seq.spec_disabled = True
        return True
    return False
