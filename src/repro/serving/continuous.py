"""Continuous-batching serving engine over the paged KV pool.

The second half of the serving subsystem (see ``scheduler`` and
``kv_pool`` for the policy/memory halves): drives a slot-indexed
running batch through one compiled decode step —

* ``decode``  compiles **once** per engine: (B, 1) tokens + (B,)
  positions + (B, max_pages) block tables are all data, so requests
  join, leave, and get preempted without re-specialising XLA;
* ``prefill`` compiles once per padded prompt-bucket length (next
  power of two), with the real length a traced scalar — any prompt
  length reuses a handful of compilations;
* idle slots run with position −1: their K/V write lands on the
  reserved scratch page and their attention is fully masked, so a
  partially-empty batch is correct, just not free.

Interleaving policy: admissions (prefill) happen at the step boundary
before the decode is launched — the FCFS prefill/decode interleave of
arXiv:2407.00029 §3.  Requests can carry real arrival times
(``generate(..., arrivals=...)``): the engine sleeps only when nothing
is runnable, which is exactly the regime where continuous batching
beats the sequential length-bucket engine (it decodes early arrivals
while late ones are still in flight).
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Model
from .engine import Completion, Request
from .kv_pool import KVCachePool, KVPoolConfig
from .scheduler import ContinuousScheduler
from .sampler import sample, sample_grouped


def _pad_bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class ContinuousServingEngine:
    def __init__(self, model: Model, params: Any, *, max_len: int = 1024,
                 max_running: int = 8, page_size: int = 16,
                 n_pages: Optional[int] = None, n_nodes: int = 1,
                 numa: bool = True,
                 window_override: Optional[int] = None,
                 seed: int = 0) -> None:
        cfg = model.cfg
        self.model = model
        self.params = params
        self.max_len = max_len
        self.max_running = max_running
        self.page_size = page_size
        self.max_pages = -(-max_len // page_size)
        if n_pages is None:
            # page 0 scratch + a full pool: every slot can reach max_len.
            # Pass a smaller n_pages to trade memory for preemptions.
            n_pages = 1 + max_running * self.max_pages
        self.n_pages = n_pages
        self.window_override = window_override
        self._key = jax.random.PRNGKey(seed)

        self.pool = KVCachePool(KVPoolConfig(
            n_pages=n_pages, page_size=page_size, n_layers=cfg.n_layers,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            dtype_bytes=jnp.dtype(cfg.dtype).itemsize, n_nodes=n_nodes,
            numa=numa))
        self.scheduler = ContinuousScheduler(
            self.pool, max_running=max_running, max_len=max_len)
        self.cache = model.init_cache(max_running, max_len,
                                      page_size=page_size, n_pages=n_pages)

        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(
                p, c, t, pos, page_size=page_size,
                window_override=window_override))
        self._prefill_jits: Dict[int, Any] = {}

    # ------------------------------------------------------------------
    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _prefill_fn(self, padded_len: int):
        if padded_len not in self._prefill_jits:
            self._prefill_jits[padded_len] = jax.jit(
                lambda p, b, c, slot, plen: self.model.prefill_paged(
                    p, b, c, slot, plen, page_size=self.page_size,
                    window_override=self.window_override))
        return self._prefill_jits[padded_len]

    def _sync_tables(self) -> None:
        """Host block tables / positions -> device cache arrays."""
        bt = np.zeros((self.max_running, self.max_pages), np.int32)
        for slot, seq in self.scheduler.running.items():
            pages = self.pool.block_table(seq.uid)
            bt[slot, :len(pages)] = pages
        self.cache["block_tables"] = jnp.asarray(bt)

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request], *,
                 arrivals: Optional[Sequence[float]] = None,
                 ) -> List[Completion]:
        """Serve ``requests``; ``arrivals[i]`` (seconds from call start)
        delays request i's admission, modelling live traffic."""
        arrivals = list(arrivals or [0.0] * len(requests))
        if len(arrivals) != len(requests):
            raise ValueError("one arrival per request")
        for r in requests:
            if len(r.prompt) >= self.max_len:
                raise ValueError(
                    f"request {r.uid}: prompt of {len(r.prompt)} tokens "
                    f"does not fit max_len={self.max_len} (needs at least "
                    "one decode slot)")
        pending = sorted(zip(arrivals, range(len(requests))))
        sched, pool = self.scheduler, self.pool

        clock0 = time.perf_counter()
        now = 0.0
        prefill_s = decode_s = 0.0
        meta: Dict[int, Dict[str, float]] = {}   # uid -> timing stamps
        done: List[Completion] = []

        while pending or sched.has_work():
            now = time.perf_counter() - clock0
            while pending and pending[0][0] <= now:
                t_arr, i = pending.pop(0)
                seq = sched.submit(requests[i], arrival=t_arr)
                meta[seq.uid] = {"t0": clock0 + t_arr}

            plan = sched.step(now)
            for seq in plan.finished:
                m = meta[seq.uid]
                done.append(Completion(
                    uid=seq.uid, prompt_len=len(seq.request.prompt),
                    tokens=list(seq.generated),
                    latency_s=m["t1"] - m["t0"],
                    prefill_s=m.get("prefill", 0.0),
                    t0=m["t0"], t1=m["t1"]))

            if plan.prefills:
                self._sync_tables()
            for seq in plan.prefills:
                t0 = time.perf_counter()
                prompt = seq.full_prompt
                padded = _pad_bucket(len(prompt))
                toks = np.zeros((1, padded), np.int32)
                toks[0, :len(prompt)] = prompt
                logits, self.cache = self._prefill_fn(padded)(
                    self.params, {"tokens": jnp.asarray(toks)}, self.cache,
                    jnp.asarray(seq.slot, jnp.int32),
                    jnp.asarray(len(prompt), jnp.int32))
                tok = int(np.asarray(sample(
                    logits, seq.request.sampling, self._next_key()))[0, 0])
                seq.generated.append(tok)
                dt = time.perf_counter() - t0
                prefill_s += dt
                m = meta[seq.uid]
                m["prefill"] = m.get("prefill", 0.0) + dt
                if seq.is_done(self.max_len):
                    m["t1"] = time.perf_counter()

            if plan.decodes:
                t0 = time.perf_counter()
                self._sync_tables()
                pos = np.full((self.max_running,), -1, np.int32)
                fed = np.zeros((self.max_running, 1), np.int32)
                sps = [requests[0].sampling] * self.max_running  # dummy
                for seq in plan.decodes:
                    pos[seq.slot] = seq.next_pos - 1   # fed-token position
                    fed[seq.slot, 0] = seq.generated[-1]
                    sps[seq.slot] = seq.request.sampling
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(fed),
                    jnp.asarray(pos))
                toks = sample_grouped(logits, sps, self._next_key())
                for seq in plan.decodes:
                    seq.generated.append(int(toks[seq.slot, 0]))
                    if seq.is_done(self.max_len):
                        meta[seq.uid]["t1"] = time.perf_counter()
                decode_s += time.perf_counter() - t0
            elif not plan.prefills and pending:
                # nothing runnable: wait for the next arrival
                wait = pending[0][0] - (time.perf_counter() - clock0)
                if wait > 0:
                    time.sleep(min(wait, 0.05))

        wall = time.perf_counter() - clock0
        self.last_phase_s = {"wall_s": wall, "prefill_s": prefill_s,
                             "decode_s": max(decode_s, 1e-9)}
        return sorted(done, key=lambda c: c.uid)
