"""Synchronous batch driver over :class:`~repro.serving.core.EngineCore`.

``ContinuousServingEngine`` is the pre-declared-arrivals front of the
layered serving stack (runner / core / async — ``docs/serving.md``
"Layered architecture"): ``generate(requests, arrivals=)`` admits each
request onto the core's timeline at its arrival offset, loops
``EngineCore.step`` until everything drains, and parks on the injected
clock when nothing is runnable (no busy-wait — with a
:class:`~repro.serving.core.VirtualClock` idle waits cost zero wall
time).  All engine mechanics — continuous batching, paged KV pool,
prefix caching + retention, chunked prefill, copy-on-write, preemption
— live in the core; this file is only the loop.

For live traffic (submit/stream/cancel while the engine steps) use
:class:`~repro.serving.async_engine.AsyncEngine`, which drives the
same core from a background stepper thread.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

from ..models.transformer import Model
from .core import Clock, EngineCore
from .engine import Completion, Request


class ContinuousServingEngine:
    def __init__(self, model: Model, params: Any, *, max_len: int = 1024,
                 max_running: int = 8, page_size: int = 16,
                 n_pages: Optional[int] = None, n_nodes: int = 1,
                 numa: bool = True,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = True,
                 window_override: Optional[int] = None,
                 mesh=None, policy=None, quant=None, spec_decode: int = 0,
                 seed: int = 0, clock: Optional[Clock] = None,
                 registry=None, tracer=None) -> None:
        self.core = EngineCore(
            model, params, max_len=max_len, max_running=max_running,
            page_size=page_size, n_pages=n_pages, n_nodes=n_nodes,
            numa=numa, prefill_chunk=prefill_chunk,
            prefix_cache=prefix_cache, window_override=window_override,
            mesh=mesh, policy=policy, quant=quant,
            spec_decode=spec_decode, seed=seed, clock=clock,
            registry=registry, tracer=tracer)
        self.decode_gaps_s: List[float] = []
        self.last_phase_s: Dict[str, float] = {}

    # engine internals tests/benches reach for, now owned by the core
    model = property(lambda self: self.core.model)
    params = property(lambda self: self.core.params)
    registry = property(lambda self: self.core.registry)
    tracer = property(lambda self: self.core.tracer)
    pool = property(lambda self: self.core.pool)
    scheduler = property(lambda self: self.core.scheduler)
    max_len = property(lambda self: self.core.max_len)
    max_running = property(lambda self: self.core.max_running)
    page_size = property(lambda self: self.core.page_size)
    n_pages = property(lambda self: self.core.n_pages)
    _decode = property(lambda self: self.core.runner._decode)

    def generate(self, requests: Sequence[Request], *,
                 arrivals: Optional[Sequence[float]] = None,
                 ) -> List[Completion]:
        """Serve ``requests``; ``arrivals[i]`` (seconds from call start)
        delays request i's admission, modelling live traffic."""
        arrivals = list(arrivals or [0.0] * len(requests))
        if len(arrivals) != len(requests):
            raise ValueError("one arrival per request")
        core = self.core
        for r in requests:
            core.check_request(r)
        pending = sorted(zip(arrivals, range(len(requests))))
        core.reset_run_stats()
        clock0 = core.clock.now()
        done: List[Completion] = []
        while pending or core.has_work():
            now = core.clock.now() - clock0
            while pending and pending[0][0] <= now:
                t_arr, i = pending.pop(0)
                core.submit(requests[i], arrival=t_arr, t0=clock0 + t_arr)
            res = core.step(now)
            done.extend(res.finished)
            if res.idle and pending:
                # nothing runnable: park until the next arrival
                wait = pending[0][0] - (core.clock.now() - clock0)
                core.clock.sleep(wait)
        self.decode_gaps_s = core.decode_gaps_s
        # raw phase times — a zero-duration phase (prefill-only run,
        # virtual clock) passes through as 0.0; ``throughput_report``
        # now reports 0.0 tok/s for it instead of a clamp-distorted rate
        phase = core.phase_s
        self.last_phase_s = {
            "wall_s": core.clock.now() - clock0,
            "prefill_s": phase["prefill_s"],
            "decode_s": phase["decode_s"]}
        return sorted(done, key=lambda c: c.uid)
