"""Continuous-batching serving engine over the paged KV pool.

The second half of the serving subsystem (see ``scheduler`` and
``kv_pool`` for the policy/memory halves): drives a slot-indexed
running batch through one compiled decode step —

* ``decode``  compiles **once** per engine: (B, 1) tokens + (B,)
  positions + (B, max_pages) block tables are all data, so requests
  join, leave, and get preempted without re-specialising XLA;
* ``prefill`` compiles once per (padded chunk-bucket, context-page
  bucket) pair — chunk buckets are next-power-of-two lengths with the
  real length a traced scalar, so any prompt length reuses a handful
  of compilations;
* idle slots run with position −1: their K/V write lands on the
  reserved scratch page and their attention is fully masked, so a
  partially-empty batch is correct, just not free.

Prefill is **chunked** (``prefill_chunk=``): a long prompt runs
``prefill_chunk`` tokens per engine step, interleaved with everybody
else's decode, so admission can never stall the decode batch for more
than one chunk's worth of work (the admission-stall problem
arXiv:2407.00029 §3 attacks with prefill/decode overlap).  Each chunk
resumes at ``Sequence.n_prefilled`` via ``Model.prefill_paged(start=,
ctx_pages=)``; only the final chunk's logits sample a token.

Prefix caching (``prefix_cache=``): admission shares every resident
page whose token-block prefix matches the new prompt (see
``kv_pool.PrefixCache``), and the engine's duties are (a) applying the
pool's queued copy-on-write page copies to the device cache *before*
the step's forward passes, and (b) registering a prompt's pages in the
prefix map once its prefill completes — i.e. once the KV bytes are
actually resident, never earlier.

Interleaving policy: prefill chunks happen at the step boundary before
the decode is launched — the FCFS prefill/decode interleave of
arXiv:2407.00029 §3.  Requests can carry real arrival times
(``generate(..., arrivals=...)``): the engine sleeps only when nothing
is runnable, which is exactly the regime where continuous batching
beats the sequential length-bucket engine (it decodes early arrivals
while late ones are still in flight).  ``decode_gaps_s`` records the
wall gap between consecutive decode steps of a ``generate`` call — the
bench uses ``max()`` of it to show chunking bounds the decode stall a
long-prompt admission can cause.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Model
from .engine import Completion, Request
from .kv_pool import KVCachePool, KVPoolConfig
from .scheduler import ContinuousScheduler
from .sampler import sample, sample_grouped


def _pad_bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class ContinuousServingEngine:
    def __init__(self, model: Model, params: Any, *, max_len: int = 1024,
                 max_running: int = 8, page_size: int = 16,
                 n_pages: Optional[int] = None, n_nodes: int = 1,
                 numa: bool = True,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = True,
                 window_override: Optional[int] = None,
                 seed: int = 0) -> None:
        cfg = model.cfg
        self.model = model
        self.params = params
        self.max_len = max_len
        self.max_running = max_running
        self.page_size = page_size
        self.max_pages = -(-max_len // page_size)
        if n_pages is None:
            # page 0 scratch + a full pool: every slot can reach max_len.
            # Pass a smaller n_pages to trade memory for preemptions.
            n_pages = 1 + max_running * self.max_pages
        self.n_pages = n_pages
        self.window_override = window_override
        self._key = jax.random.PRNGKey(seed)

        self.pool = KVCachePool(KVPoolConfig(
            n_pages=n_pages, page_size=page_size, n_layers=cfg.n_layers,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            dtype_bytes=jnp.dtype(cfg.dtype).itemsize, n_nodes=n_nodes,
            numa=numa), prefix_cache=prefix_cache)
        self.scheduler = ContinuousScheduler(
            self.pool, max_running=max_running, max_len=max_len,
            prefill_chunk=prefill_chunk)
        self.cache = model.init_cache(max_running, max_len,
                                      page_size=page_size, n_pages=n_pages)

        # the cache argument is donated AND its page pool is a list of
        # per-layer buffers outside any scan carry (the scan-escape
        # layout, see ``Model.init_cache``): every step rebinds
        # ``self.cache`` to the returned tree, each layer's only cache
        # write is a row scatter, so XLA aliases each donated buffer to
        # its output and updates K/V in place — per-step cache traffic
        # is O(touched bytes), not O(pool bytes).  (The previous stacked
        # (L, ...) pool rode the layer scan's carry; the scan's xs->ys
        # copy put an O(pool bytes) floor on every decode step and
        # prefill chunk — measured to dominate chunked prefill at 641
        # pages.)
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(
                p, c, t, pos, page_size=page_size,
                window_override=window_override),
            donate_argnums=1)
        #: (padded chunk len, ctx page bucket) -> compiled prefill;
        #: ctx bucket 0 is the one-shot fresh-sequence path
        self._prefill_jits: Dict[Tuple[int, int], Any] = {}
        # batched CoW page copier over the per-layer buffer list: one
        # donated gather+scatter moves every queued page in-place on
        # every layer (un-jitted .at[].set would copy each buffer once
        # per page); row counts bucket so compiles stay few
        self._copy_rows = jax.jit(
            lambda layers, src, dst: jax.tree.map(
                lambda a: a.at[dst].set(a[src]), layers),
            donate_argnums=0)
        #: wall-clock gaps between consecutive decode steps of the last
        #: generate() call (bench: max gap == worst admission stall)
        self.decode_gaps_s: List[float] = []

    # ------------------------------------------------------------------
    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _prefill_fn(self, padded_len: int, ctx_pages: int):
        key = (padded_len, ctx_pages)
        if key not in self._prefill_jits:
            if ctx_pages:
                self._prefill_jits[key] = jax.jit(
                    lambda p, b, c, slot, plen, start:
                    self.model.prefill_paged(
                        p, b, c, slot, plen, start=start,
                        ctx_pages=ctx_pages, page_size=self.page_size,
                        window_override=self.window_override),
                    donate_argnums=2)
            else:
                self._prefill_jits[key] = jax.jit(
                    lambda p, b, c, slot, plen: self.model.prefill_paged(
                        p, b, c, slot, plen, page_size=self.page_size,
                        window_override=self.window_override),
                    donate_argnums=2)
        return self._prefill_jits[key]

    def _sync_tables(self) -> None:
        """Host block tables / positions -> device cache arrays."""
        bt = np.zeros((self.max_running, self.max_pages), np.int32)
        for slot, seq in self.scheduler.running.items():
            pages = self.pool.block_table(seq.uid)
            bt[slot, :len(pages)] = pages
        self.cache["block_tables"] = jnp.asarray(bt)

    def _apply_copies(self) -> None:
        """Apply the pool's queued copy-on-write page copies to the
        device cache (whole-page K/V row copies on every per-layer
        buffer, one compiled dispatch).  Must run after scheduling and
        before this step's forwards, so a resumed prefill or decode
        reads the cloned rows, not scratch."""
        copies = self.pool.drain_copies()
        if not copies:
            return
        src, dst = self.pool.copy_row_plan(
            copies, pad_to_pages=_pad_bucket(len(copies), lo=1))
        self.cache = dict(self.cache)
        self.cache["layers"] = self._copy_rows(
            self.cache["layers"], jnp.asarray(src), jnp.asarray(dst))

    def _run_prefill_chunk(self, seq) -> jax.Array:
        """Run one prefill chunk for ``seq``; returns last-token logits
        (meaningful only when the chunk completes the prompt)."""
        full = seq.full_prompt
        start = seq.n_prefilled
        n = self.scheduler.chunk_for(seq)
        padded = _pad_bucket(n)
        toks = np.zeros((1, padded), np.int32)
        toks[0, :n] = full[start:start + n]
        batch = {"tokens": jnp.asarray(toks)}
        if start == 0 and n == seq.prefill_target:
            # fresh one-shot prompt: nothing resident to attend over
            logits, self.cache = self._prefill_fn(padded, 0)(
                self.params, batch, self.cache,
                jnp.asarray(seq.slot, jnp.int32),
                jnp.asarray(n, jnp.int32))
        else:
            ctx_pages = min(
                _pad_bucket(-(-(start + n) // self.page_size), lo=1),
                self.max_pages)
            logits, self.cache = self._prefill_fn(padded, ctx_pages)(
                self.params, batch, self.cache,
                jnp.asarray(seq.slot, jnp.int32),
                jnp.asarray(n, jnp.int32),
                jnp.asarray(start, jnp.int32))
        seq.n_prefilled += n
        return logits

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request], *,
                 arrivals: Optional[Sequence[float]] = None,
                 ) -> List[Completion]:
        """Serve ``requests``; ``arrivals[i]`` (seconds from call start)
        delays request i's admission, modelling live traffic."""
        arrivals = list(arrivals or [0.0] * len(requests))
        if len(arrivals) != len(requests):
            raise ValueError("one arrival per request")
        for r in requests:
            if len(r.prompt) >= self.max_len:
                raise ValueError(
                    f"request {r.uid}: prompt of {len(r.prompt)} tokens "
                    f"does not fit max_len={self.max_len} (needs at least "
                    "one decode slot)")
        pending = sorted(zip(arrivals, range(len(requests))))
        sched, pool = self.scheduler, self.pool

        clock0 = time.perf_counter()
        now = 0.0
        prefill_s = decode_s = 0.0
        t_last_decode = None
        self.decode_gaps_s = []
        meta: Dict[int, Dict[str, float]] = {}   # uid -> timing stamps
        done: List[Completion] = []

        while pending or sched.has_work():
            now = time.perf_counter() - clock0
            while pending and pending[0][0] <= now:
                t_arr, i = pending.pop(0)
                seq = sched.submit(requests[i], arrival=t_arr)
                meta[seq.uid] = {"t0": clock0 + t_arr}

            plan = sched.step(now)
            self._apply_copies()
            for seq in plan.finished:
                m = meta[seq.uid]
                done.append(Completion(
                    uid=seq.uid, prompt_len=len(seq.request.prompt),
                    tokens=list(seq.generated),
                    latency_s=m["t1"] - m["t0"],
                    prefill_s=m.get("prefill", 0.0),
                    t0=m["t0"], t1=m["t1"]))

            if plan.prefills:
                self._sync_tables()
            for seq in plan.prefills:
                t0 = time.perf_counter()
                prompt = seq.full_prompt
                logits = self._run_prefill_chunk(seq)
                if not seq.is_prefilling:       # final chunk: sample
                    tok = int(np.asarray(sample(
                        logits, seq.request.sampling,
                        self._next_key()))[0, 0])
                    seq.generated.append(tok)
                    # prompt KV is resident now — index it for reuse
                    pool.register_prefix(seq.uid, prompt)
                dt = time.perf_counter() - t0
                prefill_s += dt
                m = meta[seq.uid]
                m["prefill"] = m.get("prefill", 0.0) + dt
                if not seq.is_prefilling and seq.is_done(self.max_len):
                    m["t1"] = time.perf_counter()

            if plan.decodes:
                t0 = time.perf_counter()
                self._sync_tables()
                pos = np.full((self.max_running,), -1, np.int32)
                fed = np.zeros((self.max_running, 1), np.int32)
                sps = [requests[0].sampling] * self.max_running  # dummy
                for seq in plan.decodes:
                    pos[seq.slot] = seq.next_pos - 1   # fed-token position
                    fed[seq.slot, 0] = seq.generated[-1]
                    sps[seq.slot] = seq.request.sampling
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(fed),
                    jnp.asarray(pos))
                toks = sample_grouped(logits, sps, self._next_key())
                for seq in plan.decodes:
                    seq.generated.append(int(toks[seq.slot, 0]))
                    if seq.is_done(self.max_len):
                        meta[seq.uid]["t1"] = time.perf_counter()
                t1 = time.perf_counter()
                if t_last_decode is not None:
                    self.decode_gaps_s.append(t1 - t_last_decode)
                t_last_decode = t1
                decode_s += t1 - t0
            elif not plan.prefills and pending:
                # nothing runnable: wait for the next arrival
                wait = pending[0][0] - (time.perf_counter() - clock0)
                if wait > 0:
                    time.sleep(min(wait, 0.05))

        wall = time.perf_counter() - clock0
        self.last_phase_s = {"wall_s": wall, "prefill_s": prefill_s,
                             "decode_s": max(decode_s, 1e-9)}
        return sorted(done, key=lambda c: c.uid)
