"""Engine-worker subprocess: ``python -m repro.serving.worker``.

One replica of the multi-replica serving stack: a private model +
``AsyncEngine`` + KV page pool behind an :class:`~repro.serving.http.
HttpFrontend`, owned and monitored by ``repro.serving.supervisor`` and
routed to by ``repro.serving.router`` (``launch/serve.py --http
--replicas N``).

Startup handshake: the worker binds (``--port 0`` picks a free port),
then prints one line ``READY port=<N>`` on stdout — the supervisor
blocks on that line before wiring the replica into the router's ring.
Shutdown is SIGTERM/SIGINT -> drain -> exit 0; anything harder
(SIGKILL, the fault-injection tests) is detected upstream as a broken
connection + dead process.

``--arch tiny`` is the subprocess twin of the benchmark suite's
``bench-tiny`` model (same config, same ``PRNGKey(0)`` params), so a
seeded greedy request answered over the wire must be byte-identical to
the in-process engine — the cross-process parity anchor for
``benchmarks/serving_bench.py`` and ``tests/test_router.py``.  Any
registry arch id serves its REDUCED variant, matching
``repro.launch.serve``.
"""

from __future__ import annotations

import argparse
import signal
import sys
import threading


def build_tiny(seed: int = 0):
    """The benchmark suite's ``bench-tiny`` model (see
    ``benchmarks/serving_bench.py``): deterministic params from
    ``PRNGKey(seed)`` so every process derives identical weights."""
    import jax
    import jax.numpy as jnp

    from ..models import ModelConfig, build_model
    cfg = ModelConfig(name="bench-tiny", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port (reported via READY)")
    ap.add_argument("--arch", default="tiny",
                    help="'tiny' (bench-tiny model) or a registry arch "
                         "id served reduced")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--max-running", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=None)
    ap.add_argument("--prefill-chunk", type=int, default=None)
    ap.add_argument("--no-prefix-cache", action="store_true")
    ap.add_argument("--quant", choices=("none", "q4"), default="none",
                    help="weight format (docs/quantization.md)")
    ap.add_argument("--kv-dtype", choices=("fp32", "int8"),
                    default="fp32", help="KV page format")
    ap.add_argument("--spec-decode", type=int, default=0,
                    help="self-speculative decoding lookahead k "
                         "(0 disables; docs/serving.md)")
    ap.add_argument("--token-timeout", type=float, default=120.0)
    args = ap.parse_args(argv)

    if args.arch == "tiny":
        model, params = build_tiny(args.seed)
    else:
        import dataclasses

        import jax
        import jax.numpy as jnp

        from ..configs import get_config
        from ..models import build_model, reduced_config
        cfg = reduced_config(get_config(args.arch))
        cfg = dataclasses.replace(cfg, dtype=jnp.float32,
                                  vocab_size=max(cfg.vocab_size, 259))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(args.seed))

    from ..data.tokenizer import ByteTokenizer
    from . import faults
    from .async_engine import AsyncEngine
    from .http import HttpFrontend

    # fault-injection harness: workers inherit REPRO_FAULTS from the
    # launching shell / supervisor (no-op unless set; docs/robustness.md)
    faults.load_env()
    quant = None
    if args.quant != "none" or args.kv_dtype != "fp32":
        from ..quant.policy import QuantPolicy
        quant = QuantPolicy(weights=args.quant, kv_dtype=args.kv_dtype)
    engine = AsyncEngine(
        model, params, max_len=args.max_len, max_running=args.max_running,
        page_size=args.page_size, n_pages=args.n_pages,
        prefill_chunk=args.prefill_chunk,
        prefix_cache=not args.no_prefix_cache, quant=quant,
        spec_decode=args.spec_decode)
    fe = HttpFrontend(engine, tokenizer=ByteTokenizer(), host=args.host,
                      port=args.port, token_timeout=args.token_timeout)
    fe.start()

    stop = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: stop.set())
    # the supervisor's handshake line — keep the format stable
    print(f"READY port={fe.port}", flush=True)
    stop.wait()
    fe.close(shutdown_backend=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
