"""HTTP serving front-end: OpenAI-style completions over the engine seam.

The network edge of the serving stack (``docs/serving.md`` "HTTP
serving front-end").  Dependency-free by design — stdlib
``http.server`` threads, matching the repo's no-deps discipline — and
**backend-agnostic**: anything exposing the ``AsyncEngine`` caller
surface (``submit(request, on_token=)`` / ``stream`` / ``result`` /
``cancel`` / ``registry`` / ``shutdown``) can sit behind it.  In
practice that is either a local :class:`~repro.serving.async_engine.
AsyncEngine` (single-process serving) or a
:class:`~repro.serving.router.Router` fanning out to engine-worker
subprocesses (``launch/serve.py --http --replicas N``).

Endpoints:

``POST /v1/completions``
    JSON body -> :class:`~repro.serving.engine.Request`.  ``prompt``
    is a string (encoded with the frontend's tokenizer) or a raw token
    id list; ``max_tokens`` / ``temperature`` / ``top_k`` / ``eos_id``
    map onto :class:`~repro.serving.sampler.SamplingParams`;
    ``priority`` (``interactive``/``batch``) and ``deadline_ms``
    (remaining latency budget — also accepted as ``X-Priority`` /
    ``X-Deadline-Ms`` headers) feed the SLO-aware scheduler
    (``docs/robustness.md``).  With
    ``"stream": true`` the response is Server-Sent Events: one
    ``data:`` frame per sampled token (driven by the backend's token
    feed, so frames leave as the engine samples), a ``done`` frame with
    usage/timing, then ``data: [DONE]``.  Without it, the handler
    blocks on ``result()`` and returns one JSON completion document.

``GET /healthz``
    Liveness (and, behind a router, per-replica health).

``GET /metrics`` / ``GET /metrics.json``
    The backend registry's Prometheus text exposition / JSON snapshot
    (``repro.obs`` — the snapshot validates under
    ``repro.obs.validate``).

Failure semantics: a client that disconnects mid-stream triggers
``backend.cancel(handle)`` on the next frame write, so an abandoned
stream frees its engine slot and KV pages (asserted via ``/metrics``
in ``tests/test_http_serving.py``).  A FAILED handle surfaces as an
SSE ``error`` frame (streaming) or an HTTP 500 JSON error document
(non-streaming).  Every failure path — 400/429/500/503/504 bodies and
SSE error frames alike — carries the SAME structured shape
(:func:`error_payload`: type, message, chained cause, retryable), and
retryable refusals (429 shed, 503, 504 timeout) add a ``Retry-After``
header.  Overload protection: ``max_inflight`` / ``max_queue_depth``
bound admission and shed excess load with 429 (counted as
``http.shed``) instead of queueing into a latency cliff.
"""

from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional, Tuple

from . import faults
from .async_engine import DeadlineExceededError
from .engine import PRIORITIES, Request
from .sampler import SamplingParams

#: terminal SSE frame — after it the stream holds nothing more
SSE_DONE = b"data: [DONE]\n\n"


def sse_frame(obj: Any) -> bytes:
    """One SSE ``data:`` frame.  Compact separators + sorted keys keep
    the bytes deterministic, so the wire-parity test can byte-compare
    frames against locally rebuilt ones."""
    body = json.dumps(obj, separators=(",", ":"), sort_keys=True)
    return b"data: " + body.encode("utf-8") + b"\n\n"


def _is_retryable(exc: BaseException) -> bool:
    """Would the same request plausibly succeed if re-sent?  Shedding
    and timeouts are transient (yes); bad requests are permanent (no);
    a blown deadline is unretryable *by definition* — the budget is
    spent no matter who retries.  Walks the cause chain so a wrapped
    ``DeadlineExceededError`` keeps its verdict."""
    seen = 0
    e: Optional[BaseException] = exc
    while e is not None and seen < 8:
        if isinstance(e, (BadRequest, DeadlineExceededError)):
            return False
        if isinstance(e, (Overloaded, TimeoutError)):
            return True
        e = e.__cause__
        seen += 1
    return False


def error_payload(exc: BaseException,
                  retryable: Optional[bool] = None) -> Dict[str, Any]:
    """JSON error document — the ONE error shape every HTTP failure
    path returns (non-stream status bodies, SSE ``error`` frames, shed
    responses): type + message + chained cause (worker death, bad
    request, ...) + whether a client should re-send
    (:func:`_is_retryable` unless the caller already knows)."""
    cause = exc.__cause__
    return {"error": {
        "type": type(exc).__name__,
        "message": str(exc),
        "cause": (f"{type(cause).__name__}: {cause}"
                  if cause is not None else None),
        "retryable": (_is_retryable(exc) if retryable is None
                      else bool(retryable)),
    }}


class BadRequest(ValueError):
    """Client error in a completion body (HTTP 400)."""


class Overloaded(RuntimeError):
    """Admission refused by the front-end's bounded-admission gate
    (HTTP 429 + ``Retry-After``): the queue or inflight cap is hit and
    taking one more request would only grow latency for everyone.
    Always retryable — after ``Retry-After`` seconds."""


def parse_completion_body(
        raw: bytes, tokenizer=None,
) -> Tuple[List[int], SamplingParams, bool, Dict[str, Any]]:
    """Parse a ``/v1/completions`` body into ``(prompt token ids,
    SamplingParams, stream?, slo)``.  Raises :class:`BadRequest` on
    anything the engine could never serve.

    ``slo`` carries the request's overload-protection fields:
    ``priority`` (``interactive``/``batch``, default interactive) and
    ``deadline_ms`` (remaining latency budget in milliseconds, or None)
    — the wire always speaks *relative* budgets so hops never need
    synchronised clocks.  A budget that is already <= 0 is rejected
    here (400, not retryable): serving it would only produce an answer
    past its deadline."""
    try:
        doc = json.loads(raw.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise BadRequest(f"body is not JSON: {e}") from e
    if not isinstance(doc, dict):
        raise BadRequest("body must be a JSON object")
    prompt = doc.get("prompt")
    if isinstance(prompt, str):
        if tokenizer is None:
            raise BadRequest("string prompt needs a tokenizer; send "
                             "token ids")
        tokens = list(tokenizer.encode(prompt))
    elif (isinstance(prompt, list) and prompt
            and all(isinstance(t, int) and not isinstance(t, bool)
                    for t in prompt)):
        tokens = list(prompt)
    else:
        raise BadRequest("prompt must be a non-empty string or a list "
                         "of token ids")
    try:
        sp = SamplingParams(
            temperature=float(doc.get("temperature", 0.0)),
            top_k=int(doc.get("top_k", 0)),
            max_new_tokens=int(doc.get("max_tokens", 16)),
            eos_id=(int(doc["eos_id"])
                    if doc.get("eos_id") is not None else None))
    except (TypeError, ValueError) as e:
        raise BadRequest(f"bad sampling field: {e}") from e
    if sp.max_new_tokens < 1:
        raise BadRequest("max_tokens must be >= 1")
    stream = bool(doc.get("stream", False))
    priority = doc.get("priority", "interactive")
    if priority not in PRIORITIES:
        raise BadRequest(f"priority must be one of {list(PRIORITIES)}, "
                         f"got {priority!r}")
    deadline_ms: Optional[float] = None
    if doc.get("deadline_ms") is not None:
        try:
            deadline_ms = float(doc["deadline_ms"])
        except (TypeError, ValueError) as e:
            raise BadRequest(f"bad deadline_ms: {e}") from e
        if deadline_ms <= 0:
            raise BadRequest("deadline_ms must be > 0 (budget already "
                             "spent)")
    return tokens, sp, stream, {"priority": priority,
                                "deadline_ms": deadline_ms}


class _ServingHTTPServer(ThreadingHTTPServer):
    daemon_threads = True       # in-flight handlers die with the server
    allow_reuse_address = True
    frontend: "HttpFrontend"


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server: _ServingHTTPServer

    def log_message(self, *args: Any) -> None:     # quiet by default
        pass

    # -- GET: health + metrics -----------------------------------------
    def do_GET(self) -> None:
        fe = self.server.frontend
        if self.path == "/healthz":
            self._send_json(200, fe.health())
        elif self.path == "/metrics":
            body = fe.registry.to_prometheus().encode("utf-8")
            self._send(200, body,
                       "text/plain; version=0.0.4; charset=utf-8")
        elif self.path == "/metrics.json":
            if faults.ACTIVE:       # chaos: slow load-probe target
                faults.maybe_sleep("http.scrape_ms")
            self._send(200, fe.registry.snapshot_json().encode("utf-8"),
                       "application/json")
        else:
            self._send_json(404, {"error": {"type": "NotFound",
                                            "message": self.path}})

    # -- POST: completions ----------------------------------------------
    def do_POST(self) -> None:
        fe = self.server.frontend
        if self.path != "/v1/completions":
            self._send_json(404, {"error": {"type": "NotFound",
                                            "message": self.path}})
            return
        try:
            n = int(self.headers.get("Content-Length", 0))
            tokens, sp, stream, slo = parse_completion_body(
                self.rfile.read(n), fe.tokenizer)
            # header fallback for clients that can't touch the body
            # (proxies stamping budgets); body fields win
            if ("priority" not in slo or slo["priority"] == "interactive") \
                    and self.headers.get("X-Priority"):
                prio = self.headers["X-Priority"].strip()
                if prio not in PRIORITIES:
                    raise BadRequest(
                        f"X-Priority must be one of {list(PRIORITIES)}, "
                        f"got {prio!r}")
                slo["priority"] = prio
            if (slo.get("deadline_ms") is None
                    and self.headers.get("X-Deadline-Ms")):
                try:
                    dl = float(self.headers["X-Deadline-Ms"])
                except ValueError as e:
                    raise BadRequest(f"bad X-Deadline-Ms: {e}") from e
                if dl <= 0:
                    raise BadRequest("X-Deadline-Ms must be > 0")
                slo["deadline_ms"] = dl
        except BadRequest as e:
            fe._c_bad.inc()
            self._send_json(400, error_payload(e))
            return
        dl_ms = slo.get("deadline_ms")
        req = Request(uid=0, prompt=tokens, sampling=sp,
                      priority=slo["priority"],
                      deadline_s=dl_ms / 1e3 if dl_ms is not None else None)
        # bounded admission: shed NOW, with a structured 429 the client
        # can act on, instead of queueing into a latency cliff
        if not fe._admit():
            fe._c_shed.inc()
            self._send_json(
                429,
                error_payload(Overloaded(
                    f"admission refused: {fe.admission_state()}"),
                    retryable=True),
                headers={"Retry-After": str(fe.retry_after_s)})
            return
        fe._c_requests.inc()
        try:
            if stream:
                self._stream_completion(fe, req)
            else:
                self._block_completion(fe, req)
        finally:
            fe._release()

    # ------------------------------------------------------------------
    def _stream_completion(self, fe: "HttpFrontend", req: Request) -> None:
        backend = fe.backend
        try:
            handle = backend.submit(req)
        except Exception as e:                      # noqa: BLE001
            # backend refused/unreachable — a later retry may find it
            # healthy again (router readmission, supervisor respawn)
            self._send_json(503, error_payload(e, retryable=True),
                            headers={"Retry-After": str(fe.retry_after_s)})
            return
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        self.close_connection = True
        t0 = time.perf_counter()
        t_first: Optional[float] = None
        n_sent = 0
        try:
            for tok in backend.stream(handle, timeout=fe.token_timeout):
                if t_first is None:
                    t_first = time.perf_counter()
                n_sent += 1
                # chaos fault: silently lose this frame while still
                # counting it — the done frame then over-reports and a
                # router downstream detects the mismatch
                if faults.ACTIVE and faults.should_fire("http.drop_sse"):
                    continue
                self.wfile.write(sse_frame(fe.token_frame(tok)))
                self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError, OSError):
            # the CLIENT went away: free the engine slot + KV pages
            backend.cancel(handle)
            fe._c_disconnects.inc()
            return
        except BaseException as e:                  # noqa: BLE001
            # FAILED handle (engine/worker error) or token timeout:
            # surface the cause in-band, then end the stream
            if isinstance(e, TimeoutError):
                backend.cancel(handle)
            fe._c_failed.inc()      # before [DONE]: a client that saw
            self._try_write(        # the frame can already scrape it
                sse_frame(error_payload(e)) + SSE_DONE)
            return
        t1 = time.perf_counter()
        done = {"done": {
            "prompt_tokens": len(req.prompt),
            "completion_tokens": n_sent,
            "finish_reason": "length",
            "ttft_ms": round(((t_first or t1) - t0) * 1e3, 3),
            "latency_ms": round((t1 - t0) * 1e3, 3),
        }}
        self._try_write(sse_frame(done) + SSE_DONE)

    def _block_completion(self, fe: "HttpFrontend", req: Request) -> None:
        backend = fe.backend
        handle = None
        try:
            handle = backend.submit(req)
            comp = backend.result(handle, timeout=fe.request_timeout)
        except TimeoutError as e:
            if handle is not None:
                backend.cancel(handle)
            fe._c_failed.inc()
            self._send_json(504, error_payload(e, retryable=True),
                            headers={"Retry-After": str(fe.retry_after_s)})
            return
        except BaseException as e:                  # noqa: BLE001
            fe._c_failed.inc()
            # a blown deadline is a timeout to the client (504), just
            # never a retryable one; anything else is a plain 500
            cause, n = e, 0
            while (cause is not None and n < 8 and
                   not isinstance(cause, DeadlineExceededError)):
                cause, n = cause.__cause__, n + 1
            status = 504 if isinstance(cause, DeadlineExceededError) \
                else 500
            self._send_json(status, error_payload(e))
            return
        text = (fe.tokenizer.decode(comp.tokens)
                if fe.tokenizer is not None else "")
        self._send_json(200, {
            "id": f"cmpl-{comp.uid}",
            "object": "text_completion",
            "choices": [{"index": 0, "text": text,
                         "tokens": list(comp.tokens),
                         "finish_reason": "length"}],
            "usage": {"prompt_tokens": comp.prompt_len,
                      "completion_tokens": len(comp.tokens),
                      "total_tokens": comp.prompt_len + len(comp.tokens)},
            "timing": {"ttft_ms": round((comp.t_first - comp.t0) * 1e3, 3),
                       "latency_ms": round(comp.latency_s * 1e3, 3)},
        })

    # ------------------------------------------------------------------
    def _send(self, status: int, body: bytes, ctype: str,
              headers: Optional[Dict[str, str]] = None) -> None:
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, status: int, doc: Dict[str, Any],
                   headers: Optional[Dict[str, str]] = None) -> None:
        self._send(status, json.dumps(doc, sort_keys=True).encode("utf-8"),
                   "application/json", headers)

    def _try_write(self, data: bytes) -> None:
        try:
            self.wfile.write(data)
            self.wfile.flush()
        except OSError:
            pass        # client already gone; nothing left to tell it


class HttpFrontend:
    """Threaded HTTP server over one engine-like backend.

    ``start()`` binds and serves on a background thread (``port=0``
    picks a free port — ``self.port`` is the bound one); ``close()``
    stops accepting, joins the server thread and optionally shuts the
    backend down.  One handler thread per connection (stdlib
    ``ThreadingHTTPServer``), so a streaming client parks only its own
    thread while the engine stepper keeps serving everyone else.
    """

    def __init__(self, backend: Any, *, tokenizer: Any = None,
                 host: str = "127.0.0.1", port: int = 0,
                 token_timeout: float = 120.0,
                 request_timeout: float = 600.0,
                 max_inflight: Optional[int] = None,
                 max_queue_depth: Optional[int] = None,
                 retry_after_s: float = 1.0) -> None:
        self.backend = backend
        self.tokenizer = tokenizer
        self.token_timeout = token_timeout
        self.request_timeout = request_timeout
        #: bounded admission (None = unbounded, the pre-SLO behavior):
        #: ``max_inflight`` caps completion requests this frontend is
        #: concurrently serving; ``max_queue_depth`` caps the backend
        #: scheduler's waiting queue (read from the shared registry's
        #: ``scheduler.queue_depth`` gauge — in-process backends only;
        #: a router front door has no scheduler and relies on the
        #: inflight cap)
        if max_inflight is not None and max_inflight < 1:
            raise ValueError("max_inflight must be >= 1")
        if max_queue_depth is not None and max_queue_depth < 0:
            raise ValueError("max_queue_depth must be >= 0")
        self.max_inflight = max_inflight
        self.max_queue_depth = max_queue_depth
        self.retry_after_s = retry_after_s
        self._inflight = 0
        self._admission_lock = threading.Lock()
        self._server = _ServingHTTPServer((host, port), _Handler)
        self._server.frontend = self
        self.host, self.port = self._server.server_address[:2]
        self._thread: Optional[threading.Thread] = None
        reg = self.registry
        self._c_requests = reg.counter(
            "http.requests", "completion requests accepted").labels()
        self._c_bad = reg.counter(
            "http.bad_requests", "completion bodies rejected (400)"
            ).labels()
        self._c_failed = reg.counter(
            "http.failed", "completions that surfaced an error/timeout"
            ).labels()
        self._c_disconnects = reg.counter(
            "http.client_disconnects",
            "streams cancelled because the client went away").labels()
        self._c_shed = reg.counter(
            "http.shed",
            "completion requests refused with 429 by bounded admission"
            ).labels()
        self._g_inflight = reg.gauge(
            "http.inflight",
            "completion requests this frontend is currently serving"
            ).labels()
        self._g_queue_depth = reg.get("scheduler.queue_depth")

    # -- bounded admission ----------------------------------------------
    def _admit(self) -> bool:
        """Take one admission slot, or refuse.  Checks the inflight cap
        (frontend-local) and the scheduler queue-depth cap (in-process
        backends).  The caller MUST pair every True with ``_release``."""
        with self._admission_lock:
            if (self.max_inflight is not None
                    and self._inflight >= self.max_inflight):
                return False
            if (self.max_queue_depth is not None
                    and self._g_queue_depth is not None
                    and self._g_queue_depth.value()
                    >= self.max_queue_depth):
                return False
            self._inflight += 1
            self._g_inflight.set(float(self._inflight))
            return True

    def _release(self) -> None:
        with self._admission_lock:
            self._inflight -= 1
            self._g_inflight.set(float(self._inflight))

    def admission_state(self) -> str:
        """Human-readable gate state for shed messages/logs."""
        q = (self._g_queue_depth.value()
             if self._g_queue_depth is not None else None)
        return (f"inflight={self._inflight}/{self.max_inflight} "
                f"queue_depth={q if q is not None else 'n/a'}"
                f"/{self.max_queue_depth}")

    @property
    def registry(self):
        return self.backend.registry

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def health(self) -> Dict[str, Any]:
        doc = {"status": "ok", "backend": type(self.backend).__name__}
        backend_health = getattr(self.backend, "health", None)
        if callable(backend_health):
            doc.update(backend_health())
        return doc

    def token_frame(self, tok: int) -> Dict[str, Any]:
        """The per-token SSE payload (kept tiny and deterministic)."""
        text = (self.tokenizer.decode([tok])
                if self.tokenizer is not None else "")
        return {"index": 0, "text": text, "token": int(tok)}

    # ------------------------------------------------------------------
    def start(self) -> "HttpFrontend":
        if self._thread is not None:
            raise RuntimeError("frontend already started")
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="http-frontend",
            daemon=True)
        self._thread.start()
        return self

    def close(self, *, shutdown_backend: bool = False) -> None:
        """Stop serving (idempotent).  In-flight handler threads are
        daemons riding the backend's streams; shutting the backend down
        (``shutdown_backend=True``) terminates their handles too."""
        if self._thread is not None:
            self._server.shutdown()
            self._thread.join(timeout=10.0)
            self._thread = None
        self._server.server_close()
        if shutdown_backend:
            self.backend.shutdown()

    def __enter__(self) -> "HttpFrontend":
        return self.start() if self._thread is None else self

    def __exit__(self, *exc: Any) -> None:
        self.close()
