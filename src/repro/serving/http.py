"""HTTP serving front-end: OpenAI-style completions over the engine seam.

The network edge of the serving stack (``docs/serving.md`` "HTTP
serving front-end").  Dependency-free by design — stdlib
``http.server`` threads, matching the repo's no-deps discipline — and
**backend-agnostic**: anything exposing the ``AsyncEngine`` caller
surface (``submit(request, on_token=)`` / ``stream`` / ``result`` /
``cancel`` / ``registry`` / ``shutdown``) can sit behind it.  In
practice that is either a local :class:`~repro.serving.async_engine.
AsyncEngine` (single-process serving) or a
:class:`~repro.serving.router.Router` fanning out to engine-worker
subprocesses (``launch/serve.py --http --replicas N``).

Endpoints:

``POST /v1/completions``
    JSON body -> :class:`~repro.serving.engine.Request`.  ``prompt``
    is a string (encoded with the frontend's tokenizer) or a raw token
    id list; ``max_tokens`` / ``temperature`` / ``top_k`` / ``eos_id``
    map onto :class:`~repro.serving.sampler.SamplingParams`.  With
    ``"stream": true`` the response is Server-Sent Events: one
    ``data:`` frame per sampled token (driven by the backend's token
    feed, so frames leave as the engine samples), a ``done`` frame with
    usage/timing, then ``data: [DONE]``.  Without it, the handler
    blocks on ``result()`` and returns one JSON completion document.

``GET /healthz``
    Liveness (and, behind a router, per-replica health).

``GET /metrics`` / ``GET /metrics.json``
    The backend registry's Prometheus text exposition / JSON snapshot
    (``repro.obs`` — the snapshot validates under
    ``repro.obs.validate``).

Failure semantics: a client that disconnects mid-stream triggers
``backend.cancel(handle)`` on the next frame write, so an abandoned
stream frees its engine slot and KV pages (asserted via ``/metrics``
in ``tests/test_http_serving.py``).  A FAILED handle surfaces as an
SSE ``error`` frame (streaming) or an HTTP 500 JSON error document
(non-streaming), both carrying the chained cause.
"""

from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional, Tuple

from .engine import Request
from .sampler import SamplingParams

#: terminal SSE frame — after it the stream holds nothing more
SSE_DONE = b"data: [DONE]\n\n"


def sse_frame(obj: Any) -> bytes:
    """One SSE ``data:`` frame.  Compact separators + sorted keys keep
    the bytes deterministic, so the wire-parity test can byte-compare
    frames against locally rebuilt ones."""
    body = json.dumps(obj, separators=(",", ":"), sort_keys=True)
    return b"data: " + body.encode("utf-8") + b"\n\n"


def error_payload(exc: BaseException) -> Dict[str, Any]:
    """JSON error document carrying the exception AND its chained
    cause (worker death, bad request, ...) over the wire."""
    cause = exc.__cause__
    return {"error": {
        "type": type(exc).__name__,
        "message": str(exc),
        "cause": (f"{type(cause).__name__}: {cause}"
                  if cause is not None else None),
    }}


class BadRequest(ValueError):
    """Client error in a completion body (HTTP 400)."""


def parse_completion_body(raw: bytes, tokenizer=None,
                          ) -> Tuple[List[int], SamplingParams, bool]:
    """Parse a ``/v1/completions`` body into ``(prompt token ids,
    SamplingParams, stream?)``.  Raises :class:`BadRequest` on
    anything the engine could never serve."""
    try:
        doc = json.loads(raw.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise BadRequest(f"body is not JSON: {e}") from e
    if not isinstance(doc, dict):
        raise BadRequest("body must be a JSON object")
    prompt = doc.get("prompt")
    if isinstance(prompt, str):
        if tokenizer is None:
            raise BadRequest("string prompt needs a tokenizer; send "
                             "token ids")
        tokens = list(tokenizer.encode(prompt))
    elif (isinstance(prompt, list) and prompt
            and all(isinstance(t, int) and not isinstance(t, bool)
                    for t in prompt)):
        tokens = list(prompt)
    else:
        raise BadRequest("prompt must be a non-empty string or a list "
                         "of token ids")
    try:
        sp = SamplingParams(
            temperature=float(doc.get("temperature", 0.0)),
            top_k=int(doc.get("top_k", 0)),
            max_new_tokens=int(doc.get("max_tokens", 16)),
            eos_id=(int(doc["eos_id"])
                    if doc.get("eos_id") is not None else None))
    except (TypeError, ValueError) as e:
        raise BadRequest(f"bad sampling field: {e}") from e
    if sp.max_new_tokens < 1:
        raise BadRequest("max_tokens must be >= 1")
    stream = bool(doc.get("stream", False))
    return tokens, sp, stream


class _ServingHTTPServer(ThreadingHTTPServer):
    daemon_threads = True       # in-flight handlers die with the server
    allow_reuse_address = True
    frontend: "HttpFrontend"


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server: _ServingHTTPServer

    def log_message(self, *args: Any) -> None:     # quiet by default
        pass

    # -- GET: health + metrics -----------------------------------------
    def do_GET(self) -> None:
        fe = self.server.frontend
        if self.path == "/healthz":
            self._send_json(200, fe.health())
        elif self.path == "/metrics":
            body = fe.registry.to_prometheus().encode("utf-8")
            self._send(200, body,
                       "text/plain; version=0.0.4; charset=utf-8")
        elif self.path == "/metrics.json":
            self._send(200, fe.registry.snapshot_json().encode("utf-8"),
                       "application/json")
        else:
            self._send_json(404, {"error": {"type": "NotFound",
                                            "message": self.path}})

    # -- POST: completions ----------------------------------------------
    def do_POST(self) -> None:
        fe = self.server.frontend
        if self.path != "/v1/completions":
            self._send_json(404, {"error": {"type": "NotFound",
                                            "message": self.path}})
            return
        try:
            n = int(self.headers.get("Content-Length", 0))
            tokens, sp, stream = parse_completion_body(
                self.rfile.read(n), fe.tokenizer)
        except BadRequest as e:
            fe._c_bad.inc()
            self._send_json(400, error_payload(e))
            return
        req = Request(uid=0, prompt=tokens, sampling=sp)
        fe._c_requests.inc()
        if stream:
            self._stream_completion(fe, req)
        else:
            self._block_completion(fe, req)

    # ------------------------------------------------------------------
    def _stream_completion(self, fe: "HttpFrontend", req: Request) -> None:
        backend = fe.backend
        try:
            handle = backend.submit(req)
        except Exception as e:                      # noqa: BLE001
            self._send_json(503, error_payload(e))
            return
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        self.close_connection = True
        t0 = time.perf_counter()
        t_first: Optional[float] = None
        n_sent = 0
        try:
            for tok in backend.stream(handle, timeout=fe.token_timeout):
                if t_first is None:
                    t_first = time.perf_counter()
                self.wfile.write(sse_frame(fe.token_frame(tok)))
                self.wfile.flush()
                n_sent += 1
        except (BrokenPipeError, ConnectionResetError, OSError):
            # the CLIENT went away: free the engine slot + KV pages
            backend.cancel(handle)
            fe._c_disconnects.inc()
            return
        except BaseException as e:                  # noqa: BLE001
            # FAILED handle (engine/worker error) or token timeout:
            # surface the cause in-band, then end the stream
            if isinstance(e, TimeoutError):
                backend.cancel(handle)
            fe._c_failed.inc()      # before [DONE]: a client that saw
            self._try_write(        # the frame can already scrape it
                sse_frame(error_payload(e)) + SSE_DONE)
            return
        t1 = time.perf_counter()
        done = {"done": {
            "prompt_tokens": len(req.prompt),
            "completion_tokens": n_sent,
            "finish_reason": "length",
            "ttft_ms": round(((t_first or t1) - t0) * 1e3, 3),
            "latency_ms": round((t1 - t0) * 1e3, 3),
        }}
        self._try_write(sse_frame(done) + SSE_DONE)

    def _block_completion(self, fe: "HttpFrontend", req: Request) -> None:
        backend = fe.backend
        handle = None
        try:
            handle = backend.submit(req)
            comp = backend.result(handle, timeout=fe.request_timeout)
        except TimeoutError as e:
            if handle is not None:
                backend.cancel(handle)
            fe._c_failed.inc()
            self._send_json(504, error_payload(e))
            return
        except BaseException as e:                  # noqa: BLE001
            fe._c_failed.inc()
            self._send_json(500, error_payload(e))
            return
        text = (fe.tokenizer.decode(comp.tokens)
                if fe.tokenizer is not None else "")
        self._send_json(200, {
            "id": f"cmpl-{comp.uid}",
            "object": "text_completion",
            "choices": [{"index": 0, "text": text,
                         "tokens": list(comp.tokens),
                         "finish_reason": "length"}],
            "usage": {"prompt_tokens": comp.prompt_len,
                      "completion_tokens": len(comp.tokens),
                      "total_tokens": comp.prompt_len + len(comp.tokens)},
            "timing": {"ttft_ms": round((comp.t_first - comp.t0) * 1e3, 3),
                       "latency_ms": round(comp.latency_s * 1e3, 3)},
        })

    # ------------------------------------------------------------------
    def _send(self, status: int, body: bytes, ctype: str) -> None:
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, status: int, doc: Dict[str, Any]) -> None:
        self._send(status, json.dumps(doc, sort_keys=True).encode("utf-8"),
                   "application/json")

    def _try_write(self, data: bytes) -> None:
        try:
            self.wfile.write(data)
            self.wfile.flush()
        except OSError:
            pass        # client already gone; nothing left to tell it


class HttpFrontend:
    """Threaded HTTP server over one engine-like backend.

    ``start()`` binds and serves on a background thread (``port=0``
    picks a free port — ``self.port`` is the bound one); ``close()``
    stops accepting, joins the server thread and optionally shuts the
    backend down.  One handler thread per connection (stdlib
    ``ThreadingHTTPServer``), so a streaming client parks only its own
    thread while the engine stepper keeps serving everyone else.
    """

    def __init__(self, backend: Any, *, tokenizer: Any = None,
                 host: str = "127.0.0.1", port: int = 0,
                 token_timeout: float = 120.0,
                 request_timeout: float = 600.0) -> None:
        self.backend = backend
        self.tokenizer = tokenizer
        self.token_timeout = token_timeout
        self.request_timeout = request_timeout
        self._server = _ServingHTTPServer((host, port), _Handler)
        self._server.frontend = self
        self.host, self.port = self._server.server_address[:2]
        self._thread: Optional[threading.Thread] = None
        reg = self.registry
        self._c_requests = reg.counter(
            "http.requests", "completion requests accepted").labels()
        self._c_bad = reg.counter(
            "http.bad_requests", "completion bodies rejected (400)"
            ).labels()
        self._c_failed = reg.counter(
            "http.failed", "completions that surfaced an error/timeout"
            ).labels()
        self._c_disconnects = reg.counter(
            "http.client_disconnects",
            "streams cancelled because the client went away").labels()

    @property
    def registry(self):
        return self.backend.registry

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def health(self) -> Dict[str, Any]:
        doc = {"status": "ok", "backend": type(self.backend).__name__}
        backend_health = getattr(self.backend, "health", None)
        if callable(backend_health):
            doc.update(backend_health())
        return doc

    def token_frame(self, tok: int) -> Dict[str, Any]:
        """The per-token SSE payload (kept tiny and deterministic)."""
        text = (self.tokenizer.decode([tok])
                if self.tokenizer is not None else "")
        return {"index": 0, "text": text, "token": int(tok)}

    # ------------------------------------------------------------------
    def start(self) -> "HttpFrontend":
        if self._thread is not None:
            raise RuntimeError("frontend already started")
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="http-frontend",
            daemon=True)
        self._thread.start()
        return self

    def close(self, *, shutdown_backend: bool = False) -> None:
        """Stop serving (idempotent).  In-flight handler threads are
        daemons riding the backend's streams; shutting the backend down
        (``shutdown_backend=True``) terminates their handles too."""
        if self._thread is not None:
            self._server.shutdown()
            self._thread.join(timeout=10.0)
            self._thread = None
        self._server.server_close()
        if shutdown_backend:
            self.backend.shutdown()

    def __enter__(self) -> "HttpFrontend":
        return self.start() if self._thread is None else self

    def __exit__(self, *exc: Any) -> None:
        self.close()
