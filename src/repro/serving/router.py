"""Prefix-affinity multi-replica router over engine-worker processes.

One box stops scaling at its memory bus; serving "millions of users"
means a **front door** that spreads live traffic over N engine
replicas, each its own process with its own ``AsyncEngine`` and KV
page pool (spawned by ``repro.serving.supervisor``, served by
``repro.serving.worker``).  Placement is the whole game on CPU
clusters (PAPERS.md: Intel's distributed CPU inference work), and the
state that matters is *which replica already holds a request's prefix
pages* — so routing is **prefix-affine**:

* every request's prompt is keyed by :func:`~repro.serving.kv_pool.
  prefix_chain_key` — the same chain hash ``PrefixCache`` indexes
  pages by, capped at the first ``affinity_blocks`` full blocks (the
  shared system prompt, not the per-user tail);
* keyed requests route through an :class:`AffinityRing` — rendezvous
  (highest-random-weight) hashing over the live replicas — so equal
  prefixes always land on the replica whose pool already holds those
  pages, and a replica's death remaps *only its own* keyspace
  (minimal, deterministic redistribution; property-tested in
  ``tests/test_router.py``);
* unkeyed requests (no full block) fall back to **least-loaded with
  power-of-two choices**: sample two live replicas, take the less
  loaded one.  Load is a TTL-cached scrape of each worker's
  ``/metrics.json`` — queue depth first (``scheduler.queue_depth``),
  then KV pressure (fewer ``kv_pool.pages_free``) — so a replica
  drowning in long prompts loses ties even when its in-flight count
  looks identical; when the scrape fails (worker mid-death, fake
  clients without a metrics endpoint) the score falls back to the
  router's own in-flight counts.

Robustness semantics (the reason this layer exists at all):

* each request is driven by its own router thread streaming SSE frames
  from its worker over HTTP, bounded by an idle **timeout** per frame;
* a worker that dies (SIGKILL, OOM, crash) breaks its sockets: every
  in-flight request on it surfaces FAILED with the death as chained
  cause, the replica is drained from the ring (its keys redistribute
  to survivors) — detection is connection-level plus the supervisor's
  process monitor (:meth:`Router.mark_dead`);
* a request that died with **zero tokens received** (never reached
  PREFILLING on the worker, or prefilled but never sampled — recompute
  is idempotent either way) retries on a surviving replica, bounded by
  ``max_retries`` AND by the request's remaining ``deadline_s`` budget
  — the router never dispatches an attempt that has already blown its
  SLO, and each attempt forwards only the *remaining* budget as the
  wire field ``deadline_ms``;
* a replica that is *alive but failing* (timeouts, error frames, lossy
  streams) trips a per-replica **circuit breaker** after
  ``breaker_threshold`` consecutive failures: it leaves the ring and
  the fallback pool, and after ``breaker_probation_s`` the next pick
  issues a ``healthy()`` probe and readmits it on success.

The router exposes the ``AsyncEngine`` caller surface (``submit`` /
``stream`` / ``result`` / ``cancel`` / ``shutdown`` / ``registry``),
so :class:`~repro.serving.http.HttpFrontend` serves a Router and a
local engine identically.  Router metrics (``router.*`` — catalogue in
``docs/observability.md``): per-replica in-flight gauges and request
counters, affinity keyed/hit counters, retry/failure/death counters.
"""

from __future__ import annotations

import dataclasses
import http.client
import itertools
import json
import random
import threading
import time
from typing import (Any, Callable, Dict, Iterable, Iterator, List,
                    Optional, Tuple)

from .async_engine import (CancelledError, DeadlineExceededError,
                           RequestState)
from .engine import Completion, Request
from .kv_pool import prefix_chain_key


class RouterError(RuntimeError):
    """A request failed at the routing layer; the underlying error is
    chained as ``__cause__``."""


class WorkerDiedError(RouterError):
    """The worker serving a request died (connection broken / process
    gone) before the stream completed."""


class NoReplicasError(RouterError):
    """Every replica is dead — nothing left to route to."""


# ----------------------------------------------------------------------
# rendezvous hashing
# ----------------------------------------------------------------------
_M64 = (1 << 64) - 1


def _mix64(x: int) -> int:
    """SplitMix64-style finalizer: deterministic (no ``PYTHONHASHSEED``
    dependence beyond int hashing, which is identity), well-mixed, and
    cheap — the weight function rendezvous hashing ranks replicas by."""
    x &= _M64
    x ^= x >> 33
    x = (x * 0xFF51AFD7ED558CCD) & _M64
    x ^= x >> 33
    x = (x * 0xC4CEB9FE1A85EC53) & _M64
    x ^= x >> 33
    return x


class AffinityRing:
    """Rendezvous (highest-random-weight) hash over live replica ids.

    ``pick(key)`` is a pure function of ``(key, live set)``: the same
    key always lands on the same live replica, and removing a replica
    remaps exactly the keys that were on it — the minimal,
    deterministic redistribution a prefix-page cache wants (a surviving
    replica's warm pages never move).
    """

    def __init__(self, replica_ids: Iterable[int]) -> None:
        self._live = set(int(r) for r in replica_ids)

    def live(self) -> Tuple[int, ...]:
        return tuple(sorted(self._live))

    def __contains__(self, rid: int) -> bool:
        return rid in self._live

    def add(self, rid: int) -> None:
        self._live.add(int(rid))

    def remove(self, rid: int) -> None:
        self._live.discard(int(rid))

    def pick(self, key: int) -> int:
        """The live replica with the highest weight for ``key``
        (ties — vanishingly rare 64-bit collisions — break on id)."""
        if not self._live:
            raise NoReplicasError("no live replicas in the ring")
        return max(sorted(self._live),
                   key=lambda rid: _mix64(key ^ _mix64(rid + 1)))


def pick_least_loaded(live: List[int], load: Any,
                      rng: random.Random) -> int:
    """Power-of-two-choices fallback for unkeyed requests: sample two
    live replicas, take the one with the lower load score (ties break
    on id).  ``load`` is either a dict of in-flight counts (the legacy
    signal) or a callable ``rid -> sortable score`` (the router passes
    its TTL-cached ``/metrics.json`` scrape).  Only ever sees ``live``,
    so it cannot pick a dead replica by construction."""
    if not live:
        raise NoReplicasError("no live replicas")
    score = load if callable(load) else (lambda r: load.get(r, 0))
    cands = rng.sample(live, 2) if len(live) >= 2 else list(live)
    return min(cands, key=lambda r: (score(r), r))


# ----------------------------------------------------------------------
# worker client (HTTP/SSE wire to one replica)
# ----------------------------------------------------------------------
def _iter_sse(resp) -> Iterator[Dict[str, Any]]:
    """Parse ``data:`` frames off an open SSE response; returns at
    ``[DONE]``.  EOF before ``[DONE]`` means the worker went away."""
    while True:
        line = resp.readline()
        if not line:
            raise WorkerDiedError("connection closed mid-stream")
        line = line.strip()
        if not line or not line.startswith(b"data:"):
            continue
        payload = line[len(b"data:"):].strip()
        if payload == b"[DONE]":
            return
        yield json.loads(payload)


class HttpWorkerClient:
    """Router-side client for one engine-worker's HTTP endpoint.

    ``stream_completion`` is a generator of parsed SSE event dicts
    (token frames, the ``done`` frame, worker-side ``error`` frames);
    closing it mid-stream closes the connection, which the worker's
    frontend turns into an engine-side ``cancel()``.  ``proc`` is the
    supervisor's process handle, consulted to tell a dead worker from
    a transient network error.
    """

    def __init__(self, host: str, port: int, *, proc: Any = None) -> None:
        self.host, self.port = host, int(port)
        self.proc = proc

    def alive(self) -> bool:
        return self.proc is None or self.proc.poll() is None

    def describe(self) -> str:
        return f"{self.host}:{self.port}"

    def stream_completion(self, body: Dict[str, Any], *,
                          timeout: float) -> Iterator[Dict[str, Any]]:
        conn = http.client.HTTPConnection(self.host, self.port,
                                          timeout=timeout)
        try:
            try:
                conn.request(
                    "POST", "/v1/completions",
                    json.dumps({**body, "stream": True}),
                    headers={"Content-Type": "application/json"})
                resp = conn.getresponse()
            except TimeoutError as e:
                raise TimeoutError(
                    f"worker {self.describe()}: no response within "
                    f"{timeout} s") from e
            except (ConnectionError, OSError) as e:
                raise WorkerDiedError(
                    f"worker {self.describe()} unreachable: {e}") from e
            if resp.status != 200:
                raise RouterError(
                    f"worker {self.describe()} rejected the request: "
                    f"HTTP {resp.status} {resp.read()[:300]!r}")
            try:
                yield from _iter_sse(resp)
            except TimeoutError as e:
                raise TimeoutError(
                    f"worker {self.describe()}: no frame within "
                    f"{timeout} s") from e
            except WorkerDiedError:
                raise
            except (ConnectionError, OSError) as e:
                raise WorkerDiedError(
                    f"worker {self.describe()} dropped the stream: "
                    f"{e}") from e
        finally:
            conn.close()

    def metrics(self, *, timeout: float = 0.5) -> Optional[Dict[str, Any]]:
        """One ``/metrics.json`` snapshot (the worker registry's
        ``snapshot()`` document), or None when the worker is
        unreachable — the router's load signal treats None as
        "fall back to in-flight counts".  The short default timeout
        bounds how long a mid-death worker can stall the load probe."""
        try:
            conn = http.client.HTTPConnection(self.host, self.port,
                                              timeout=timeout)
            try:
                conn.request("GET", "/metrics.json")
                resp = conn.getresponse()
                if resp.status != 200:
                    return None
                return json.loads(resp.read())
            finally:
                conn.close()
        except (OSError, ValueError):
            return None

    def healthy(self, *, timeout: float = 2.0) -> bool:
        try:
            conn = http.client.HTTPConnection(self.host, self.port,
                                              timeout=timeout)
            try:
                conn.request("GET", "/healthz")
                return conn.getresponse().status == 200
            finally:
                conn.close()
        except OSError:
            return False


# ----------------------------------------------------------------------
# router
# ----------------------------------------------------------------------
@dataclasses.dataclass(eq=False)        # identity semantics, like
class RouterHandle:                     # async_engine.RequestHandle
    """Caller's view of one routed request.  Mutable fields are written
    by the request's router thread under the router lock."""

    uid: int
    request: Request
    state: RequestState = RequestState.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    completion: Optional[Completion] = None
    error: Optional[BaseException] = None
    replica: Optional[int] = None       # current / last attempted
    n_retries: int = 0
    on_token: Optional[Callable[[int], None]] = None
    _cancel: bool = False

    @property
    def done(self) -> bool:
        return self.state in (RequestState.FINISHED,
                              RequestState.CANCELLED, RequestState.FAILED)


class Router:
    """Front-door load balancer over N engine-worker replicas (see the
    module docstring for the routing + failure semantics).

    ``workers`` maps replica id -> a worker client
    (:class:`HttpWorkerClient`, or any object with the same
    ``stream_completion``/``alive``/``describe`` surface — tests inject
    in-process fakes).
    """

    def __init__(self, workers: Dict[int, Any], *, page_size: int = 16,
                 affinity_blocks: int = 2, timeout_s: float = 120.0,
                 max_retries: int = 1, load_ttl: float = 0.5,
                 breaker_threshold: int = 3,
                 breaker_probation_s: float = 2.0,
                 registry=None, seed: int = 0,
                 tokenizer: Any = None) -> None:
        if not workers:
            raise ValueError("router needs at least one replica")
        if breaker_threshold < 1:
            raise ValueError("breaker_threshold must be >= 1")
        from ..obs.metrics import MetricsRegistry
        self.workers = dict(workers)
        self.page_size = page_size
        self.affinity_blocks = affinity_blocks
        self.timeout_s = timeout_s
        self.max_retries = max_retries
        self.load_ttl = load_ttl
        #: per-replica circuit breaker: ``breaker_threshold``
        #: CONSECUTIVE worker-attributable failures (death, timeout,
        #: error frame, lossy stream) open the breaker — the replica
        #: leaves the ring and the fallback pool without being declared
        #: dead; after ``breaker_probation_s`` the next pick issues a
        #: ``healthy()`` probe and a passing replica is readmitted.
        #: Catches the "alive but failing" replica the supervisor's
        #: process monitor can't see.
        self.breaker_threshold = breaker_threshold
        self.breaker_probation_s = breaker_probation_s
        self.tokenizer = tokenizer
        self.ring = AffinityRing(self.workers)
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._update = threading.Condition(self._lock)
        self._uids = itertools.count()
        self._alive = True
        self._dead: Dict[int, BaseException] = {}
        self._fail_streak: Dict[int, int] = {r: 0 for r in self.workers}
        #: rid -> earliest monotonic time a health probe may run
        self._breaker: Dict[int, float] = {}
        self._inflight: Dict[int, int] = {r: 0 for r in self.workers}
        #: rid -> (expiry monotonic time, score) — the TTL cache in
        #: front of the ``/metrics.json`` load scrape
        self._load_cache: Dict[int, Tuple[float, Tuple]] = {}
        self._affinity_last: Dict[int, int] = {}    # key -> last replica
        self._threads: List[threading.Thread] = []

        reg = self.registry
        c_req = reg.counter("router.requests",
                            "requests dispatched to this replica "
                            "(retries re-count)")
        g_inf = reg.gauge("router.inflight",
                          "requests currently in flight on this replica")
        self._c_req = {r: c_req.labels(replica=r) for r in self.workers}
        self._g_inf = {r: g_inf.labels(replica=r) for r in self.workers}
        self._c_keyed = reg.counter(
            "router.affinity.keyed",
            "requests carrying a prefix-affinity key").labels()
        self._c_hits = reg.counter(
            "router.affinity.hits",
            "keyed requests routed to the same live replica as the "
            "previous request with that key").labels()
        self._c_retries = reg.counter(
            "router.retries",
            "requests re-dispatched to a surviving replica after a "
            "worker death (zero tokens received)").labels()
        self._c_failures = reg.counter(
            "router.failures", "requests that surfaced FAILED").labels()
        self._c_deaths = reg.counter(
            "router.replica_deaths",
            "replicas drained from the ring").labels()
        self._c_readmits = reg.counter(
            "router.readmissions",
            "respawned replicas re-admitted to the ring").labels()
        self._c_load_scrapes = reg.counter(
            "router.load_scrapes",
            "/metrics.json load probes issued (cache misses)").labels()
        self._c_breaker_open = reg.counter(
            "router.breaker_open",
            "circuit breakers opened (consecutive-failure threshold "
            "hit; replica on probation)").labels()
        self._c_breaker_closed = reg.counter(
            "router.breaker_closed",
            "circuit breakers closed after a passing health probe"
            ).labels()
        self._c_breaker_probes = reg.counter(
            "router.breaker_probes",
            "health probes issued for breaker-open replicas").labels()
        self._g_live = reg.gauge(
            "router.replicas_live", "live replicas in the ring").labels()
        self._g_live.set(len(self.workers))

    # ------------------------------------------------------------------
    # caller API (the AsyncEngine surface)
    # ------------------------------------------------------------------
    def submit(self, request: Request, *,
               on_token: Optional[Callable[[int], None]] = None,
               ) -> RouterHandle:
        """Route a request; returns immediately.  A dedicated router
        thread streams it from its worker."""
        with self._lock:
            if not self._alive:
                raise RouterError("router is shut down")
            uid = next(self._uids)
        handle = RouterHandle(
            uid=uid, request=dataclasses.replace(request, uid=uid),
            on_token=on_token)
        t = threading.Thread(target=self._run, args=(handle,),
                             name=f"router-req-{uid}", daemon=True)
        with self._lock:
            self._threads.append(t)
            self._threads = [x for x in self._threads if x.is_alive()
                             or x is t]
        t.start()
        return handle

    def stream(self, handle: RouterHandle, *,
               timeout: Optional[float] = None) -> Iterator[int]:
        """Yield tokens as worker frames arrive; returns at a terminal
        state (raises on FAILED).  ``timeout`` bounds each wait for the
        *next* token."""
        cursor = 0
        while True:
            with self._update:
                if not self._update.wait_for(
                        lambda: len(handle.tokens) > cursor or handle.done,
                        timeout=timeout):
                    raise TimeoutError(
                        f"request {handle.uid}: no token within "
                        f"{timeout} s")
                self._raise_if_failed(handle)
                new = handle.tokens[cursor:]
                cursor += len(new)
                done = handle.done
            yield from new
            if done:
                return

    def result(self, handle: RouterHandle, *,
               timeout: Optional[float] = None) -> Completion:
        with self._update:
            if not self._update.wait_for(lambda: handle.done,
                                         timeout=timeout):
                raise TimeoutError(
                    f"request {handle.uid} not done within {timeout} s")
            self._raise_if_failed(handle)
            if handle.state is RequestState.CANCELLED:
                raise CancelledError(
                    f"request {handle.uid} was cancelled")
            return handle.completion

    def cancel(self, handle: RouterHandle) -> bool:
        """Ask the request's router thread to stop; closing its worker
        connection makes the worker cancel engine-side (slot + pages
        free).  Returns False when already terminal."""
        with self._update:
            if handle.done:
                return False
            handle._cancel = True
            self._update.notify_all()
        return True

    def mark_dead(self, rid: int,
                  cause: Optional[BaseException] = None) -> bool:
        """Drain a replica: out of the ring (its keyspace redistributes
        to survivors), out of the fallback pool.  Called by request
        threads on connection-level detection and by the supervisor's
        process monitor.  Idempotent."""
        with self._lock:
            if rid in self._dead or rid not in self.workers:
                return False
            self._dead[rid] = (cause if cause is not None
                               else WorkerDiedError(f"replica {rid} died"))
            self.ring.remove(rid)
            self._load_cache.pop(rid, None)
            self._c_deaths.inc()
            self._g_live.set(len(self._live_locked()))
        return True

    def readmit(self, rid: int, client: Any = None) -> bool:
        """Re-admit a respawned replica: fresh worker client, back in
        the affinity ring (its old keyspace deterministically returns —
        rendezvous hashing) and the least-loaded pool.  Inverse of
        :meth:`mark_dead`; the launcher wires it to the supervisor's
        ``on_respawn`` hook.  Idempotent on a live replica."""
        with self._lock:
            if rid not in self.workers:
                return False
            if client is not None:
                self.workers[rid] = client
            if rid not in self._dead and rid not in self._breaker:
                return False
            self._dead.pop(rid, None)
            # a respawned worker starts with a clean slate: breaker
            # closed, streak zeroed
            self._breaker.pop(rid, None)
            self._fail_streak[rid] = 0
            self.ring.add(rid)
            self._inflight[rid] = 0
            self._g_inf[rid].set(0)
            self._load_cache.pop(rid, None)
            self._c_readmits.inc()
            self._g_live.set(len(self._live_locked()))
        return True

    def health(self) -> Dict[str, Any]:
        with self._lock:
            return {"replicas": {
                str(r): {"alive": r not in self._dead,
                         "breaker_open": r in self._breaker}
                for r in sorted(self.workers)},
                "live": len(self._live_locked())}

    def shutdown(self, *, timeout: float = 10.0) -> None:
        """Stop accepting, cancel in-flight requests, join request
        threads.  Worker *processes* belong to the supervisor."""
        with self._update:
            self._alive = False
            threads = list(self._threads)
            self._update.notify_all()
        deadline = time.perf_counter() + timeout
        for t in threads:
            t.join(timeout=max(deadline - time.perf_counter(), 0.1))

    def __enter__(self) -> "Router":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.shutdown()

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def _live_locked(self) -> List[int]:
        return [r for r in sorted(self.workers)
                if r not in self._dead and r not in self._breaker]

    # ------------------------------------------------------------------
    # circuit breaker
    # ------------------------------------------------------------------
    def _record_failure(self, rid: int) -> None:
        """One worker-attributable failure (death, timeout, error
        frame, lossy stream).  At ``breaker_threshold`` consecutive
        failures the breaker opens: out of the ring and the fallback
        pool until a probation-gated health probe passes."""
        with self._lock:
            self._fail_streak[rid] = self._fail_streak.get(rid, 0) + 1
            if (self._fail_streak[rid] >= self.breaker_threshold
                    and rid not in self._breaker):
                self._breaker[rid] = (time.monotonic()
                                      + self.breaker_probation_s)
                self.ring.remove(rid)
                self._load_cache.pop(rid, None)
                self._c_breaker_open.inc()
                self._g_live.set(len(self._live_locked()))

    def _record_success(self, rid: int) -> None:
        with self._lock:
            self._fail_streak[rid] = 0

    def _probe_breakers(self) -> None:
        """Readmit breaker-open replicas whose probation elapsed and
        whose ``healthy()`` probe passes.  Probes run OUTSIDE the lock
        (network call); a failing probe re-arms the probation window."""
        now = time.monotonic()
        with self._lock:
            due = [r for r, t in self._breaker.items()
                   if t <= now and r not in self._dead]
        for rid in due:
            probe = getattr(self.workers[rid], "healthy", None)
            self._c_breaker_probes.inc()
            ok = probe(timeout=2.0) if callable(probe) else True
            with self._lock:
                if rid not in self._breaker:    # raced with readmit()
                    continue
                if ok:
                    del self._breaker[rid]
                    self._fail_streak[rid] = 0
                    if rid not in self._dead:
                        self.ring.add(rid)
                    self._load_cache.pop(rid, None)
                    self._c_breaker_closed.inc()
                else:
                    self._breaker[rid] = (time.monotonic()
                                          + self.breaker_probation_s)
                self._g_live.set(len(self._live_locked()))

    def _load_score(self, rid: int) -> Tuple:
        """Load rank for the power-of-two fallback, lower = less
        loaded: ``(queue depth, -free KV pages)`` scraped from the
        worker's ``/metrics.json`` behind a ``load_ttl``-second cache
        (two probes per unkeyed request at most once per TTL).  A
        failed scrape — dead worker, fake client without a metrics
        endpoint — scores by the router's own in-flight count, which
        compares sanely against scraped scores (queued requests vs
        dispatched requests, same scale)."""
        now = time.monotonic()
        hit = self._load_cache.get(rid)
        if hit is not None and hit[0] > now:
            return hit[1]
        score: Optional[Tuple] = None
        fn = getattr(self.workers[rid], "metrics", None)
        if fn is not None:
            self._c_load_scrapes.inc()
            snap = fn()
            if snap:
                queue = free = None
                for g in snap.get("gauges", ()):
                    if g.get("name") == "scheduler.queue_depth":
                        queue = (queue or 0.0) + float(g["value"])
                    elif g.get("name") == "kv_pool.pages_free":
                        free = (free or 0.0) + float(g["value"])
                if queue is not None or free is not None:
                    score = (queue or 0.0, -(free or 0.0))
        if score is None:
            score = (float(self._inflight.get(rid, 0)), 0.0)
        self._load_cache[rid] = (now + self.load_ttl, score)
        return score

    def affinity_key(self, prompt: List[int]) -> Optional[int]:
        return prefix_chain_key(prompt, self.page_size,
                                max_blocks=self.affinity_blocks)

    def _pick(self, key: Optional[int]) -> int:
        if self._breaker:       # probation over? probe + readmit
            self._probe_breakers()
        with self._lock:
            live = self._live_locked()
            if not live:
                raise NoReplicasError(
                    "all replicas are dead or breaker-open: "
                    + "; ".join(f"{r}: {e}"
                                for r, e in sorted(self._dead.items()))
                    + (f"; breaker-open: {sorted(self._breaker)}"
                       if self._breaker else ""))
            if key is not None:
                rid = self.ring.pick(key)
                self._c_keyed.inc()
                if self._affinity_last.get(key) == rid:
                    self._c_hits.inc()
                self._affinity_last[key] = rid
            else:
                rid = pick_least_loaded(live, self._load_score, self._rng)
            self._inflight[rid] += 1
            self._g_inf[rid].set(self._inflight[rid])
            self._c_req[rid].inc()
            return rid

    # ------------------------------------------------------------------
    # per-request driver thread
    # ------------------------------------------------------------------
    def _run(self, handle: RouterHandle) -> None:
        req = handle.request
        key = self.affinity_key(req.prompt)
        sp = req.sampling
        body = {"prompt": list(req.prompt),
                "max_tokens": sp.max_new_tokens,
                "temperature": sp.temperature, "top_k": sp.top_k,
                "eos_id": sp.eos_id}
        if req.priority != "interactive":
            body["priority"] = req.priority
        t0 = time.perf_counter()
        # the deadline budget is anchored at router ingress; each
        # attempt forwards only the *remaining* budget, so a retry
        # after a slow first attempt cannot overrun the caller's SLO
        deadline_abs = (t0 + req.deadline_s
                        if req.deadline_s is not None else None)
        while True:
            if handle._cancel or not self._alive:
                self._terminate(handle, RequestState.CANCELLED)
                return
            if deadline_abs is not None:
                remaining = deadline_abs - time.perf_counter()
                if remaining <= 0:
                    self._fail(handle, DeadlineExceededError(
                        f"request {handle.uid} spent its "
                        f"{req.deadline_s} s budget at the router "
                        f"(after {handle.n_retries} retries)"))
                    return
                body["deadline_ms"] = remaining * 1e3
            try:
                rid = self._pick(key)
            except NoReplicasError as e:
                self._fail(handle, e)
                return
            with self._update:
                handle.replica = rid
                handle.state = RequestState.PREFILLING
                self._update.notify_all()
            done_info: Optional[Dict[str, Any]] = None
            t_first: Optional[float] = None
            try:
                try:
                    gen = self.workers[rid].stream_completion(
                        body, timeout=self.timeout_s)
                    for ev in gen:
                        if handle._cancel or not self._alive:
                            gen.close()     # -> conn close -> worker
                            self._terminate(handle,   # cancels engine-side
                                            RequestState.CANCELLED)
                            return
                        if "token" in ev:
                            if t_first is None:
                                t_first = time.perf_counter()
                            self._emit(handle, int(ev["token"]))
                        elif "error" in ev:
                            err = ev["error"]
                            raise RouterError(
                                f"worker {rid} failed the request: "
                                f"{err.get('type')}: {err.get('message')}"
                                + (f" (cause: {err['cause']})"
                                   if err.get("cause") else ""))
                        elif "done" in ev:
                            done_info = ev["done"]
                finally:
                    self._release(rid)
            except WorkerDiedError as e:
                alive = self.workers[rid].alive()
                self._record_failure(rid)
                self.mark_dead(rid, cause=e)
                can_retry = (not handle.tokens
                             and handle.n_retries < self.max_retries
                             and (deadline_abs is None
                                  or time.perf_counter() < deadline_abs))
                if can_retry:
                    handle.n_retries += 1
                    self._c_retries.inc()
                    continue
                err = WorkerDiedError(
                    f"replica {rid} died "
                    f"{'mid-stream' if handle.tokens else 'mid-queue'} "
                    f"(process alive={alive})")
                err.__cause__ = e
                self._fail(handle, err)
                return
            except BaseException as e:          # noqa: BLE001 — timeout,
                if isinstance(e, (TimeoutError, RouterError)):
                    self._record_failure(rid)   # worker-attributable
                self._fail(handle, e)           # worker reject, client bug
                return
            t1 = time.perf_counter()
            comp = Completion(
                uid=handle.uid, prompt_len=len(req.prompt),
                tokens=list(handle.tokens), latency_s=t1 - t0,
                prefill_s=max((t_first or t1) - t0, 0.0), t0=t0, t1=t1,
                t_first=t_first if t_first is not None else t1,
                t_sched=t0)
            if done_info is not None:
                n = done_info.get("completion_tokens")
                if n is not None and n != len(handle.tokens):
                    self._record_failure(rid)   # lossy stream
                    self._fail(handle, RouterError(
                        f"worker {rid} reported {n} tokens but "
                        f"{len(handle.tokens)} frames arrived"))
                    return
            self._record_success(rid)
            with self._update:
                handle.completion = comp
                handle.state = RequestState.FINISHED
                self._update.notify_all()
            return

    def _emit(self, handle: RouterHandle, tok: int) -> None:
        with self._update:
            handle.tokens.append(tok)
            if handle.state is RequestState.PREFILLING:
                handle.state = RequestState.DECODING
            self._update.notify_all()
        if handle.on_token is not None:
            handle.on_token(tok)    # outside the lock, like AsyncEngine

    def _release(self, rid: int) -> None:
        with self._lock:
            self._inflight[rid] = max(self._inflight[rid] - 1, 0)
            self._g_inf[rid].set(self._inflight[rid])

    def _terminate(self, handle: RouterHandle,
                   state: RequestState) -> None:
        with self._update:
            if not handle.done:
                handle.state = state
            self._update.notify_all()

    def _fail(self, handle: RouterHandle, exc: BaseException) -> None:
        self._c_failures.inc()
        with self._update:
            if not handle.done:
                handle.error = exc
                handle.state = RequestState.FAILED
            self._update.notify_all()

    def _raise_if_failed(self, handle: RouterHandle) -> None:
        if handle.state is RequestState.FAILED:
            raise RouterError(
                f"request {handle.uid} failed") from handle.error
