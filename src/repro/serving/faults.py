"""Deterministic fault injection for the serving stack.

Chaos testing needs *injectable* failure, not flaky tests: the robust
paths in this repo (worker-death failover, circuit breaking, load
shedding, deadline expiry) only execute when something goes wrong, so
the test suite and the ``tools/check.sh`` chaos smoke lane must be able
to make things go wrong **on demand and deterministically**.  This
module is that switchboard — dependency-free (stdlib only, no jax) and
**zero-cost when disarmed**: every instrumented call site guards on the
module-level :data:`ACTIVE` flag, so production pays one attribute load
and a falsy check.

A *fault point* is a string name with a float value; what the value
means is the call site's contract (documented in
``docs/robustness.md`` "Fault points"):

===================  ==================================================
``step.latency_ms``  :meth:`EngineCore.step` sleeps this many
                     milliseconds at the top of every step — a slow /
                     overloaded worker.
``http.drop_sse``    the HTTP front-end silently drops every N-th
                     token frame it would have streamed (the ``done``
                     frame still reports the true count, so the router
                     detects the mismatch) — a lossy worker stream.
``pool.exhaust``     every N-th *fresh admission* page grant fails as
                     if the KV pool were out of pages — memory
                     pressure without building a tiny pool.
``http.scrape_ms``   ``GET /metrics.json`` sleeps this many
                     milliseconds before answering — a slow load-probe
                     target for the router's TTL cache.
===================  ==================================================

Arming:

* in-process (tests): :func:`arm` / :func:`reset`;
* across processes (chaos smoke): the ``REPRO_FAULTS`` environment
  variable — ``"step.latency_ms=40,http.drop_sse=3"`` — parsed by
  :func:`load_env`, which ``repro.serving.worker`` calls at startup.
  Supervisor-spawned workers inherit the parent environment, so
  exporting ``REPRO_FAULTS`` before ``--http --replicas N`` arms every
  worker in the fleet.

Every firing is counted (:func:`hits`), so tests can assert a fault
actually fired rather than passing vacuously.
"""

from __future__ import annotations

import os
import threading
import time
from typing import Dict

#: fast-path guard: call sites check ``faults.ACTIVE`` before anything
#: else, so a disarmed registry costs one attribute load per site
ACTIVE = False

_ARMED: Dict[str, float] = {}
_HITS: Dict[str, int] = {}
_FIRE_COUNTS: Dict[str, int] = {}       # every-N-th bookkeeping
_LOCK = threading.Lock()

ENV_VAR = "REPRO_FAULTS"


def arm(name: str, value: float) -> None:
    """Arm one fault point.  ``value`` semantics are per-point (a
    latency in ms, an every-N-th period, ...)."""
    global ACTIVE
    with _LOCK:
        _ARMED[str(name)] = float(value)
        ACTIVE = True


def disarm(name: str) -> None:
    global ACTIVE
    with _LOCK:
        _ARMED.pop(name, None)
        _FIRE_COUNTS.pop(name, None)
        ACTIVE = bool(_ARMED)


def reset() -> None:
    """Disarm everything and zero the hit counters (test teardown)."""
    global ACTIVE
    with _LOCK:
        _ARMED.clear()
        _HITS.clear()
        _FIRE_COUNTS.clear()
        ACTIVE = False


def armed(name: str) -> bool:
    return name in _ARMED


def value(name: str, default: float = 0.0) -> float:
    return _ARMED.get(name, default)


def hits(name: str) -> int:
    """How many times fault ``name`` actually fired."""
    return _HITS.get(name, 0)


def _record(name: str) -> None:
    with _LOCK:
        _HITS[name] = _HITS.get(name, 0) + 1


# ----------------------------------------------------------------------
# call-site helpers
# ----------------------------------------------------------------------
def maybe_sleep(name: str) -> None:
    """Sleep ``value(name)`` milliseconds when armed (latency faults)."""
    ms = _ARMED.get(name)
    if ms is None or ms <= 0:
        return
    _record(name)
    time.sleep(ms / 1e3)


def should_fire(name: str) -> bool:
    """Every-N-th firing: with ``value(name) == N`` (>= 1), returns
    True on the N-th, 2N-th, ... call since arming.  Deterministic by
    construction — no randomness, so chaos tests replay exactly."""
    n = _ARMED.get(name)
    if n is None or n < 1:
        return False
    with _LOCK:
        c = _FIRE_COUNTS.get(name, 0) + 1
        _FIRE_COUNTS[name] = c
        fire = c % int(n) == 0
    if fire:
        _HITS[name] = _HITS.get(name, 0) + 1
    return fire


def load_env(env: str = ENV_VAR) -> int:
    """Arm fault points from ``$REPRO_FAULTS`` (comma-separated
    ``name=value`` pairs); returns how many were armed.  Unparseable
    entries are skipped — a typo in a chaos run must not take the
    worker down with an unrelated error."""
    spec = os.environ.get(env, "")
    n = 0
    for part in spec.split(","):
        part = part.strip()
        if not part or "=" not in part:
            continue
        name, _, val = part.partition("=")
        if not name.strip():
            continue
        try:
            arm(name.strip(), float(val))
            n += 1
        except ValueError:
            continue
    return n
