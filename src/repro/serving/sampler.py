"""Token samplers: greedy / temperature / top-k."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # 0 -> greedy
    top_k: int = 0                # 0 -> full distribution
    max_new_tokens: int = 64
    eos_id: Optional[int] = None


def sample(logits: jax.Array, params: SamplingParams,
           key: jax.Array) -> jax.Array:
    """logits (B, 1, V) -> tokens (B, 1)."""
    lf = logits[:, -1].astype(jnp.float32)
    if params.temperature <= 0.0:
        return jnp.argmax(lf, axis=-1, keepdims=True).astype(jnp.int32)
    lf = lf / params.temperature
    if params.top_k > 0:
        vals, _ = jax.lax.top_k(lf, params.top_k)
        kth = vals[:, -1:]
        lf = jnp.where(lf < kth, -jnp.inf, lf)
    tok = jax.random.categorical(key, lf, axis=-1)
    return tok[:, None].astype(jnp.int32)
