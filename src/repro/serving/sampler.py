"""Token samplers: greedy / temperature / top-k.

``sample`` applies one :class:`SamplingParams` to a whole batch;
``sample_grouped`` honours a *per-request* params list by grouping the
batch lanes that share (temperature, top_k) and sampling each group
with its own sub-key — the serving engines use it so mixed-policy
batches stay a handful of device calls instead of one per request.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # 0 -> greedy
    top_k: int = 0                # 0 -> full distribution
    max_new_tokens: int = 64
    eos_id: Optional[int] = None


def sample(logits: jax.Array, params: SamplingParams,
           key: jax.Array) -> jax.Array:
    """logits (B, 1, V) -> tokens (B, 1)."""
    lf = logits[:, -1].astype(jnp.float32)
    if params.temperature <= 0.0:
        return jnp.argmax(lf, axis=-1, keepdims=True).astype(jnp.int32)
    lf = lf / params.temperature
    if params.top_k > 0:
        vals, _ = jax.lax.top_k(lf, params.top_k)
        kth = vals[:, -1:]
        lf = jnp.where(lf < kth, -jnp.inf, lf)
    tok = jax.random.categorical(key, lf, axis=-1)
    return tok[:, None].astype(jnp.int32)


def sample_grouped(logits: jax.Array, params: Sequence[SamplingParams],
                   key: jax.Array) -> np.ndarray:
    """logits (B, 1, V), one SamplingParams per lane -> tokens (B, 1).

    Lanes with identical (temperature, top_k) sample together; greedy
    lanes ignore the key, so a fully-greedy batch is one argmax."""
    B = logits.shape[0]
    if len(params) != B:
        raise ValueError(f"{len(params)} params for batch {B}")
    groups = {}
    for b, sp in enumerate(params):
        groups.setdefault((sp.temperature, sp.top_k), []).append(b)
    out = np.zeros((B, 1), np.int32)
    keys = jax.random.split(key, len(groups))
    for sub, (_, lanes) in zip(keys, sorted(groups.items())):
        idx = jnp.asarray(lanes)
        out[lanes] = np.asarray(sample(logits[idx], params[lanes[0]], sub))
    return out
