"""Continuous-batching scheduler (the serving-side ArcLight claim).

The bucket engine (``serving.engine``) runs length-equal batches
strictly sequentially: no request can join mid-decode, and the batch
runs until its *slowest* member finishes.  On CPU servers continuous
batching is the dominant throughput lever (arXiv:2407.00029 §4): keep a
fixed-capacity **running batch** of ``max_running`` slot-indexed
sequences, and at every decode step

* **evict** finished sequences (their slot and KV pages free instantly),
* **admit** waiting requests into free slots when the KV pool can cover
  their prompt (FCFS) — admission is **prefix-aware**: the pool's
  prompt-prefix map is consulted first and matched pages are *shared*,
  not allocated, so cached pages never count against the free-page
  budget and a request whose prompt is mostly cached admits into a
  nearly-full pool;
* run one **prefill chunk** (``prefill_chunk`` tokens) for every
  sequence whose prompt KV is not yet fully resident — long prompts
  spread over many steps instead of stalling the decode batch,
* **grow** each decoding sequence by one token slot, **preempting** the
  youngest-arrival sequence (recompute-style: its pages are freed and
  the whole prefix re-queues) when the pool is exhausted.

Slots are *positions in the device batch*, so membership changes are
pure data (block tables, position vectors) — the compiled decode step
never re-specialises.  The scheduler is deliberately jax-free: it
manipulates the :class:`~repro.serving.kv_pool.KVCachePool` and emits
:class:`Schedule` decisions; the engine turns decisions into device
calls.

The base policy is FCFS; SLO awareness is **data-driven** on top of it
(no policy knob): requests carrying a ``priority`` class admit in
``(priority, arrival)`` order and are preempted batch-first, and
requests carrying a ``deadline_s`` budget are shed — queued or running
— the step their deadline passes (``Schedule.expired``), *before* any
more prefill or decode is burned on them.  A workload with uniform
priorities and no deadlines schedules byte-identically to plain FCFS.

Invariants the engine relies on:

* **preemption ordering** — a preempted sequence's pages are released
  *before* anything else allocates in the same step, its ``slot``
  resets to -1, and it re-queues by original arrival time; its restart
  prompt (``full_prompt``) carries previously generated tokens so the
  recompute is exact;
* a sequence appears in exactly one of ``prefills`` / ``decodes`` per
  step, and only sequences with fully-resident prompts decode;
* admission reserves pages for the *whole* prompt plus one decode
  token up front, so a mid-prefill sequence never grows (and a fresh
  admission can never instantly re-preempt itself);
* every page about to be written this step has refcount 1 — shared
  pages are cloned first via ``ensure_writable`` (copy-on-write), and
  the prefix-match cap (``match_prefix`` leaves >= 1 prompt token
  uncached) keeps prompt writes out of shared pages structurally.
"""

from __future__ import annotations

import dataclasses
from typing import Deque, Dict, List, Optional
from collections import deque

from . import faults
from .engine import PRIORITIES, Request
from .kv_pool import KVCachePool
from .spec import lookahead_for

#: admission/victim ordering key per SLO class — lower admits first,
#: higher is preempted first.  Derived from ``engine.PRIORITIES`` so
#: the two stay one source of truth.
PRIORITY_RANK = {name: i for i, name in enumerate(PRIORITIES)}


@dataclasses.dataclass(eq=False)    # identity semantics: a Sequence is
class Sequence:                     # one admission ticket, never a value
    """A request's life inside the scheduler."""

    request: Request
    arrival: float = 0.0
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1                  # -1 = not running
    n_prefilled: int = 0            # tokens whose KV is resident
    prefill_target: int = 0         # prompt length being prefilled
    n_preempts: int = 0
    n_cached_tokens: int = 0        # prefix-cache hits at last admission
    t_first_sched: float = -1.0     # first time it got a slot
    #: absolute deadline on the scheduler's clock (``arrival +
    #: request.deadline_s``, pinned at submit); +inf = no deadline
    deadline: float = float("inf")
    #: verify-step (accepted, drafted) history + auto-off latch for
    #: per-sequence speculation (``spec.note_accept``)
    spec_recent: List = dataclasses.field(default_factory=list)
    spec_disabled: bool = False

    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def priority_rank(self) -> int:
        return PRIORITY_RANK[self.request.priority]

    @property
    def full_prompt(self) -> List[int]:
        """Prompt for (re-)prefill: original prompt + tokens generated
        before a preemption (recompute-style restart)."""
        return list(self.request.prompt) + self.generated

    @property
    def next_pos(self) -> int:
        """Absolute position of the next token to be fed/decoded."""
        return len(self.request.prompt) + len(self.generated)

    @property
    def is_prefilling(self) -> bool:
        return self.n_prefilled < self.prefill_target

    def is_done(self, max_len: int) -> bool:
        sp = self.request.sampling
        if len(self.generated) >= sp.max_new_tokens:
            return True
        if (sp.eos_id is not None and self.generated
                and self.generated[-1] == sp.eos_id):
            return True
        return self.next_pos >= max_len


@dataclasses.dataclass
class Schedule:
    """One step's decisions, in execution order.

    ``prefills`` holds every sequence that should run one prefill chunk
    this step (``n_prefilled`` -> engine's resume offset); ``decodes``
    holds the fully-prefilled rest of the running batch.
    """

    finished: List[Sequence] = dataclasses.field(default_factory=list)
    preempted: List[Sequence] = dataclasses.field(default_factory=list)
    prefills: List[Sequence] = dataclasses.field(default_factory=list)
    decodes: List[Sequence] = dataclasses.field(default_factory=list)
    #: deadline-expired sequences shed this step — slot and pages are
    #: already released; the engine only has to fail/trace them
    expired: List[Sequence] = dataclasses.field(default_factory=list)


class ContinuousScheduler:
    def __init__(self, pool: KVCachePool, *, max_running: int,
                 max_len: int, policy: str = "fcfs",
                 prefill_chunk: Optional[int] = None,
                 spec_lookahead: int = 0,
                 registry=None) -> None:
        if policy != "fcfs":
            raise ValueError(f"unknown policy {policy!r}")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if spec_lookahead < 0:
            raise ValueError("spec_lookahead must be >= 0")
        self.pool = pool
        self.max_running = max_running
        self.max_len = max_len
        self.policy = policy
        self.prefill_chunk = prefill_chunk
        #: worst-case speculative draft tokens per decode step
        #: (``--spec-decode k``): the grow step below reserves pages for
        #: all k possible extra writes up front; the engine returns
        #: unused grants after a rejected draft (``pool.truncate_to``)
        self.spec_lookahead = spec_lookahead
        self.waiting: Deque[Sequence] = deque()
        self.running: Dict[int, Sequence] = {}      # slot -> Sequence
        self._free_slots = list(range(max_running - 1, -1, -1))
        self.n_preemptions = 0
        #: latched True on the first deadline-bearing submit: SLO-free
        #: workloads skip the per-step expiry scans entirely, keeping
        #: the hot path byte-identical to the pre-SLO scheduler
        self._has_deadlines = False
        # observability (optional; instruments resolved once — the
        # scheduler stays jax-free, repro.obs is stdlib-only)
        self._m_preempt = self._m_admit = self._m_expired = None
        self._g_queue = self._g_running = None
        if registry is not None:
            self._m_preempt = registry.counter(
                "scheduler.preemptions",
                "recompute-style preemptions (pool pressure)").labels()
            self._m_admit = registry.counter(
                "scheduler.admissions",
                "sequences admitted into the running batch").labels()
            self._m_expired = registry.counter(
                "scheduler.expired",
                "deadline-expired sequences shed before completion"
            ).labels()
            self._g_queue = registry.gauge(
                "scheduler.queue_depth",
                "waiting sequences after the last step").labels()
            self._g_running = registry.gauge(
                "scheduler.running",
                "running-batch occupancy after the last step").labels()

    # ------------------------------------------------------------------
    def submit(self, request: Request, arrival: float = 0.0) -> Sequence:
        if request.priority not in PRIORITY_RANK:
            raise ValueError(
                f"request {request.uid}: unknown priority "
                f"{request.priority!r} (expected one of {PRIORITIES})")
        seq = Sequence(request=request, arrival=arrival)
        if request.deadline_s is not None:
            # pin the relative budget to THIS clock's timeline once, at
            # submit — every later comparison is a plain float check
            seq.deadline = arrival + request.deadline_s
            self._has_deadlines = True
        self._requeue(seq)
        return seq

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def cancel(self, seq: Sequence) -> bool:
        """Remove ``seq`` from the scheduler wherever it lives —
        waiting queue or running batch — releasing its slot and every
        page reference (mid-prefill included: a partially-resident
        prompt frees completely).  Returns False when ``seq`` is not
        known (already finished, cancelled or preempted-and-raced)."""
        if seq.slot >= 0 and self.running.get(seq.slot) is seq:
            del self.running[seq.slot]
            self._free_slots.append(seq.slot)
            self.pool.release(seq.uid)
            seq.slot = -1
            return True
        try:
            self.waiting.remove(seq)
        except ValueError:
            return False
        self.pool.release(seq.uid)      # no-op for queued sequences
        return True

    def chunk_for(self, seq: Sequence) -> int:
        """Tokens the engine should prefill for ``seq`` this step."""
        remaining = seq.prefill_target - seq.n_prefilled
        if self.prefill_chunk is None:
            return remaining
        return min(self.prefill_chunk, remaining)

    def _slot_node(self, slot: int) -> int:
        """Home-node hint: stripe slots across the pool's NUMA nodes so
        each node's threads mostly touch locally-resident KV pages
        (under TP the per-shard pools of one node count once — a page's
        head-slices follow its node)."""
        n = max(self.pool.mm.kv_node_count, 1)
        return slot % n

    def _requeue(self, seq: Sequence) -> None:
        """Priority-then-FCFS insertion (stable): the queue is kept
        sorted by ``(priority_rank, arrival)``, so interactive traffic
        admits ahead of batch and order within a class is arrival
        order.  With uniform priorities this degrades to exactly the
        old FCFS queue — ties insert *after* equals."""
        key = (seq.priority_rank, seq.arrival)
        for i, w in enumerate(self.waiting):
            if (w.priority_rank, w.arrival) > key:
                self.waiting.insert(i, seq)
                return
        self.waiting.append(seq)

    def _admit(self, seq: Sequence, slot: int) -> bool:
        """Reserve KV for ``seq``'s whole prompt + one decode token,
        sharing every prefix-cached page instead of allocating it."""
        if faults.ACTIVE and faults.should_fire("pool.exhaust"):
            return False        # injected memory pressure (chaos tests)
        pool = self.pool
        prompt = seq.full_prompt
        need_total = pool.cfg.pages_for(len(prompt) + 1)
        if need_total > pool.cfg.max_pages_per_seq:
            raise ValueError(
                f"request {seq.uid}: prompt needs {need_total} pages; "
                f"pool only has {pool.cfg.max_pages_per_seq}")
        match = pool.match_prefix(prompt)
        # prefix-aware budget: cached pages are shared, not allocated —
        # but a matched RETAINED page (refcount 0) is itself part of
        # n_free()'s reclaimable count, and adopting it revives it, so
        # it must not be counted as capacity for the uncached tail
        matched_retained = sum(1 for p in match.pages
                               if pool.refcount(p) == 0)
        if need_total - len(match.pages) > pool.n_free() - matched_retained:
            return False
        hint = self._slot_node(slot)
        if not pool.adopt_prefix(seq.uid, match, node_hint=hint):
            return False
        if not pool.grow(seq.uid, len(prompt) + 1, node_hint=hint):
            pool.release(seq.uid)   # roll back the adopted references
            return False
        seq.n_prefilled = match.n_tokens
        seq.n_cached_tokens = match.n_tokens
        seq.prefill_target = len(prompt)
        return True

    # ------------------------------------------------------------------
    def step(self, now: float = 0.0) -> Schedule:
        """Plan one engine step.  Order matters: evict, shed, admit,
        grow."""
        sched = Schedule()

        # 1. evict finished sequences — slot and pages free immediately
        #    (a sequence that completed AT its deadline still counts as
        #    finished: eviction runs before expiry shedding)
        for slot in sorted(self.running):
            seq = self.running[slot]
            if not seq.is_prefilling and seq.is_done(self.max_len):
                del self.running[slot]
                self._free_slots.append(slot)
                self.pool.release(seq.uid)
                seq.slot = -1
                sched.finished.append(seq)

        # 2. shed deadline-expired work: queued requests go *before*
        #    they burn any prefill, running ones before another step is
        #    spent on an answer nobody is waiting for.  Pages drain
        #    through the same release path as cancel/preempt (CoW
        #    pending copies included), so the pool stays clean.
        if self._has_deadlines:
            for seq in [w for w in self.waiting if now >= w.deadline]:
                self.waiting.remove(seq)
                self.pool.release(seq.uid)      # no-op for queued seqs
                sched.expired.append(seq)
            for slot in sorted(self.running):
                seq = self.running[slot]
                if now >= seq.deadline:
                    del self.running[slot]
                    self._free_slots.append(slot)
                    self.pool.release(seq.uid)
                    seq.slot = -1
                    sched.expired.append(seq)
            if sched.expired and self._m_expired is not None:
                self._m_expired.inc(len(sched.expired))

        # 3. admit arrived waiting sequences — the queue is kept in
        #    (priority, arrival) order, so this walk is priority-first;
        #    not-yet-arrived entries are skipped (a future interactive
        #    arrival must not block an already-arrived batch request).
        #    The first failed page reservation stops admission, as
        #    before.
        i = 0
        while self._free_slots and i < len(self.waiting):
            seq = self.waiting[i]
            if seq.arrival > now:
                i += 1
                continue
            slot = self._free_slots[-1]
            if not self._admit(seq, slot):
                break
            del self.waiting[i]
            self._free_slots.pop()
            seq.slot = slot
            if seq.t_first_sched < 0:
                seq.t_first_sched = now
            if self._m_admit is not None:
                self._m_admit.inc()
            self.running[slot] = seq

        # 4. every sequence whose prompt KV is not fully resident runs
        #    one prefill chunk this step (freshly admitted ones included)
        for slot in sorted(self.running):
            if self.running[slot].is_prefilling:
                sched.prefills.append(self.running[slot])

        # 5. grow every decoding sequence for this step's token write;
        #    preempt lowest-priority / youngest arrivals when the pool
        #    runs dry
        for slot in sorted(list(self.running)):
            seq = self.running.get(slot)
            if seq is None:                 # preempted earlier in this loop
                continue
            if seq in sched.prefills:       # reservation made at admission
                continue
            hint = self._slot_node(slot)
            k_eff = (lookahead_for(seq, self.spec_lookahead, self.max_len)
                     if self.spec_lookahead else 0)
            while not (self.pool.grow(seq.uid, seq.next_pos + 1 + k_eff,
                                      node_hint=hint)
                       and self._writable_span(seq, k_eff, hint)):
                victim = self._pick_victim(exclude=seq)
                if victim is None:
                    raise RuntimeError(
                        "KV pool cannot hold a single sequence — "
                        "raise n_pages or lower max_len")
                self._preempt(victim)
                sched.preempted.append(victim)
                if victim in sched.prefills:
                    sched.prefills.remove(victim)

        sched.decodes = [self.running[s] for s in sorted(self.running)
                         if self.running[s] not in sched.prefills]
        if self._g_queue is not None:
            self._g_queue.set(len(self.waiting))
            self._g_running.set(len(self.running))
        return sched

    def _writable_span(self, seq: Sequence, k_eff: int, hint: int) -> bool:
        """Copy-on-write guard for this step's whole write span: plain
        decode writes one row at ``next_pos - 1``; a speculating step
        writes up to ``k_eff`` more (draft rows), which can cross into
        the next page(s).  Clone every shared page the span touches.
        A False mid-loop (pool dry) leaves earlier clones in place —
        they are private refcount-1 pages the retry (or the preemption
        the caller triggers) handles like any owned page."""
        ps = self.pool.cfg.page_size
        first = (seq.next_pos - 1) // ps
        last = (seq.next_pos - 1 + k_eff) // ps
        for li in range(first, last + 1):
            if not self.pool.ensure_writable(seq.uid, li * ps,
                                             node_hint=hint):
                return False
        return True

    # ------------------------------------------------------------------
    def _pick_victim(self, exclude: Sequence) -> Optional[Sequence]:
        """Batch loses before interactive; within a class the youngest
        arrival loses (FCFS fairness for the oldest).  Evicting batch
        first is what bounds interactive TTFT/ITL under pool pressure —
        and with uniform priorities this is exactly the old
        youngest-arrival rule."""
        candidates = [s for s in self.running.values() if s is not exclude]
        if not candidates:
            return None
        return max(candidates,
                   key=lambda s: (s.priority_rank, s.arrival, s.uid))

    def _preempt(self, seq: Sequence) -> None:
        self.n_preemptions += 1
        if self._m_preempt is not None:
            self._m_preempt.inc()
        seq.n_preempts += 1
        del self.running[seq.slot]
        self._free_slots.append(seq.slot)
        self.pool.release(seq.uid)
        seq.slot = -1
        seq.n_prefilled = 0
        seq.prefill_target = 0
        self._requeue(seq)
