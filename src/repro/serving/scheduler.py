"""Continuous-batching scheduler (the serving-side ArcLight claim).

The bucket engine (``serving.engine``) runs length-equal batches
strictly sequentially: no request can join mid-decode, and the batch
runs until its *slowest* member finishes.  On CPU servers continuous
batching is the dominant throughput lever (arXiv:2407.00029 §4): keep a
fixed-capacity **running batch** of ``max_running`` slot-indexed
sequences, and at every decode step

* **evict** finished sequences (their slot and KV pages free instantly),
* **admit** waiting requests into free slots when the KV pool can cover
  their prompt (FCFS; prefill interleaves with ongoing decode),
* **grow** each running sequence by one token slot, **preempting** the
  youngest-arrival sequence (recompute-style: its pages are freed and
  the whole prefix re-queues) when the pool is exhausted.

Slots are *positions in the device batch*, so membership changes are
pure data (block tables, position vectors) — the compiled decode step
never re-specialises.  The scheduler is deliberately jax-free: it
manipulates the :class:`~repro.serving.kv_pool.KVCachePool` and emits
:class:`Schedule` decisions; the engine turns decisions into device
calls.  Policies beyond FCFS (priority, SLA-aware, prefix-sharing
admission) slot in behind ``policy=`` — see ROADMAP "Open items".
"""

from __future__ import annotations

import dataclasses
from typing import Deque, Dict, List, Optional
from collections import deque

from .engine import Request
from .kv_pool import KVCachePool


@dataclasses.dataclass(eq=False)    # identity semantics: a Sequence is
class Sequence:                     # one admission ticket, never a value
    """A request's life inside the scheduler."""

    request: Request
    arrival: float = 0.0
    generated: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1                  # -1 = not running
    n_prefilled: int = 0            # tokens whose KV is resident
    n_preempts: int = 0
    t_first_sched: float = -1.0     # first time it got a slot

    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def full_prompt(self) -> List[int]:
        """Prompt for (re-)prefill: original prompt + tokens generated
        before a preemption (recompute-style restart)."""
        return list(self.request.prompt) + self.generated

    @property
    def next_pos(self) -> int:
        """Absolute position of the next token to be fed/decoded."""
        return len(self.request.prompt) + len(self.generated)

    def is_done(self, max_len: int) -> bool:
        sp = self.request.sampling
        if len(self.generated) >= sp.max_new_tokens:
            return True
        if (sp.eos_id is not None and self.generated
                and self.generated[-1] == sp.eos_id):
            return True
        return self.next_pos >= max_len


@dataclasses.dataclass
class Schedule:
    """One step's decisions, in execution order."""

    finished: List[Sequence] = dataclasses.field(default_factory=list)
    preempted: List[Sequence] = dataclasses.field(default_factory=list)
    prefills: List[Sequence] = dataclasses.field(default_factory=list)
    decodes: List[Sequence] = dataclasses.field(default_factory=list)


class ContinuousScheduler:
    def __init__(self, pool: KVCachePool, *, max_running: int,
                 max_len: int, policy: str = "fcfs") -> None:
        if policy != "fcfs":
            raise ValueError(f"unknown policy {policy!r}")
        self.pool = pool
        self.max_running = max_running
        self.max_len = max_len
        self.policy = policy
        self.waiting: Deque[Sequence] = deque()
        self.running: Dict[int, Sequence] = {}      # slot -> Sequence
        self._free_slots = list(range(max_running - 1, -1, -1))
        self.n_preemptions = 0

    # ------------------------------------------------------------------
    def submit(self, request: Request, arrival: float = 0.0) -> Sequence:
        seq = Sequence(request=request, arrival=arrival)
        self.waiting.append(seq)
        return seq

    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def _slot_node(self, slot: int) -> int:
        """Home-node hint: stripe slots across the pool's nodes so each
        node's threads mostly touch locally-resident KV pages."""
        n = max(len(self.pool.mm.kv_pools), 1)
        return slot % n

    def _requeue(self, seq: Sequence) -> None:
        """FCFS re-insertion by arrival time (stable)."""
        i = 0
        for i, w in enumerate(self.waiting):
            if w.arrival > seq.arrival:
                self.waiting.insert(i, seq)
                return
        self.waiting.append(seq)

    # ------------------------------------------------------------------
    def step(self, now: float = 0.0) -> Schedule:
        """Plan one engine step.  Order matters: evict, admit, grow."""
        sched = Schedule()

        # 1. evict finished sequences — slot and pages free immediately
        for slot in sorted(self.running):
            seq = self.running[slot]
            if seq.is_done(self.max_len):
                del self.running[slot]
                self._free_slots.append(slot)
                self.pool.free(seq.uid)
                seq.slot = -1
                sched.finished.append(seq)

        # 2. admit waiting arrivals while a slot + prompt pages exist
        while (self.waiting and self._free_slots
               and self.waiting[0].arrival <= now):
            seq = self.waiting[0]
            # reserve the prompt plus one decode token so admission can
            # never instantly re-preempt itself
            slot = self._free_slots[-1]
            if not self.pool.grow(seq.uid, len(seq.full_prompt) + 1,
                                  node_hint=self._slot_node(slot)):
                break
            self.waiting.popleft()
            self._free_slots.pop()
            seq.slot = slot
            seq.n_prefilled = len(seq.full_prompt)
            if seq.t_first_sched < 0:
                seq.t_first_sched = now
            self.running[slot] = seq
            sched.prefills.append(seq)

        # 3. grow every running sequence for this step's token write;
        #    preempt youngest arrivals when the pool runs dry
        for slot in sorted(list(self.running)):
            seq = self.running.get(slot)
            if seq is None:                 # preempted earlier in this loop
                continue
            if seq in sched.prefills:       # already covered by admission
                continue
            while not self.pool.grow(seq.uid, seq.next_pos + 1,
                                     node_hint=self._slot_node(slot)):
                victim = self._pick_victim(exclude=seq)
                if victim is None:
                    raise RuntimeError(
                        "KV pool cannot hold a single sequence — "
                        "raise n_pages or lower max_len")
                self._preempt(victim)
                sched.preempted.append(victim)
                if victim.slot == -1 and victim in sched.prefills:
                    sched.prefills.remove(victim)

        sched.decodes = [self.running[s] for s in sorted(self.running)
                         if self.running[s] not in sched.prefills]
        return sched

    # ------------------------------------------------------------------
    def _pick_victim(self, exclude: Sequence) -> Optional[Sequence]:
        """Youngest arrival loses (FCFS fairness for the oldest)."""
        candidates = [s for s in self.running.values() if s is not exclude]
        if not candidates:
            return None
        return max(candidates, key=lambda s: (s.arrival, s.uid))

    def _preempt(self, seq: Sequence) -> None:
        self.n_preemptions += 1
        seq.n_preempts += 1
        del self.running[seq.slot]
        self._free_slots.append(seq.slot)
        self.pool.free(seq.uid)
        seq.slot = -1
        seq.n_prefilled = 0
        self._requeue(seq)
