"""Serving engine — the ArcLight decoding frontend (paper Fig 2, top).

Handles weight loading, request scheduling, the prefill + autoregressive
decode loop, and sampling, over the backend model zoo.  Requests are
grouped into *length buckets* (equal prompt length ⇒ no padding waste —
the batching discipline real CPU servers use), each bucket is prefilled
once and decoded in lockstep with per-request completion tracking.

jit boundaries: one compiled ``prefill`` per (bucket_size, prompt_len)
and one compiled ``decode_step`` per bucket_size; the static cache
length keeps decode XLA-stable across steps.

This engine is the *baseline*: buckets run strictly sequentially and no
request can join mid-decode.  The production path is
``repro.serving.continuous.ContinuousServingEngine`` (paged KV pool +
continuous batching); both produce identical greedy tokens.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Model
from .sampler import SamplingParams, sample_grouped


#: admission/preemption ordering of the SLO-aware scheduler: lower rank
#: wins.  ``interactive`` traffic (chat turns — humans waiting on TTFT)
#: outranks ``batch`` (offline eval, summarisation pipelines).
PRIORITIES = ("interactive", "batch")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    #: SLO class — one of :data:`PRIORITIES`; the paged scheduler admits
    #: interactive before batch and preempts batch before interactive.
    #: The bucket engine ignores it (no admission queue to order).
    priority: str = "interactive"
    #: latency budget in seconds **from submission** (None = no
    #: deadline).  The scheduler pins it to an absolute deadline on its
    #: own clock at submit time (``arrival + deadline_s``) and sheds the
    #: request — queued or running — once the deadline passes, instead
    #: of burning prefill/decode on an answer nobody is waiting for.
    #: Over HTTP this rides as ``deadline_ms`` (remaining budget,
    #: re-anchored at every hop so clock skew never accumulates).
    deadline_s: Optional[float] = None
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Completion:
    uid: int
    prompt_len: int
    tokens: List[int]
    latency_s: float
    prefill_s: float
    #: absolute engine-clock stamps (``time.perf_counter``): work start /
    #: finish — ``throughput_report`` derives true end-to-end wall time
    #: from them instead of the old max(latency) (which under-reported
    #: whenever buckets ran sequentially)
    t0: float = 0.0
    t1: float = 0.0
    #: absolute stamp of the FIRST generated token (TTFT = t_first - t0;
    #: the serving_async bench compares engines on it)
    t_first: float = 0.0
    #: absolute stamp of FIRST scheduling (slot grant): TTFT decomposes
    #: into queue-wait (t_sched - t0) + prefill (t_first - t_sched).
    #: The bucket engine admits instantly, so it stamps t_sched = t0.
    t_sched: float = 0.0


class ServingEngine:
    def __init__(self, model: Model, params: Any, *, max_len: int = 1024,
                 cache_len: Optional[int] = None,
                 window_override: Optional[int] = None,
                 seed: int = 0) -> None:
        # device execution lives behind the runner seam (same layering
        # as the continuous stack: ModelRunner / EngineCore / drivers)
        from .runner import BucketRunner
        self.model = model
        self.params = params
        self.max_len = max_len
        self.cache_len = cache_len
        self.window_override = window_override
        self._key = jax.random.PRNGKey(seed)
        self.runner = BucketRunner(model, params,
                                   window_override=window_override)

    # ------------------------------------------------------------------
    def _buckets(self, requests: Sequence[Request],
                 max_batch: int) -> List[List[Request]]:
        by_len: Dict[int, List[Request]] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        buckets = []
        for _, rs in sorted(by_len.items()):
            for i in range(0, len(rs), max_batch):
                buckets.append(rs[i:i + max_batch])
        return buckets

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request], *,
                 max_batch: int = 8) -> List[Completion]:
        out: List[Completion] = []
        wall0 = time.perf_counter()
        prefill_total = 0.0
        for bucket in self._buckets(requests, max_batch):
            comps = self._run_bucket(bucket)
            prefill_total += comps[0].prefill_s
            out.extend(comps)
        #: true phase times of the last generate() call, for
        #: ``throughput_report(comps, **engine.last_phase_s)``
        wall = time.perf_counter() - wall0
        self.last_phase_s = {"wall_s": wall, "prefill_s": prefill_total,
                             "decode_s": max(wall - prefill_total, 0.0)}
        return sorted(out, key=lambda c: c.uid)

    def _run_bucket(self, bucket: List[Request]) -> List[Completion]:
        B = len(bucket)
        plen = len(bucket[0].prompt)
        tokens = jnp.asarray([r.prompt for r in bucket], jnp.int32)
        batch: Dict[str, Any] = {"tokens": tokens}
        for k in bucket[0].extra:
            batch[k] = jnp.asarray(
                np.stack([np.asarray(r.extra[k]) for r in bucket]))
        cache = self.runner.init_cache(B, self.max_len,
                                       cache_len=self.cache_len,
                                       memory_len=0)

        t0 = time.perf_counter()
        logits, cache = self.runner.prefill(batch, cache)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        max_new = max(r.sampling.max_new_tokens for r in bucket)
        # each request keeps its OWN SamplingParams (temperature/top-k);
        # lanes sharing params still sample in one device call
        sps = [r.sampling for r in bucket]
        done = np.zeros(B, bool)
        generated: List[List[int]] = [[] for _ in range(B)]
        cur = sample_grouped(logits, sps, self._next_key())
        t_first = time.perf_counter()
        for step in range(max_new):
            for b, r in enumerate(bucket):
                if done[b]:
                    continue
                t = int(cur[b, 0])
                generated[b].append(t)
                if ((r.sampling.eos_id is not None
                     and t == r.sampling.eos_id)
                        or len(generated[b]) >= r.sampling.max_new_tokens):
                    done[b] = True
            if done.all() or plen + step + 1 >= self.max_len:
                break
            logits, cache = self.runner.decode(cache, jnp.asarray(cur),
                                               jnp.asarray(plen + step))
            cur = sample_grouped(logits, sps, self._next_key())
        t1 = time.perf_counter()
        return [Completion(uid=r.uid, prompt_len=plen,
                           tokens=generated[b], latency_s=t1 - t0,
                           prefill_s=t_prefill, t0=t0, t1=t1,
                           t_first=t_first, t_sched=t0)
                for b, r in enumerate(bucket)]


def throughput_report(completions: Sequence[Completion], *,
                      wall_s: Optional[float] = None,
                      prefill_s: Optional[float] = None,
                      decode_s: Optional[float] = None) -> Dict[str, float]:
    """Phase-consistent throughput summary.

    Engines measure their own phase times (``engine.last_phase_s``) —
    pass them through for exact numbers.  Without them the report falls
    back to the completions' ``t0``/``t1`` stamps: true end-to-end wall
    is ``max(t1) - min(t0)`` (the old ``max(latency)`` under-reported
    whenever buckets ran sequentially, since each bucket's latency
    clock started at its own prefill).  Both phases use the same wall
    so ``prefill_s + decode_s ~= wall_s`` for sequential engines.
    """
    total_new = sum(len(c.tokens) for c in completions)
    stamped = any(c.t1 > 0 for c in completions)
    if wall_s is None:
        if stamped:
            wall_s = (max(c.t1 for c in completions)
                      - min(c.t0 for c in completions))
        else:   # no stamps (hand-built completions): best effort
            wall_s = max(c.latency_s for c in completions)
    if prefill_s is None:
        if stamped:
            # per-bucket prefills share one (t0, prefill_s) pair —
            # dedupe so a bucket isn't counted once per member
            prefill_s = sum(p for _, p in {(c.t0, c.prefill_s)
                                           for c in completions})
        else:   # stamp-less completions are per-request measurements
            prefill_s = sum(c.prefill_s for c in completions)
    if decode_s is None:
        decode_s = max(wall_s - prefill_s, 0.0)
    # a zero-duration phase reports 0.0 tok/s EXPLICITLY: the old
    # max(dt, 1e-9) clamp turned prefill-only runs (and virtual-clock
    # tests, where a phase can legitimately take no time) into
    # astronomical rates instead of admitting "no time was measured"
    prompt_total = sum(c.prompt_len for c in completions)
    return {
        "requests": len(completions),
        "new_tokens": total_new,
        "wall_s": wall_s,
        "decode_tok_per_s": total_new / decode_s if decode_s > 0 else 0.0,
        "prefill_tok_per_s": (prompt_total / prefill_s
                              if prefill_s > 0 else 0.0),
    }
