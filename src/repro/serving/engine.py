"""Serving engine — the ArcLight decoding frontend (paper Fig 2, top).

Handles weight loading, request scheduling, the prefill + autoregressive
decode loop, and sampling, over the backend model zoo.  Requests are
grouped into *length buckets* (equal prompt length ⇒ no padding waste —
the batching discipline real CPU servers use), each bucket is prefilled
once and decoded in lockstep with per-request completion tracking.

jit boundaries: one compiled ``prefill`` per (bucket_size, prompt_len)
and one compiled ``decode_step`` per bucket_size; the static cache
length keeps decode XLA-stable across steps.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Model
from .sampler import SamplingParams, sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Completion:
    uid: int
    prompt_len: int
    tokens: List[int]
    latency_s: float
    prefill_s: float


class ServingEngine:
    def __init__(self, model: Model, params: Any, *, max_len: int = 1024,
                 cache_len: Optional[int] = None,
                 window_override: Optional[int] = None,
                 seed: int = 0) -> None:
        self.model = model
        self.params = params
        self.max_len = max_len
        self.cache_len = cache_len
        self.window_override = window_override
        self._key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(
                p, b, c, window_override=window_override))
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(
                p, c, t, pos, window_override=window_override))

    # ------------------------------------------------------------------
    def _buckets(self, requests: Sequence[Request],
                 max_batch: int) -> List[List[Request]]:
        by_len: Dict[int, List[Request]] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        buckets = []
        for _, rs in sorted(by_len.items()):
            for i in range(0, len(rs), max_batch):
                buckets.append(rs[i:i + max_batch])
        return buckets

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    # ------------------------------------------------------------------
    def generate(self, requests: Sequence[Request], *,
                 max_batch: int = 8) -> List[Completion]:
        out: List[Completion] = []
        for bucket in self._buckets(requests, max_batch):
            out.extend(self._run_bucket(bucket))
        return sorted(out, key=lambda c: c.uid)

    def _run_bucket(self, bucket: List[Request]) -> List[Completion]:
        model, params = self.model, self.params
        B = len(bucket)
        plen = len(bucket[0].prompt)
        tokens = jnp.asarray([r.prompt for r in bucket], jnp.int32)
        batch: Dict[str, Any] = {"tokens": tokens}
        for k in bucket[0].extra:
            batch[k] = jnp.asarray(
                np.stack([np.asarray(r.extra[k]) for r in bucket]))
        memory_len = 0
        cache = model.init_cache(B, self.max_len, cache_len=self.cache_len,
                                 memory_len=memory_len)

        t0 = time.time()
        logits, cache = self._prefill(params, batch, cache)
        logits.block_until_ready()
        t_prefill = time.time() - t0

        max_new = max(r.sampling.max_new_tokens for r in bucket)
        sp = bucket[0].sampling
        done = np.zeros(B, bool)
        generated: List[List[int]] = [[] for _ in range(B)]
        cur = sample(logits, sp, self._next_key())
        for step in range(max_new):
            toks = np.asarray(cur[:, 0])
            for b, r in enumerate(bucket):
                if done[b]:
                    continue
                t = int(toks[b])
                generated[b].append(t)
                if ((r.sampling.eos_id is not None
                     and t == r.sampling.eos_id)
                        or len(generated[b]) >= r.sampling.max_new_tokens):
                    done[b] = True
            if done.all() or plen + step + 1 >= self.max_len:
                break
            logits, cache = self._decode(params, cache, cur,
                                         jnp.asarray(plen + step))
            cur = sample(logits, sp, self._next_key())
        dt = time.time() - t0
        return [Completion(uid=r.uid, prompt_len=plen,
                           tokens=generated[b], latency_s=dt,
                           prefill_s=t_prefill)
                for b, r in enumerate(bucket)]


def throughput_report(completions: Sequence[Completion]) -> Dict[str, float]:
    total_new = sum(len(c.tokens) for c in completions)
    wall = max(c.latency_s for c in completions)
    return {
        "requests": len(completions),
        "new_tokens": total_new,
        "wall_s": wall,
        "decode_tok_per_s": total_new / max(wall - completions[0].prefill_s,
                                            1e-9),
        "prefill_tok_per_s": (sum(c.prompt_len for c in completions)
                              / max(sum(c.prefill_s for c in completions),
                                    1e-9)),
    }
