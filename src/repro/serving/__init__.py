"""repro.serving substrate."""
