"""repro.serving substrate.

A layered serving stack over one model zoo (``docs/serving.md``
"Layered architecture"):

* :class:`~repro.serving.runner.ModelRunner` — device execution: paged
  KV cache, compiled prefill/decode, donation, CoW row copies.  No
  scheduling knowledge.  (:class:`~repro.serving.runner.BucketRunner`
  is the same seam for the length-bucket baseline.)
* :class:`~repro.serving.core.EngineCore` — one scheduler step + runner
  dispatch + sequence bookkeeping per ``step()`` call, with an injected
  :class:`~repro.serving.core.Clock` so tests never sleep.
* Front-ends over the core:
  :class:`~repro.serving.continuous.ContinuousServingEngine` (the
  synchronous pre-declared-arrivals driver) and
  :class:`~repro.serving.async_engine.AsyncEngine` (live
  submit/stream/poll/cancel on a background stepper thread).
* :class:`~repro.serving.engine.ServingEngine` — length-bucket batching
  (the paper's baseline discipline): simple, padding-free, but buckets
  run sequentially and nobody joins mid-decode.

Memory and policy under the hood: paged KV-cache pool (``kv_pool``,
refcounted prefix caching + retention LRU + copy-on-write) and the
continuous-batching scheduler (``scheduler``: per-step join/evict,
chunked prefill, preemption under memory pressure).

The network edge (``docs/serving.md`` "HTTP serving front-end"):
:class:`~repro.serving.http.HttpFrontend` serves ``/v1/completions``
(SSE streaming) + ``/healthz`` + ``/metrics`` over any engine-like
backend, and :class:`~repro.serving.router.Router` is such a backend
fanning out to N worker subprocesses (``repro.serving.worker``, spawned
by :class:`~repro.serving.supervisor.Supervisor`) with prefix-affinity
placement and worker-death failover.
"""

from . import faults
from .async_engine import (AsyncEngine, AsyncEngineError, CancelledError,
                           DeadlineExceededError, PollResult,
                           RequestHandle, RequestState)
from .continuous import ContinuousServingEngine
from .core import (Clock, EngineCore, MonotonicClock, StepResult,
                   VirtualClock)
from .engine import (PRIORITIES, Completion, Request, ServingEngine,
                     throughput_report)
from .http import HttpFrontend, Overloaded
from .kv_pool import (KVCachePool, KVPoolConfig, PrefixCache, PrefixMatch,
                      prefix_chain_key)
from .router import (AffinityRing, HttpWorkerClient, NoReplicasError,
                     Router, RouterError, RouterHandle, WorkerDiedError)
from .runner import BucketRunner, ModelRunner
from .sampler import SamplingParams, sample, sample_grouped
from .scheduler import ContinuousScheduler, Schedule, Sequence
from .supervisor import Supervisor, WorkerStartupError

__all__ = [
    "AffinityRing", "AsyncEngine", "AsyncEngineError", "BucketRunner",
    "CancelledError", "Clock", "Completion", "ContinuousScheduler",
    "ContinuousServingEngine", "DeadlineExceededError", "EngineCore",
    "HttpFrontend", "HttpWorkerClient", "KVCachePool", "KVPoolConfig",
    "ModelRunner", "MonotonicClock", "NoReplicasError", "Overloaded",
    "PRIORITIES", "PollResult", "PrefixCache", "PrefixMatch", "Request",
    "RequestHandle", "RequestState", "Router", "RouterError",
    "RouterHandle", "SamplingParams", "Schedule", "Sequence",
    "ServingEngine", "StepResult", "Supervisor", "VirtualClock",
    "WorkerDiedError", "WorkerStartupError", "faults", "sample",
    "sample_grouped", "throughput_report", "prefix_chain_key",
]
