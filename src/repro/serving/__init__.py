"""repro.serving substrate.

Two engines over one model zoo:

* :class:`~repro.serving.engine.ServingEngine` — length-bucket batching
  (the paper's baseline discipline): simple, padding-free, but buckets
  run sequentially and nobody joins mid-decode.
* :class:`~repro.serving.continuous.ContinuousServingEngine` — paged
  KV-cache pool (``kv_pool``) + continuous-batching scheduler
  (``scheduler``): slot-indexed running batch, per-step join/evict,
  preemption under memory pressure, NUMA-aware page placement,
  refcounted prefix caching (shared prompt pages, copy-on-write) and
  chunked prefill (long prompts interleave with decode).
"""

from .continuous import ContinuousServingEngine
from .engine import (Completion, Request, ServingEngine,
                     throughput_report)
from .kv_pool import KVCachePool, KVPoolConfig, PrefixCache, PrefixMatch
from .sampler import SamplingParams, sample, sample_grouped
from .scheduler import ContinuousScheduler, Schedule, Sequence

__all__ = [
    "Completion", "ContinuousScheduler", "ContinuousServingEngine",
    "KVCachePool", "KVPoolConfig", "PrefixCache", "PrefixMatch", "Request",
    "SamplingParams", "Schedule", "Sequence", "ServingEngine", "sample",
    "sample_grouped", "throughput_report",
]
