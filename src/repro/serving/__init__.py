"""repro.serving substrate.

A layered serving stack over one model zoo (``docs/serving.md``
"Layered architecture"):

* :class:`~repro.serving.runner.ModelRunner` — device execution: paged
  KV cache, compiled prefill/decode, donation, CoW row copies.  No
  scheduling knowledge.  (:class:`~repro.serving.runner.BucketRunner`
  is the same seam for the length-bucket baseline.)
* :class:`~repro.serving.core.EngineCore` — one scheduler step + runner
  dispatch + sequence bookkeeping per ``step()`` call, with an injected
  :class:`~repro.serving.core.Clock` so tests never sleep.
* Front-ends over the core:
  :class:`~repro.serving.continuous.ContinuousServingEngine` (the
  synchronous pre-declared-arrivals driver) and
  :class:`~repro.serving.async_engine.AsyncEngine` (live
  submit/stream/poll/cancel on a background stepper thread).
* :class:`~repro.serving.engine.ServingEngine` — length-bucket batching
  (the paper's baseline discipline): simple, padding-free, but buckets
  run sequentially and nobody joins mid-decode.

Memory and policy under the hood: paged KV-cache pool (``kv_pool``,
refcounted prefix caching + retention LRU + copy-on-write) and the
continuous-batching scheduler (``scheduler``: per-step join/evict,
chunked prefill, preemption under memory pressure).
"""

from .async_engine import (AsyncEngine, AsyncEngineError, CancelledError,
                           PollResult, RequestHandle, RequestState)
from .continuous import ContinuousServingEngine
from .core import (Clock, EngineCore, MonotonicClock, StepResult,
                   VirtualClock)
from .engine import (Completion, Request, ServingEngine,
                     throughput_report)
from .kv_pool import KVCachePool, KVPoolConfig, PrefixCache, PrefixMatch
from .runner import BucketRunner, ModelRunner
from .sampler import SamplingParams, sample, sample_grouped
from .scheduler import ContinuousScheduler, Schedule, Sequence

__all__ = [
    "AsyncEngine", "AsyncEngineError", "BucketRunner", "CancelledError",
    "Clock", "Completion", "ContinuousScheduler",
    "ContinuousServingEngine", "EngineCore", "KVCachePool", "KVPoolConfig",
    "ModelRunner", "MonotonicClock", "PollResult", "PrefixCache",
    "PrefixMatch", "Request", "RequestHandle", "RequestState",
    "SamplingParams", "Schedule", "Sequence", "ServingEngine",
    "StepResult", "VirtualClock", "sample", "sample_grouped",
    "throughput_report",
]
