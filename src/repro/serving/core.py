"""Single-step engine core of the serving stack (``EngineCore``).

Middle of the three-layer split (runner / core / async): one
:meth:`EngineCore.step` call is exactly one iteration of the old
monolithic continuous loop — scheduler step, queued copy-on-write page
copies, prefill chunks, one batched decode, sampling, prefix
registration and finish bookkeeping — with **no loop, no sleeping and
no thread** of its own.  Anyone can drive it:

* the synchronous driver (``ContinuousServingEngine.generate``) loops
  it over a pre-declared arrivals list and must produce byte-identical
  greedy tokens to the pre-split engine;
* the :class:`~repro.serving.async_engine.AsyncEngine` stepper thread
  loops it against a live, lock-guarded inbox;
* tests call it step-by-step and assert on the returned
  :class:`StepResult` without any timing races.

Time is **injected** (:class:`Clock`): the core never calls
``time.perf_counter`` or ``time.sleep`` directly, so a
:class:`VirtualClock` lets arrival-staggered tests run without a
single real sleep (idle waits advance virtual time for free) while the
default :class:`MonotonicClock` gives production wall-clock stamps.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Model
from ..obs.metrics import MetricsRegistry, NullRegistry
from ..obs.trace import NullTracer, RequestTracer
from . import faults
from .engine import Completion, Request
from .kv_pool import KVCachePool, KVPoolConfig
from .runner import ModelRunner, _pad_bucket
from .sampler import sample, sample_grouped
from .scheduler import ContinuousScheduler, Sequence
from .spec import lookahead_for, note_accept, propose


class Clock:
    """Injected time source.  ``now()`` is monotonic seconds;
    ``sleep(dt)`` blocks (or virtually advances) for ``dt`` seconds."""

    def now(self) -> float:
        raise NotImplementedError

    def sleep(self, dt: float) -> None:
        raise NotImplementedError


class MonotonicClock(Clock):
    """Real wall time (``time.perf_counter`` / ``time.sleep``)."""

    def now(self) -> float:
        return time.perf_counter()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class VirtualClock(Clock):
    """Deterministic test clock: ``sleep`` advances ``now()`` without
    any wall time passing, so idle engine steps are free and
    arrival-staggered workloads run as fast as the device allows."""

    def __init__(self, start: float = 0.0) -> None:
        self.t = start
        self.slept_s = 0.0

    def now(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        if dt > 0:
            self.t += dt
            self.slept_s += dt

    def advance(self, dt: float) -> None:
        self.t += dt


@dataclasses.dataclass
class StepResult:
    """What one :meth:`EngineCore.step` did.

    ``emitted`` is every (uid, token) sampled this step in emission
    order — the async layer's incremental delivery feed.  ``finished``
    carries completed requests (tokens + timing stamps).  ``idle``
    means no forward pass ran: the driver may park until the next
    arrival/submission.
    """

    finished: List[Completion] = dataclasses.field(default_factory=list)
    emitted: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    #: uids shed this step because their deadline passed (queued or
    #: running) — pages/slots already drained; the async layer fails
    #: the handles with a deadline-exceeded cause
    expired: List[int] = dataclasses.field(default_factory=list)
    n_prefills: int = 0
    n_decodes: int = 0

    @property
    def idle(self) -> bool:
        return self.n_prefills == 0 and self.n_decodes == 0


class EngineCore:
    """Scheduler + runner + sequence bookkeeping, one step at a time."""

    def __init__(self, model: Model, params, *, max_len: int = 1024,
                 max_running: int = 8, page_size: int = 16,
                 n_pages: Optional[int] = None, n_nodes: int = 1,
                 numa: bool = True,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = True,
                 window_override: Optional[int] = None,
                 mesh=None, policy=None, quant=None,
                 spec_decode: int = 0,
                 seed: int = 0, clock: Optional[Clock] = None,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[RequestTracer] = None) -> None:
        cfg = model.cfg
        if spec_decode < 0:
            raise ValueError("spec_decode must be >= 0")
        #: self-speculative decoding lookahead (``--spec-decode k``):
        #: each decode step drafts up to k tokens per greedy lane by
        #: prompt lookup (serving.spec) and verifies them in ONE
        #: batched forward — accepted drafts are decode steps the
        #: hardware never ran.  0 disables (plain one-token decode).
        self.spec_decode = int(spec_decode)
        # quantization policy (repro.quant.policy.QuantPolicy): decides
        # the weight format the runner loads and the KV page dtype the
        # pool sizes its bytes for.  None == full-precision serving.
        if quant is None:
            from ..quant.policy import QuantPolicy
            quant = QuantPolicy()
        self.quant = quant
        self.model = model
        self.params = params
        self.max_len = max_len
        self.max_running = max_running
        self.page_size = page_size
        if n_pages is None:
            # page 0 scratch + a full pool: every slot can reach max_len.
            # Pass a smaller n_pages to trade memory for preemptions.
            n_pages = 1 + max_running * (-(-max_len // page_size))
        self.n_pages = n_pages
        self.clock = clock if clock is not None else MonotonicClock()
        #: metrics registry every layer below reports into (a private
        #: real registry by default — pass NullRegistry to disable);
        #: tracer defaults to the no-op twin (opt in via --trace)
        self.registry = (registry if registry is not None
                         else MetricsRegistry())
        self.tracer = tracer if tracer is not None else NullTracer()
        self._key = jax.random.PRNGKey(seed)

        # mesh mode (TP serving): each mesh shard stands in for one
        # NUMA node (the paper's node≅shard mapping), so page planning
        # stripes rows across n_nodes AND splits every page's bytes across
        # the shards' head slices (KVPoolConfig.n_shards)
        n_shards = (int(mesh.shape.get("model", 1))
                    if mesh is not None else 1)
        self.pool = KVCachePool(KVPoolConfig(
            n_pages=n_pages, page_size=page_size, n_layers=cfg.n_layers,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            dtype_bytes=np.dtype(cfg.dtype).itemsize, n_nodes=n_nodes,
            numa=numa, n_shards=n_shards,
            kv_dtype=quant.kv_dtype), prefix_cache=prefix_cache)
        self.pool.bind_registry(self.registry)
        self.scheduler = ContinuousScheduler(
            self.pool, max_running=max_running, max_len=max_len,
            prefill_chunk=prefill_chunk, spec_lookahead=self.spec_decode,
            registry=self.registry)
        self.runner = ModelRunner(
            model, params, max_running=max_running, max_len=max_len,
            page_size=page_size, n_pages=n_pages,
            window_override=window_override, mesh=mesh, policy=policy,
            quant=quant, registry=self.registry, clock=self.clock)

        self._meta: Dict[int, Dict[str, object]] = {}  # uid -> timing stamps
        self._t_last_decode: Optional[float] = None
        #: wall gaps between consecutive decode steps since the last
        #: reset (bench: max gap == worst admission stall)
        self.decode_gaps_s: List[float] = []

        # instruments resolved ONCE here — step() touches only bound
        # handles, never the registry (docs/observability.md budget)
        reg = self.registry
        self._m_phase_prefill = reg.counter(
            "serving.phase.prefill_s",
            "wall seconds spent running prefill chunks (run-scoped)")
        self._m_phase_decode = reg.counter(
            "serving.phase.decode_s",
            "wall seconds spent in batched decode (run-scoped)")
        self._c_prefill_s = self._m_phase_prefill.labels()
        self._c_decode_s = self._m_phase_decode.labels()
        self._m_itl = reg.histogram(
            "serving.decode.itl_ms",
            "inter-token latency: wall gap between consecutive decode "
            "steps (run-scoped)")
        self._h_itl = self._m_itl.labels()
        self._h_chunk = reg.histogram(
            "serving.prefill.chunk_ms",
            "one prefill chunk end-to-end (dispatch + sample)").labels()
        self._c_steps = reg.counter(
            "serving.steps", "engine steps, idle included").labels()
        self._c_tok_prefill = reg.counter(
            "serving.tokens.prefill", "prompt tokens prefilled").labels()
        self._c_tok_decode = reg.counter(
            "serving.tokens.decode",
            "tokens sampled by batched decode").labels()
        self._h_occupancy = reg.histogram(
            "serving.batch.occupancy",
            "decode-batch occupancy per decoding step",
            buckets=tuple(float(i) for i in range(1, max_running + 1)),
            ).labels()
        # speculative-decoding instruments, bound only when the feature
        # is on so k=0 snapshots stay free of dead spec.* series
        self._c_spec_drafted = self._c_spec_accepted = None
        self._c_spec_rollbacks = self._c_spec_pages = None
        self._c_spec_autooff = None
        self._h_spec_accept = None
        if self.spec_decode:
            self._c_spec_autooff = reg.counter(
                "spec.auto_disabled",
                "sequences whose speculation was turned off after the "
                "windowed accept rate collapsed (spec.note_accept)"
            ).labels()
            self._c_spec_drafted = reg.counter(
                "spec.drafted",
                "draft tokens proposed by the prompt-lookup drafter "
                "and fed to verify").labels()
            self._c_spec_accepted = reg.counter(
                "spec.accepted",
                "draft tokens accepted (each one a decode forward the "
                "device never ran)").labels()
            self._c_spec_rollbacks = reg.counter(
                "spec.rollbacks",
                "verify steps that rejected at least one draft token "
                "for a lane").labels()
            self._c_spec_pages = reg.counter(
                "spec.pages_returned",
                "speculative page grants returned to the pool after a "
                "rejected draft (KVCachePool.truncate_to)").labels()
            self._h_spec_accept = reg.histogram(
                "spec.accept_rate",
                "per-lane fraction of drafted tokens accepted each "
                "verify step",
                buckets=tuple(i / 8 for i in range(1, 9))).labels()
        # per-(node, shard) pool gauges, sampled after every step; a
        # page's bytes are split across every shard's head-slice pool,
        # so each shard sees the same per-node free count.  Skipped
        # entirely under NullRegistry (no per-step dict build).
        self._pool_gauges: List[Tuple[object, int]] = []
        self._g_retained = None
        if not isinstance(reg, NullRegistry):
            g_free = reg.gauge(
                "kv_pool.pages_free",
                "allocatable pages on this NUMA node as seen by this "
                "TP shard's head-slice pool")
            for node in range(max(self.pool.mm.kv_node_count, 1)):
                for shard in range(n_shards):
                    self._pool_gauges.append(
                        (g_free.labels(node=node, shard=shard), node))
            self._g_retained = reg.gauge(
                "kv_pool.pages_retained",
                "refcount-0 prefix pages parked in the retention LRU",
                ).labels()
            # static capacity facts, set once: together they let a
            # dashboard derive pages-per-byte-budget, the quantity the
            # int8 KV format (--kv-dtype int8) roughly doubles
            reg.gauge(
                "kv_pool.page_bytes",
                "device bytes per KV page across all layers/heads under "
                "the configured kv_dtype").labels(
                    kv_dtype=quant.kv_dtype).set(
                        float(self.pool.cfg.page_bytes))
            reg.gauge(
                "kv_pool.pages_total",
                "total pages in the pool, scratch page 0 included",
                ).labels().set(float(n_pages))

    # ------------------------------------------------------------------
    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def check_request(self, request: Request) -> None:
        """Reject a request the engine can never serve.  Both limits
        are caught HERE, at submit, so an impossible request fails its
        own handle instead of raising inside the scheduler mid-step
        (which would kill the async stepper for everyone)."""
        if len(request.prompt) >= self.max_len:
            raise ValueError(
                f"request {request.uid}: prompt of {len(request.prompt)} "
                f"tokens does not fit max_len={self.max_len} (needs at "
                "least one decode slot)")
        need = self.pool.cfg.pages_for(len(request.prompt) + 1)
        if need > self.pool.cfg.max_pages_per_seq:
            raise ValueError(
                f"request {request.uid}: prompt needs {need} pages; "
                f"pool only has {self.pool.cfg.max_pages_per_seq}")

    @property
    def phase_s(self) -> Dict[str, float]:
        """Thin parity view over the registry-backed phase counters
        (pre-PR6 callers read ``core.phase_s[...]``).  Zeros under a
        ``NullRegistry``."""
        return {"prefill_s": self._m_phase_prefill.value(),
                "decode_s": self._m_phase_decode.value()}

    def reset_run_stats(self) -> None:
        """Zero the per-run accumulators (phase counters, ITL histogram,
        decode gaps) so back-to-back driver runs report cleanly.
        Cumulative series (scheduler, pool, dispatch) keep counting."""
        self.decode_gaps_s = []
        self._t_last_decode = None
        self._m_phase_prefill.reset()
        self._m_phase_decode.reset()
        self._m_itl.reset()

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    # ------------------------------------------------------------------
    def submit(self, request: Request, *, arrival: float = 0.0,
               t0: Optional[float] = None) -> Sequence:
        """Queue a request with the scheduler.  ``arrival`` is on the
        driver's scheduling timeline (the ``now`` passed to ``step``);
        ``t0`` is the absolute clock stamp latency is measured from
        (defaults to the current clock)."""
        self.check_request(request)
        seq = self.scheduler.submit(request, arrival=arrival)
        t0_abs = t0 if t0 is not None else self.clock.now()
        self._meta[seq.uid] = {"t0": t0_abs, "arrival": arrival}
        self.tracer.event(seq.uid, "QUEUED", t0_abs,
                          prompt_len=len(request.prompt))
        return seq

    def cancel(self, seq: Sequence, *,
               trace_event: Optional[str] = "CANCELLED") -> bool:
        """Tear a sequence down wherever it lives (queued, prefilling
        or decoding): slot and every page reference free immediately.
        Returns False when it already left the scheduler.
        ``trace_event`` names the terminal trace event to emit (the
        async layer passes None when it records FAILED itself)."""
        out = self.scheduler.cancel(seq)
        if out and trace_event is not None:
            self.tracer.event(seq.uid, trace_event, self.clock.now())
        self._meta.pop(seq.uid, None)
        return out

    # ------------------------------------------------------------------
    def _sync_tables(self) -> None:
        """Host block tables -> device cache array."""
        bt = np.zeros((self.max_running, self.runner.max_pages), np.int32)
        for slot, seq in self.scheduler.running.items():
            pages = self.pool.block_table(seq.uid)
            bt[slot, :len(pages)] = pages
        self.runner.set_block_tables(bt)

    def _apply_copies(self) -> None:
        """Apply the pool's queued copy-on-write page copies to the
        device cache.  Must run after scheduling and before this step's
        forwards, so a resumed prefill or decode reads the cloned rows,
        not scratch."""
        copies = self.pool.drain_copies()
        if not copies:
            return
        if self.tracer.enabled:
            # attribute cloned destination pages back to owning uids
            # (only walks block tables on the rare CoW step)
            t = self.clock.now()
            dsts = {d for _, d in copies}
            for seq in self.scheduler.running.values():
                n = sum(1 for p in self.pool.block_table(seq.uid)
                        if p in dsts)
                if n:
                    self.tracer.event(seq.uid, "COW", t, pages=n)
        src, dst = self.pool.copy_row_plan(
            copies, pad_to_pages=_pad_bucket(len(copies), lo=1))
        self.runner.apply_copy_rows(src, dst)

    def _finish(self, seq: Sequence) -> Completion:
        m = self._meta.pop(seq.uid)
        # t_first_sched lives on the driver's scheduling timeline (the
        # ``now`` fed to step); both drivers submit with
        # t0 = clock0 + arrival, so clock0 = t0 - arrival converts it
        # to the absolute clock the other stamps use
        if seq.t_first_sched >= 0:
            t_sched = m["t0"] - m.get("arrival", 0.0) + seq.t_first_sched
        else:
            t_sched = m["t0"]
        self.tracer.event(seq.uid, "FINISHED", m["t1"],
                          n_tokens=len(seq.generated),
                          n_preempts=seq.n_preempts)
        return Completion(
            uid=seq.uid, prompt_len=len(seq.request.prompt),
            tokens=list(seq.generated), latency_s=m["t1"] - m["t0"],
            prefill_s=m.get("prefill", 0.0), t0=m["t0"], t1=m["t1"],
            t_first=m.get("t_first", m["t1"]), t_sched=t_sched)

    # ------------------------------------------------------------------
    def step(self, now: float = 0.0) -> StepResult:
        """One engine step: schedule, apply CoW copies, run prefill
        chunks, run the batched decode, sample, finish.  ``now`` gates
        admission of waiting arrivals (driver-relative seconds)."""
        clock = self.clock
        tracer = self.tracer
        if faults.ACTIVE:       # injected worker latency (chaos tests)
            faults.maybe_sleep("step.latency_ms")
        self._c_steps.inc()
        plan = self.scheduler.step(now)
        for seq in plan.expired:
            # scheduler already drained slot + pages; surface the death
            # through the normal terminal vocabulary so trace validation
            # holds, and let the async layer fail the handle
            tracer.event(seq.uid, "FAILED", clock.now(),
                         error="deadline exceeded",
                         n_tokens=len(seq.generated))
            self._meta.pop(seq.uid, None)
        for seq in plan.preempted:
            tracer.event(seq.uid, "PREEMPTED", clock.now(),
                         n_preempts=seq.n_preempts)
            m = self._meta.get(seq.uid)
            if m is not None:       # next admission re-opens PREFILLING
                m.pop("state", None)
        self._apply_copies()
        res = StepResult(expired=[s.uid for s in plan.expired],
                         n_prefills=len(plan.prefills),
                         n_decodes=len(plan.decodes))
        for seq in plan.finished:
            res.finished.append(self._finish(seq))

        if plan.prefills:
            self._sync_tables()
        for seq in plan.prefills:
            t0 = clock.now()
            prompt = seq.full_prompt
            start = seq.n_prefilled
            n = self.scheduler.chunk_for(seq)
            fresh = start == 0 and n == seq.prefill_target
            m = self._meta[seq.uid]
            if m.get("state") != "PREFILLING":  # (re-)entered prefill
                m["state"] = "PREFILLING"
                tracer.event(seq.uid, "PREFILLING", t0, start=start,
                             cached=seq.n_cached_tokens)
            tracer.event(seq.uid, "PREFILL_CHUNK", t0, start=start, n=n)
            logits = self.runner.prefill_chunk(
                prompt[start:start + n], slot=seq.slot, start=start,
                fresh=fresh)
            seq.n_prefilled += n
            if not seq.is_prefilling:           # final chunk: sample
                tok = int(np.asarray(sample(
                    logits, seq.request.sampling,
                    self._next_key()))[0, 0])
                seq.generated.append(tok)
                res.emitted.append((seq.uid, tok))
                # prompt KV is resident now — index it for reuse
                self.pool.register_prefix(seq.uid, prompt)
                m.setdefault("t_first", clock.now())
                m["state"] = "DECODING"
                tracer.event(seq.uid, "DECODING", clock.now())
            dt = clock.now() - t0
            self._c_prefill_s.inc(dt)
            self._h_chunk.observe(dt * 1e3)
            self._c_tok_prefill.inc(n)
            m["prefill"] = m.get("prefill", 0.0) + dt
            if not seq.is_prefilling and seq.is_done(self.max_len):
                m["t1"] = clock.now()

        if plan.decodes:
            t0 = clock.now()
            self._sync_tables()
            # draft by prompt lookup (greedy lanes only); a step where
            # no lane drafts falls through to plain one-token decode so
            # non-repetitive traffic never pays the (k+1)-wide forward
            drafts: Dict[int, List[int]] = {}
            if self.spec_decode:
                for seq in plan.decodes:
                    k_eff = lookahead_for(seq, self.spec_decode,
                                          self.max_len)
                    if k_eff > 0:
                        d = propose(seq.full_prompt, k_eff)
                        if d:
                            drafts[seq.slot] = d
            if drafts:
                n_emitted = self._decode_verify(plan, drafts, res)
            else:
                pos = np.full((self.max_running,), -1, np.int32)
                fed = np.zeros((self.max_running, 1), np.int32)
                # idle lanes borrow a real lane's params so grouping
                # (and therefore key consumption) never depends on dead
                # slots
                sps = [plan.decodes[0].request.sampling] \
                    * self.max_running
                for seq in plan.decodes:
                    pos[seq.slot] = seq.next_pos - 1  # fed-token position
                    fed[seq.slot, 0] = seq.generated[-1]
                    sps[seq.slot] = seq.request.sampling
                logits = self.runner.decode(fed, pos)
                toks = sample_grouped(logits, sps, self._next_key())
                for seq in plan.decodes:
                    tok = int(toks[seq.slot, 0])
                    seq.generated.append(tok)
                    res.emitted.append((seq.uid, tok))
                    if seq.is_done(self.max_len):
                        self._meta[seq.uid]["t1"] = clock.now()
                n_emitted = len(plan.decodes)
            t1 = clock.now()
            if self._t_last_decode is not None:
                gap = t1 - self._t_last_decode
                self.decode_gaps_s.append(gap)
                self._h_itl.observe(gap * 1e3)
            self._t_last_decode = t1
            self._c_decode_s.inc(t1 - t0)
            self._c_tok_decode.inc(n_emitted)
            self._h_occupancy.observe(float(len(plan.decodes)))

        if self._pool_gauges:
            free = self.pool.free_pages_by_node()
            for g, node in self._pool_gauges:
                g.set(free.get(node, 0))
            self._g_retained.set(self.pool.n_retained())

        return res

    def _decode_verify(self, plan, drafts: Dict[int, List[int]],
                       res: StepResult) -> int:
        """Speculative decode step: feed every decoding lane its last
        token plus its draft (lanes without one ride along as plain
        decode), verify all positions in one forward, accept each
        lane's longest matching draft prefix plus the model's own token
        at the first mismatch (the "bonus" token).

        Byte parity with k=0 is structural: the verify kernel scores
        position j with exactly the context sequential decode would see
        (``Model.verify_step``), every emitted token is the model's own
        greedy argmax there, emission stops at ``is_done`` exactly like
        the one-token loop, and the step consumes one PRNG key like
        plain decode (draft lanes are greedy, so sampling lanes see the
        identical key sequence).  Returns the emitted-token count.
        """
        clock = self.clock
        S = self.spec_decode + 1
        pos = np.full((self.max_running,), -1, np.int32)
        fed = np.zeros((self.max_running, S), np.int32)
        n_fed = np.ones((self.max_running,), np.int32)
        sps = [plan.decodes[0].request.sampling] * self.max_running
        for seq in plan.decodes:
            pos[seq.slot] = seq.next_pos - 1        # fed-token position
            fed[seq.slot, 0] = seq.generated[-1]
            ds = drafts.get(seq.slot)
            if ds:
                fed[seq.slot, 1:1 + len(ds)] = ds
                n_fed[seq.slot] = 1 + len(ds)
            sps[seq.slot] = seq.request.sampling
        logits = self.runner.verify(fed, pos, n_fed)
        # the model's greedy choice at every fed position — same
        # argmax (same tie-breaking) sample() runs for greedy lanes
        targets = np.asarray(jnp.argmax(logits, axis=-1))   # (B, S)
        toks = sample_grouped(logits[:, :1], sps, self._next_key())
        n_emitted = 0
        for seq in plan.decodes:
            ds = drafts.get(seq.slot)
            if not ds:                      # plain decode rode along
                tok = int(toks[seq.slot, 0])
                seq.generated.append(tok)
                res.emitted.append((seq.uid, tok))
                n_emitted += 1
                if seq.is_done(self.max_len):
                    self._meta[seq.uid]["t1"] = clock.now()
                continue
            m = len(ds)
            a = 0
            while a < m and ds[a] == int(targets[seq.slot, a]):
                a += 1
            # emit the a accepted drafts + the bonus token, stopping at
            # EOS / max_new exactly where one-token decode would have
            for j in range(a + 1):
                tok = int(targets[seq.slot, j])
                seq.generated.append(tok)
                res.emitted.append((seq.uid, tok))
                n_emitted += 1
                if seq.is_done(self.max_len):
                    self._meta[seq.uid]["t1"] = clock.now()
                    break
            self._c_spec_drafted.inc(m)
            self._c_spec_accepted.inc(a)
            if a < m:
                self._c_spec_rollbacks.inc()
            self._h_spec_accept.observe(a / m)
            # live accept-rate feedback: a lane whose windowed rate has
            # collapsed stops drafting (lookahead_for returns 0) — the
            # (k+1)-wide verify forward is pure loss for it
            if note_accept(seq, a, m):
                self._c_spec_autooff.inc()
            # roll back the worst-case page grant: KV rows past the
            # accepted frontier are garbage; pages past the next write
            # go home (re-granted next step if the lane drafts again)
            returned = self.pool.truncate_to(seq.uid, seq.next_pos)
            if returned:
                self._c_spec_pages.inc(returned)
        return n_emitted
