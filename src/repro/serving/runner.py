"""Device-execution layer of the serving stack (``ModelRunner``).

Bottom of the three-layer split (runner / core / async — see
``docs/serving.md`` "Layered architecture"): a :class:`ModelRunner`
owns everything that touches the device for the paged continuous
engine — the per-layer paged KV cache, the compiled prefill / decode
functions and their donation contracts, block-table upload, and the
batched copy-on-write row copier — and knows **nothing** about
scheduling, sequences, arrival times or sampling policy.  Its whole
API is "run this chunk / this decode batch against the cache": the
:class:`~repro.serving.core.EngineCore` turns `Schedule` decisions
into these calls, and anything driving the core (the synchronous
``generate`` driver, the async stepper thread, a test) gets the same
compiled artifacts.

Compilation contracts (moved verbatim from the pre-split engine, so
compile counts and donation behaviour are unchanged):

* ``decode`` compiles **once** per runner: (B, 1) tokens + (B,)
  positions + block tables are all data, so batch membership changes
  never re-specialise XLA;
* ``prefill`` compiles once per (padded chunk bucket, context-page
  bucket) pair — chunk buckets are next-power-of-two lengths with the
  real length a traced scalar;
* the cache argument is **donated** on both, and the paged pool is a
  list of per-layer buffers outside any scan carry (the scan-escape
  layout), so every step is an in-place row scatter costing O(touched
  bytes), not O(pool bytes);
* the CoW copier is one donated gather+scatter over the per-layer
  buffer list, with row plans padded to buckets by the caller.

:class:`BucketRunner` is the same seam for the length-bucket baseline
(``serving.engine.ServingEngine``): per-(batch, prompt-len) prefill +
per-batch decode jits over the ring cache, so both engines sit on one
runner/sampling boundary.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Model


def _pad_bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class ModelRunner:
    """Pure ``(device state, chunk/batch) -> logits`` execution over a
    paged KV cache.  No scheduling knowledge; see module docstring."""

    def __init__(self, model: Model, params: Any, *, max_running: int,
                 max_len: int, page_size: int, n_pages: int,
                 window_override: Optional[int] = None) -> None:
        self.model = model
        self.params = params
        self.max_running = max_running
        self.max_len = max_len
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_pages = -(-max_len // page_size)
        self.window_override = window_override
        self.cache = model.init_cache(max_running, max_len,
                                      page_size=page_size, n_pages=n_pages)
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(
                p, c, t, pos, page_size=page_size,
                window_override=window_override),
            donate_argnums=1)
        #: (padded chunk len, ctx page bucket) -> compiled prefill;
        #: ctx bucket 0 is the one-shot fresh-sequence path
        self._prefill_jits: Dict[Tuple[int, int], Any] = {}
        # batched CoW page copier over the per-layer buffer list: one
        # donated gather+scatter moves every queued page in-place on
        # every layer (un-jitted .at[].set would copy each buffer once
        # per page); row counts bucket so compiles stay few
        self._copy_rows = jax.jit(
            lambda layers, src, dst: jax.tree.map(
                lambda a: a.at[dst].set(a[src]), layers),
            donate_argnums=0)

    # ------------------------------------------------------------------
    def _prefill_fn(self, padded_len: int, ctx_pages: int):
        key = (padded_len, ctx_pages)
        if key not in self._prefill_jits:
            if ctx_pages:
                self._prefill_jits[key] = jax.jit(
                    lambda p, b, c, slot, plen, start:
                    self.model.prefill_paged(
                        p, b, c, slot, plen, start=start,
                        ctx_pages=ctx_pages, page_size=self.page_size,
                        window_override=self.window_override),
                    donate_argnums=2)
            else:
                self._prefill_jits[key] = jax.jit(
                    lambda p, b, c, slot, plen: self.model.prefill_paged(
                        p, b, c, slot, plen, page_size=self.page_size,
                        window_override=self.window_override),
                    donate_argnums=2)
        return self._prefill_jits[key]

    def set_block_tables(self, tables: np.ndarray) -> None:
        """Upload the host (max_running, max_pages) block-table array."""
        self.cache["block_tables"] = jnp.asarray(tables)

    def apply_copy_rows(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Apply a ``KVCachePool.copy_row_plan`` to every per-layer
        buffer: whole-page K/V row copies, one compiled dispatch."""
        self.cache = dict(self.cache)
        self.cache["layers"] = self._copy_rows(
            self.cache["layers"], jnp.asarray(src), jnp.asarray(dst))

    def prefill_chunk(self, tokens: Sequence[int], *, slot: int,
                      start: int, fresh: bool) -> jax.Array:
        """Run one prefill chunk (``tokens`` at absolute positions
        ``[start, start + len)``) into batch slot ``slot``; returns the
        chunk's last-token logits.  ``fresh`` selects the cheaper
        one-shot path (nothing resident to attend over)."""
        n = len(tokens)
        padded = _pad_bucket(n)
        toks = np.zeros((1, padded), np.int32)
        toks[0, :n] = tokens
        batch = {"tokens": jnp.asarray(toks)}
        if fresh:
            logits, self.cache = self._prefill_fn(padded, 0)(
                self.params, batch, self.cache,
                jnp.asarray(slot, jnp.int32), jnp.asarray(n, jnp.int32))
        else:
            ctx_pages = min(
                _pad_bucket(-(-(start + n) // self.page_size), lo=1),
                self.max_pages)
            logits, self.cache = self._prefill_fn(padded, ctx_pages)(
                self.params, batch, self.cache,
                jnp.asarray(slot, jnp.int32), jnp.asarray(n, jnp.int32),
                jnp.asarray(start, jnp.int32))
        return logits

    def decode(self, fed: np.ndarray, pos: np.ndarray) -> jax.Array:
        """One batched decode step: ``fed`` (max_running, 1) tokens,
        ``pos`` (max_running,) absolute fed-token positions (-1 = idle
        slot, masked + scratch-paged).  Returns (max_running, 1, V)."""
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(fed), jnp.asarray(pos))
        return logits


class BucketRunner:
    """Device seam for the length-bucket baseline: ring-cache prefill +
    lockstep decode jits, one compile per (batch, prompt-len) /
    batch-size respectively."""

    def __init__(self, model: Model, params: Any, *,
                 window_override: Optional[int] = None) -> None:
        self.model = model
        self.params = params
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(
                p, b, c, window_override=window_override))
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(
                p, c, t, pos, window_override=window_override))

    def init_cache(self, batch: int, max_len: int, *,
                   cache_len: Optional[int] = None,
                   memory_len: int = 0) -> Dict[str, Any]:
        return self.model.init_cache(batch, max_len, cache_len=cache_len,
                                     memory_len=memory_len)

    def prefill(self, batch: Dict[str, Any], cache: Dict[str, Any]):
        return self._prefill(self.params, batch, cache)

    def decode(self, cache: Dict[str, Any], tokens: jax.Array,
               pos: jax.Array):
        return self._decode(self.params, cache, tokens, pos)
