"""Device-execution layer of the serving stack (``ModelRunner``).

Bottom of the three-layer split (runner / core / async — see
``docs/serving.md`` "Layered architecture"): a :class:`ModelRunner`
owns everything that touches the device for the paged continuous
engine — the per-layer paged KV cache, the compiled prefill / decode
functions and their donation contracts, block-table upload, and the
batched copy-on-write row copier — and knows **nothing** about
scheduling, sequences, arrival times or sampling policy.  Its whole
API is "run this chunk / this decode batch against the cache": the
:class:`~repro.serving.core.EngineCore` turns `Schedule` decisions
into these calls, and anything driving the core (the synchronous
``generate`` driver, the async stepper thread, a test) gets the same
compiled artifacts.

Compilation contracts (moved verbatim from the pre-split engine, so
compile counts and donation behaviour are unchanged):

* ``decode`` compiles **once** per runner: (B, 1) tokens + (B,)
  positions + block tables are all data, so batch membership changes
  never re-specialise XLA;
* ``prefill`` compiles once per (padded chunk bucket, context-page
  bucket) pair — chunk buckets are next-power-of-two lengths with the
  real length a traced scalar;
* the cache argument is **donated** on both, and the paged pool is a
  list of per-layer buffers outside any scan carry (the scan-escape
  layout), so every step is an in-place row scatter costing O(touched
  bytes), not O(pool bytes);
* the CoW copier is one donated gather+scatter over the per-layer
  buffer list, with row plans padded to buckets by the caller.

:class:`BucketRunner` is the same seam for the length-bucket baseline
(``serving.engine.ServingEngine``): per-(batch, prompt-len) prefill +
per-batch decode jits over the ring cache, so both engines sit on one
runner/sampling boundary.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.transformer import Model


def _pad_bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class ModelRunner:
    """Pure ``(device state, chunk/batch) -> logits`` execution over a
    paged KV cache.  No scheduling knowledge; see module docstring.

    ``mesh=`` switches on the **tensor-parallel mode** (the paper's §3
    partition run over a JAX device mesh, shard ≅ NUMA node): every
    per-layer page-pool buffer is laid out head-sharded over the
    ``model`` axis (``NamedSharding`` on the Hkv dim — each shard holds
    its head slice of *every* page), block tables upload replicated,
    and the compiled decode / prefill / CoW-copy functions run the
    forward inside ``shard_map``: a per-shard **local model** (head
    counts divided by the shard count) attends only over its local
    slice of the pool, and one zero-padded psum per layer
    (``launch.shardings.make_paged_head_merge``) restores the full head
    set before the replicated ``w_o`` — bit-identical maths to the
    single-shard engine, one all-reduce per layer, zero cross-shard
    KV-page traffic.  Donation still aliases each shard's pool buffers
    in place.  ``policy`` (``launch.shardings.Policy``) is validated:
    the TP mode implements the head-sharded cache layout
    (``shard_cache_head_dim``) and requires head counts divisible by
    the mesh's ``model`` axis (§3.2 "partitioned by attention heads").
    """

    def __init__(self, model: Model, params: Any, *, max_running: int,
                 max_len: int, page_size: int, n_pages: int,
                 window_override: Optional[int] = None,
                 mesh: Optional[Any] = None,
                 policy: Optional[Any] = None,
                 quant: Optional[Any] = None,
                 registry: Optional[Any] = None,
                 clock: Optional[Any] = None) -> None:
        from ..quant.policy import (QuantPolicy, make_qmm,
                                    quantize_serving_params)
        self.model = model
        self.params = params
        #: serving quantization policy (``repro.quant.QuantPolicy``):
        #: Q4_0 weights are rewritten ONCE here at load — packed codes
        #: and scales are what jit closes over and (in TP mode) what is
        #: device_put per shard — and the model reads them through the
        #: ``qmm`` hook; int8 KV pages are allocated by ``init_cache``
        #: below and quantize/dequantize inside the compiled step.
        self.quant = quant if quant is not None else QuantPolicy()
        if self.quant.weights == "q4":
            self.params = quantize_serving_params(
                params, min_size=self.quant.min_size)
            model.qmm = make_qmm(self.quant.impl)
        self.max_running = max_running
        self.max_len = max_len
        self.page_size = page_size
        self.n_pages = n_pages
        self.max_pages = -(-max_len // page_size)
        self.window_override = window_override
        self.mesh = mesh
        self.tp_axis = "model"
        self.tp_shards = (int(mesh.shape.get(self.tp_axis, 1))
                          if mesh is not None else 1)
        # observability: per-call dispatch time (enqueue-to-return of
        # the compiled call — device completion is owned by whoever
        # blocks; under TP one shard_map dispatch drives all S shards,
        # so series are labelled by shard count).  Instruments resolve
        # once; time comes from the engine's injected clock so tests
        # under a VirtualClock record zeros deterministically.
        self._now = clock.now if clock is not None else time.perf_counter
        self._h_decode = self._h_prefill = self._h_verify = None
        self._c_q4_decode = self._c_q4_prefill = self._c_q4_verify = None
        if registry is not None:
            shards = str(self.tp_shards)
            self._h_decode = registry.histogram(
                "runner.decode.dispatch_ms",
                "batched decode dispatch wall per call").labels(
                    shards=shards)
            self._h_prefill = registry.histogram(
                "runner.prefill.dispatch_ms",
                "prefill-chunk dispatch wall per call").labels(
                    shards=shards)
            self._h_verify = registry.histogram(
                "runner.verify.dispatch_ms",
                "speculative verify dispatch wall per call").labels(
                    shards=shards)
            if self.quant.weights == "q4":
                # dequant dispatch counters: each compiled forward under
                # Q4_0 weights routes every projection through the
                # dequantizing matmul, so count dispatches per phase
                c = registry.counter(
                    "runner.quant.q4_dispatch",
                    "compiled forward dispatches whose projections ran "
                    "through Q4_0 dequantizing matmuls")
                self._c_q4_decode = c.labels(phase="decode")
                self._c_q4_prefill = c.labels(phase="prefill")
                self._c_q4_verify = c.labels(phase="verify")
        self.cache = model.init_cache(max_running, max_len,
                                      page_size=page_size, n_pages=n_pages,
                                      kv_dtype=self.quant.kv_dtype)
        #: (padded chunk len, ctx page bucket) -> compiled prefill;
        #: ctx bucket 0 is the one-shot fresh-sequence path
        self._prefill_jits: Dict[Tuple[int, int], Any] = {}
        #: feed width S -> compiled speculative verify (one per draft
        #: lookahead the engine runs with — in practice a single entry)
        self._verify_jits: Dict[int, Any] = {}
        if mesh is not None:
            self._init_tp(policy)
            return
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(
                p, c, t, pos, page_size=page_size,
                window_override=window_override),
            donate_argnums=1)
        # batched CoW page copier over the per-layer buffer list: one
        # donated gather+scatter moves every queued page in-place on
        # every layer (un-jitted .at[].set would copy each buffer once
        # per page); row counts bucket so compiles stay few
        self._copy_rows = jax.jit(
            lambda layers, src, dst: jax.tree.map(
                lambda a: a.at[dst].set(a[src]), layers),
            donate_argnums=0)

    # ------------------------------------------------------------------
    # tensor-parallel mode
    # ------------------------------------------------------------------
    def _init_tp(self, policy: Optional[Any]) -> None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import NamedSharding, PartitionSpec as P

        from ..launch.shardings import (make_paged_head_merge,
                                        paged_cache_specs,
                                        serving_tp_param_specs)

        cfg = self.model.cfg
        mesh, axis, S = self.mesh, self.tp_axis, self.tp_shards
        if policy is not None and not policy.shard_cache_head_dim:
            raise ValueError(
                "TP serving implements the head-sharded KV layout; "
                "Policy(shard_cache_head_dim=False) has no paged variant")
        if cfg.n_heads % S or cfg.n_kv_heads % S:
            raise ValueError(
                f"arch {cfg.name!r}: {cfg.n_heads} query / "
                f"{cfg.n_kv_heads} kv heads do not shard over the "
                f"{S}-way {axis!r} mesh axis (§3.2 partitions by "
                "attention heads)")
        # per-shard local model: head counts divided, head_dim pinned
        # (resolved_head_dim would otherwise re-derive from d_model)
        local_cfg = dataclasses.replace(
            cfg, n_heads=cfg.n_heads // S, n_kv_heads=cfg.n_kv_heads // S,
            head_dim=cfg.resolved_head_dim)
        self.local_model = Model(local_cfg)
        self.local_model.paged_head_merge = make_paged_head_merge(
            cfg.n_heads, S, axis=axis)
        if self.quant.weights == "q4":
            # the per-shard forward reads the same packed/scales leaves,
            # sliced along their column (head) dim by the param specs —
            # Q4_0 quantizes along K, so a column shard of the quantized
            # pair is byte-identical to quantizing the sharded weight
            from ..quant.policy import make_qmm
            self.local_model.qmm = make_qmm(self.quant.impl)

        self._pspecs = serving_tp_param_specs(self.params, axis=axis)
        self._cspecs = paged_cache_specs(self.cache, axis=axis)
        self._repl = NamedSharding(mesh, P())
        # bind params and pool buffers to their shard-local carve-outs
        self.params = jax.device_put(
            self.params, jax.tree.map(
                lambda s: NamedSharding(mesh, s), self._pspecs))
        self.cache = jax.device_put(
            self.cache, jax.tree.map(
                lambda s: NamedSharding(mesh, s), self._cspecs))

        ps, wo = self.page_size, self.window_override
        #: un-jitted shard_map decode — probe with
        #: ``core.tp.collective_ops_in`` (one psum per layer, no
        #: gather/scatter of KV pages)
        self.tp_raw_decode = shard_map(
            lambda p, c, t, pos: self.local_model.decode_step(
                p, c, t, pos, page_size=ps, window_override=wo),
            mesh=mesh, in_specs=(self._pspecs, self._cspecs, P(), P()),
            out_specs=(P(), self._cspecs), check_rep=False)
        self._decode = jax.jit(self.tp_raw_decode, donate_argnums=1)
        self._copy_rows = jax.jit(
            shard_map(
                lambda layers, src, dst: jax.tree.map(
                    lambda a: a.at[dst].set(a[src]), layers),
                mesh=mesh,
                in_specs=(self._cspecs["layers"], P(), P()),
                out_specs=self._cspecs["layers"], check_rep=False),
            donate_argnums=0)

    # ------------------------------------------------------------------
    def _prefill_fn(self, padded_len: int, ctx_pages: int):
        key = (padded_len, ctx_pages)
        if key in self._prefill_jits:
            return self._prefill_jits[key]
        if self.mesh is None:
            if ctx_pages:
                self._prefill_jits[key] = jax.jit(
                    lambda p, b, c, slot, plen, start:
                    self.model.prefill_paged(
                        p, b, c, slot, plen, start=start,
                        ctx_pages=ctx_pages, page_size=self.page_size,
                        window_override=self.window_override),
                    donate_argnums=2)
            else:
                self._prefill_jits[key] = jax.jit(
                    lambda p, b, c, slot, plen: self.model.prefill_paged(
                        p, b, c, slot, plen, page_size=self.page_size,
                        window_override=self.window_override),
                    donate_argnums=2)
            return self._prefill_jits[key]
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        ps, wo, local = self.page_size, self.window_override, \
            self.local_model
        if ctx_pages:
            body = (lambda p, b, c, slot, plen, start:
                    local.prefill_paged(
                        p, b, c, slot, plen, start=start,
                        ctx_pages=ctx_pages, page_size=ps,
                        window_override=wo))
            in_specs = (self._pspecs, {"tokens": P()}, self._cspecs,
                        P(), P(), P())
        else:
            body = (lambda p, b, c, slot, plen: local.prefill_paged(
                p, b, c, slot, plen, page_size=ps, window_override=wo))
            in_specs = (self._pspecs, {"tokens": P()}, self._cspecs,
                        P(), P())
        self._prefill_jits[key] = jax.jit(
            shard_map(body, mesh=self.mesh, in_specs=in_specs,
                      out_specs=(P(), self._cspecs), check_rep=False),
            donate_argnums=2)
        return self._prefill_jits[key]

    def _verify_fn(self, S: int):
        """Compiled speculative verify for feed width ``S`` (1 + max
        draft tokens).  Same donation contract as decode — the cache
        argument aliases in place — and in TP mode the same shard_map
        wrapping: tokens / positions / feed counts are replicated data,
        the pool stays head-sharded, one psum per layer."""
        fn = self._verify_jits.get(S)
        if fn is not None:
            return fn
        if self.mesh is None:
            fn = jax.jit(
                lambda p, c, t, pos, nf: self.model.verify_step(
                    p, c, t, pos, nf, page_size=self.page_size,
                    window_override=self.window_override),
                donate_argnums=1)
        else:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            ps, wo, local = self.page_size, self.window_override, \
                self.local_model
            fn = jax.jit(
                shard_map(
                    lambda p, c, t, pos, nf: local.verify_step(
                        p, c, t, pos, nf, page_size=ps,
                        window_override=wo),
                    mesh=self.mesh,
                    in_specs=(self._pspecs, self._cspecs, P(), P(), P()),
                    out_specs=(P(), self._cspecs), check_rep=False),
                donate_argnums=1)
        self._verify_jits[S] = fn
        return fn

    def verify(self, fed: np.ndarray, pos: np.ndarray,
               n_fed: np.ndarray) -> jax.Array:
        """One batched speculative verify step: ``fed`` (max_running, S)
        = last sampled token + up to S - 1 draft tokens per lane,
        ``pos`` (max_running,) absolute position of column 0 (-1 = idle
        slot), ``n_fed`` (max_running,) real leading columns per lane.
        Returns (max_running, S, V) — column j's argmax is what plain
        decode would emit after j accepted drafts (see
        ``Model.verify_step``)."""
        t0 = self._now() if self._h_verify is not None else 0.0
        logits, self.cache = self._verify_fn(fed.shape[1])(
            self.params, self.cache, jnp.asarray(fed), jnp.asarray(pos),
            jnp.asarray(n_fed))
        if self._h_verify is not None:
            self._h_verify.observe((self._now() - t0) * 1e3)
        if self._c_q4_verify is not None:
            self._c_q4_verify.inc()
        return logits

    def set_block_tables(self, tables: np.ndarray) -> None:
        """Upload the host (max_running, max_pages) block-table array
        (replicated across every shard in TP mode — tables are the
        host-side page map, never sharded)."""
        bt = jnp.asarray(tables)
        if self.mesh is not None:
            bt = jax.device_put(bt, self._repl)
        self.cache["block_tables"] = bt

    def apply_copy_rows(self, src: np.ndarray, dst: np.ndarray) -> None:
        """Apply a ``KVCachePool.copy_row_plan`` to every per-layer
        buffer: whole-page K/V row copies, one compiled dispatch."""
        self.cache = dict(self.cache)
        self.cache["layers"] = self._copy_rows(
            self.cache["layers"], jnp.asarray(src), jnp.asarray(dst))

    def prefill_chunk(self, tokens: Sequence[int], *, slot: int,
                      start: int, fresh: bool) -> jax.Array:
        """Run one prefill chunk (``tokens`` at absolute positions
        ``[start, start + len)``) into batch slot ``slot``; returns the
        chunk's last-token logits.  ``fresh`` selects the cheaper
        one-shot path (nothing resident to attend over)."""
        n = len(tokens)
        padded = _pad_bucket(n)
        toks = np.zeros((1, padded), np.int32)
        toks[0, :n] = tokens
        batch = {"tokens": jnp.asarray(toks)}
        t0 = self._now() if self._h_prefill is not None else 0.0
        if fresh:
            logits, self.cache = self._prefill_fn(padded, 0)(
                self.params, batch, self.cache,
                jnp.asarray(slot, jnp.int32), jnp.asarray(n, jnp.int32))
        else:
            ctx_pages = min(
                _pad_bucket(-(-(start + n) // self.page_size), lo=1),
                self.max_pages)
            logits, self.cache = self._prefill_fn(padded, ctx_pages)(
                self.params, batch, self.cache,
                jnp.asarray(slot, jnp.int32), jnp.asarray(n, jnp.int32),
                jnp.asarray(start, jnp.int32))
        if self._h_prefill is not None:
            self._h_prefill.observe((self._now() - t0) * 1e3)
        if self._c_q4_prefill is not None:
            self._c_q4_prefill.inc()
        return logits

    def decode(self, fed: np.ndarray, pos: np.ndarray) -> jax.Array:
        """One batched decode step: ``fed`` (max_running, 1) tokens,
        ``pos`` (max_running,) absolute fed-token positions (-1 = idle
        slot, masked + scratch-paged).  Returns (max_running, 1, V)."""
        t0 = self._now() if self._h_decode is not None else 0.0
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(fed), jnp.asarray(pos))
        if self._h_decode is not None:
            self._h_decode.observe((self._now() - t0) * 1e3)
        if self._c_q4_decode is not None:
            self._c_q4_decode.inc()
        return logits


class BucketRunner:
    """Device seam for the length-bucket baseline: ring-cache prefill +
    lockstep decode jits, one compile per (batch, prompt-len) /
    batch-size respectively."""

    def __init__(self, model: Model, params: Any, *,
                 window_override: Optional[int] = None) -> None:
        self.model = model
        self.params = params
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(
                p, b, c, window_override=window_override))
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(
                p, c, t, pos, window_override=window_override))

    def init_cache(self, batch: int, max_len: int, *,
                   cache_len: Optional[int] = None,
                   memory_len: int = 0) -> Dict[str, Any]:
        return self.model.init_cache(batch, max_len, cache_len=cache_len,
                                     memory_len=memory_len)

    def prefill(self, batch: Dict[str, Any], cache: Dict[str, Any]):
        return self._prefill(self.params, batch, cache)

    def decode(self, cache: Dict[str, Any], tokens: jax.Array,
               pos: jax.Array):
        return self._decode(self.params, cache, tokens, pos)
