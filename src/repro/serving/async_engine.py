"""Async serving front-end (``AsyncEngine``): live submit/stream/poll.

Top of the three-layer serving stack (runner / core / async): a
background **stepper thread** loops :meth:`EngineCore.step` against a
lock-guarded inbox, so callers submit, poll, stream and cancel *while
the engine is stepping* — the live-traffic regime the batch-mode
``generate(arrivals=)`` driver can only simulate.  The shape follows
what production engines converge on (vLLM's AsyncLLMEngine over its
EngineCore, arXiv:2309.06180; Orca's iteration-level scheduling,
OSDI '22): all device work stays on one thread, all cross-thread state
is plain host data under one lock.

Request lifecycle (per-handle terminal-state machine)::

    QUEUED ──► PREFILLING ──► DECODING ──► FINISHED
      │             │             │
      │ preempted ◄─┴─────────────┤ (back to QUEUED; recompute restart)
      │             │             │
      └──────┬──────┴─────────────┘
             ▼
      CANCELLED / FAILED                 (terminal)

``cancel`` frees the slot and every KV page reference immediately,
mid-prefill included.  A per-request error (e.g. an oversized prompt,
validated on the stepper) fails only that handle; an unexpected
exception anywhere in the step loop marks the engine dead, fails every
live handle, and re-raises to the *callers*: the next ``poll`` /
``stream`` / ``submit`` raises :class:`AsyncEngineError` chaining the
stepper's exception — background threads must never swallow errors.

The stepper **parks** (condition-variable wait) whenever the core has
no work and the inbox is empty: an idle engine costs zero CPU, and
``submit`` wakes it.  ``shutdown()`` stops the loop, joins the thread,
and cancels whatever was still in flight.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
import threading
from typing import Any, Callable, Dict, Iterator, List, Optional

from ..models.transformer import Model
from .core import Clock, EngineCore
from .engine import Completion, Request
from .scheduler import Sequence


class RequestState(enum.Enum):
    QUEUED = "queued"            # submitted / waiting for a slot
    PREFILLING = "prefilling"    # prompt KV becoming resident
    DECODING = "decoding"        # generating tokens
    FINISHED = "finished"        # eos / token budget / max_len
    CANCELLED = "cancelled"      # by caller or shutdown
    FAILED = "failed"            # per-request or engine error


TERMINAL_STATES = frozenset(
    {RequestState.FINISHED, RequestState.CANCELLED, RequestState.FAILED})


class AsyncEngineError(RuntimeError):
    """Raised to callers when the stepper thread died; the original
    exception is chained as ``__cause__``."""


class CancelledError(RuntimeError):
    """``result()`` called on a request that was cancelled."""


class DeadlineExceededError(RuntimeError):
    """The request's ``deadline_s`` budget ran out before it finished:
    the scheduler shed it (queued or running, pages drained) and the
    handle FAILED with this as its cause.  Deliberately not retryable —
    the client's budget is spent no matter who retries."""


@dataclasses.dataclass(eq=False)    # identity semantics: one handle is
class RequestHandle:                # one in-flight request, never a value
    """Caller's view of one in-flight request.  All mutable fields are
    written by the stepper under the engine lock; read them through
    ``poll``/``stream``/``result``, not directly, unless the engine is
    shut down."""

    uid: int
    request: Request
    state: RequestState = RequestState.QUEUED
    tokens: List[int] = dataclasses.field(default_factory=list)
    completion: Optional[Completion] = None
    error: Optional[BaseException] = None
    #: per-token push callback (``submit(on_token=)``) — invoked by the
    #: stepper thread OUTSIDE the engine lock, once per sampled token
    on_token: Optional[Callable[[int], None]] = None
    _seq: Optional[Sequence] = None          # set once the stepper admits
    _n_polled: int = 0

    @property
    def done(self) -> bool:
        return self.state in TERMINAL_STATES


@dataclasses.dataclass
class PollResult:
    """One ``poll``'s delta: tokens sampled since the previous poll,
    the current state, and the completion once terminal."""

    state: RequestState
    new_tokens: List[int]
    completion: Optional[Completion] = None

    @property
    def done(self) -> bool:
        return self.state in TERMINAL_STATES


class AsyncEngine:
    """Live submit/stream/poll over a background ``EngineCore`` stepper.

    Constructor keywords mirror ``ContinuousServingEngine`` (they are
    forwarded to :class:`EngineCore`).  Use as a context manager or
    call :meth:`shutdown` explicitly — the stepper is a daemon thread,
    but an orderly join is what tests and servers want.
    """

    def __init__(self, model: Model, params: Any, *,
                 clock: Optional[Clock] = None, **core_kwargs) -> None:
        self.core = EngineCore(model, params, clock=clock, **core_kwargs)
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)    # stepper parks
        self._update = threading.Condition(self._lock)  # pollers park
        self._inbox: List[RequestHandle] = []
        self._cancels: List[RequestHandle] = []
        self._handles: Dict[int, RequestHandle] = {}
        self._uids = itertools.count()
        self._alive = True
        self._error: Optional[BaseException] = None
        self._clock0 = self.core.clock.now()
        # stepper telemetry (core.registry; bound handles — the loop
        # never touches the registry itself)
        reg = self.core.registry
        self._c_submitted = reg.counter(
            "async.submitted", "requests accepted by submit()").labels()
        self._c_cancelled = reg.counter(
            "async.cancelled", "requests torn down by cancel()").labels()
        self._c_failed = reg.counter(
            "async.failed", "handles failed (bad request, callback "
            "error, engine death)").labels()
        self._g_inbox = reg.gauge(
            "async.inbox_depth",
            "submitted-but-not-yet-scheduled requests at the last "
            "stepper drain").labels()
        self._thread = threading.Thread(
            target=self._step_loop, name="engine-stepper", daemon=True)
        self._thread.start()

    # observability surfaces (owned by the core)
    registry = property(lambda self: self.core.registry)
    tracer = property(lambda self: self.core.tracer)

    # ------------------------------------------------------------------
    # caller API
    # ------------------------------------------------------------------
    def submit(self, request: Request, *,
               on_token: Optional[Callable[[int], None]] = None,
               ) -> RequestHandle:
        """Queue a request for admission; returns immediately.  The
        engine assigns its own uid (``handle.uid``) so concurrent
        clients can never collide.

        ``on_token`` is a push-style streaming hook for transports that
        cannot poll (SSE writers, websockets, queues): the stepper
        thread calls it once per sampled token, in order, **outside**
        the engine lock (so it may safely call back into the engine).
        Keep it fast — it runs on the stepper, so a slow callback slows
        every request.  A raising callback fails *this* handle (its
        sequence is torn down, pages freed), never the engine.
        """
        with self._wake:
            self._check_alive()
            uid = next(self._uids)
            handle = RequestHandle(
                uid=uid, request=dataclasses.replace(request, uid=uid),
                on_token=on_token)
            self._handles[uid] = handle
            self._inbox.append(handle)
            self._c_submitted.inc()
            self._wake.notify_all()
        return handle

    def poll(self, handle: RequestHandle) -> PollResult:
        """Non-blocking progress check: tokens sampled since the last
        ``poll`` of this handle, current state, completion when done.
        Raises :class:`AsyncEngineError` if the stepper died, or the
        per-request error if this handle FAILED."""
        with self._update:
            self._raise_if_failed(handle)
            new = handle.tokens[handle._n_polled:]
            handle._n_polled = len(handle.tokens)
            return PollResult(state=handle.state, new_tokens=list(new),
                              completion=handle.completion)

    def stream(self, handle: RequestHandle, *,
               timeout: Optional[float] = None) -> Iterator[int]:
        """Yield ``handle``'s tokens as the stepper samples them;
        returns at a terminal state (raises on FAILED).  ``timeout``
        bounds each wait for the *next* token, not the whole stream."""
        cursor = 0
        while True:
            with self._update:
                # deadline per *token*, not per notification: other
                # requests' steps also notify, and must not reset it
                if not self._update.wait_for(
                        lambda: len(handle.tokens) > cursor or handle.done,
                        timeout=timeout):
                    raise TimeoutError(
                        f"request {handle.uid}: no token within "
                        f"{timeout} s")
                self._raise_if_failed(handle)
                new = handle.tokens[cursor:]
                cursor += len(new)
                done = handle.done
            yield from new
            if done:
                return

    def result(self, handle: RequestHandle, *,
               timeout: Optional[float] = None) -> Completion:
        """Block until ``handle`` is terminal; return its completion
        (raises on FAILED, and on CANCELLED there is no completion —
        a ``CancelledError`` is raised instead)."""
        with self._update:
            if not self._update.wait_for(lambda: handle.done,
                                         timeout=timeout):
                raise TimeoutError(
                    f"request {handle.uid} not done within {timeout} s")
            self._raise_if_failed(handle)
            if handle.state is RequestState.CANCELLED:
                raise CancelledError(f"request {handle.uid} was cancelled")
            return handle.completion

    def cancel(self, handle: RequestHandle) -> bool:
        """Request cancellation; the stepper tears the sequence down
        (slot + all KV pages) before its next step.  Returns False when
        the handle is already terminal."""
        with self._wake:
            if handle.done or handle in self._cancels:
                return False
            self._cancels.append(handle)
            self._wake.notify_all()
        return True

    def shutdown(self, *, timeout: Optional[float] = 30.0) -> None:
        """Stop the stepper, join its thread, and cancel every request
        still in flight.  Idempotent."""
        with self._wake:
            self._alive = False
            self._wake.notify_all()
        self._thread.join(timeout=timeout)
        if self._thread.is_alive():
            raise RuntimeError("stepper thread did not stop")
        # thread is dead: tear down the leftovers single-threaded
        with self._update:
            for h in self._handles.values():
                if not h.done:
                    if h._seq is not None:
                        self.core.cancel(h._seq)
                    h.state = RequestState.CANCELLED
            self._handles.clear()
            self._inbox.clear()
            self._cancels.clear()
            self._update.notify_all()

    def __enter__(self) -> "AsyncEngine":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.shutdown()

    # ------------------------------------------------------------------
    # stepper thread
    # ------------------------------------------------------------------
    def _step_loop(self) -> None:
        core = self.core
        try:
            while True:
                with self._wake:
                    while (self._alive and not self._inbox
                           and not self._cancels and not core.has_work()):
                        self._wake.wait()       # park: idle engine = 0 CPU
                    if not self._alive:
                        return
                    inbox, self._inbox = self._inbox, []
                    cancels, self._cancels = self._cancels, []
                    self._g_inbox.set(len(inbox))
                for handle in cancels:
                    if handle.done:     # finished/failed while queued
                        continue        # for cancel: keep that state
                    if handle._seq is not None:
                        core.cancel(handle._seq)
                    self._c_cancelled.inc()
                    with self._update:
                        handle.state = RequestState.CANCELLED
                        self._handles.pop(handle.uid, None)
                        self._update.notify_all()
                now = core.clock.now() - self._clock0
                for handle in inbox:
                    if handle.done:             # cancelled while queued
                        continue
                    try:
                        handle._seq = core.submit(handle.request,
                                                  arrival=now)
                    except ValueError as e:     # bad request, engine fine
                        # never reached core.submit's QUEUED stamp: give
                        # the trace a complete (if instant) lifecycle
                        t = core.clock.now()
                        core.tracer.event(handle.uid, "QUEUED", t)
                        core.tracer.event(handle.uid, "FAILED", t,
                                          error=str(e))
                        self._c_failed.inc()
                        with self._update:
                            handle.state = RequestState.FAILED
                            handle.error = e
                            self._handles.pop(handle.uid, None)
                            self._update.notify_all()
                res = core.step(now=core.clock.now() - self._clock0)
                self._publish(res)
        except BaseException as e:              # noqa: BLE001 — must
            self._die(e)                        # reach the callers

    def _publish(self, res) -> None:
        callbacks: List[tuple] = []
        with self._update:
            for uid, tok in res.emitted:
                handle = self._handles.get(uid)
                if handle is not None:
                    handle.tokens.append(tok)
                    if handle.on_token is not None:
                        callbacks.append((handle, tok))
            self._update.notify_all()       # pollers see the new tokens
        # push-stream outside the lock: a callback may poll/cancel/submit
        # without deadlocking, and a slow one never blocks pollers.  This
        # runs BEFORE completions publish, so (a) by the time result()
        # returns, every on_token fired — a transport can close its
        # stream on result() without losing the tail — and (b) a
        # raising final-token callback still fails its handle (the
        # handle is not FINISHED yet)
        for handle, tok in callbacks:
            try:
                handle.on_token(tok)
            except BaseException as e:      # noqa: BLE001 — a client
                self._fail_handle(handle, e)   # bug fails ITS handle only
        with self._update:
            for uid in res.expired:
                # the scheduler already drained slot + pages and the
                # core already traced FAILED — only the handle is left
                handle = self._handles.pop(uid, None)
                if handle is not None and not handle.done:
                    handle.error = DeadlineExceededError(
                        f"request {uid} missed its deadline "
                        f"({handle.request.deadline_s} s budget)")
                    handle.state = RequestState.FAILED
                    self._c_failed.inc()
            for comp in res.finished:
                # terminal handles leave the registry (the caller keeps
                # its own reference) so a long-lived engine's per-step
                # state walk and memory track LIVE requests, not every
                # request ever served
                handle = self._handles.pop(comp.uid, None)
                if handle is not None and not handle.done:
                    handle.completion = comp
                    handle.state = RequestState.FINISHED
            for handle in self._handles.values():
                if handle.done or handle._seq is None:
                    continue
                seq = handle._seq
                if seq.slot < 0:
                    handle.state = RequestState.QUEUED
                elif seq.is_prefilling:
                    handle.state = RequestState.PREFILLING
                else:
                    handle.state = RequestState.DECODING
            self._update.notify_all()

    def _fail_handle(self, handle: RequestHandle,
                     exc: BaseException) -> None:
        """Fail one handle from the stepper thread (bad ``on_token``):
        tear its sequence down, free its pages, leave the engine up."""
        with self._update:
            if handle.done:     # cancelled/failed concurrently
                return
            if handle._seq is not None:
                self.core.cancel(handle._seq, trace_event=None)
            self.core.tracer.event(handle.uid, "FAILED",
                                   self.core.clock.now(), error=str(exc))
            self._c_failed.inc()
            handle.state = RequestState.FAILED
            handle.error = exc
            self._handles.pop(handle.uid, None)
            self._update.notify_all()

    def _die(self, exc: BaseException) -> None:
        with self._update:
            self._error = exc
            self._alive = False
            t = self.core.clock.now()
            for h in self._handles.values():
                if not h.done:
                    if h._seq is not None:  # queued-in-core: close trace
                        self.core.tracer.event(h.uid, "FAILED", t,
                                               error="engine died")
                    self._c_failed.inc()
                    h.state = RequestState.FAILED
                    h.error = exc
            self._handles.clear()
            self._update.notify_all()

    # ------------------------------------------------------------------
    def _check_alive(self) -> None:
        if self._error is not None:
            raise AsyncEngineError(
                "engine stepper died") from self._error
        if not self._alive:
            raise RuntimeError("engine is shut down")

    def _raise_if_failed(self, handle: RequestHandle) -> None:
        if handle.state is RequestState.FAILED:
            if handle.error is self._error and self._error is not None:
                raise AsyncEngineError(
                    "engine stepper died") from self._error
            raise AsyncEngineError(
                f"request {handle.uid} failed") from handle.error
