"""Worker-process supervisor for multi-replica serving.

Spawns N ``repro.serving.worker`` subprocesses (each a private
``AsyncEngine`` + KV page pool behind its own HTTP port), waits for
each one's ``READY port=<N>`` handshake line, and hands back
:class:`~repro.serving.router.HttpWorkerClient` objects keyed by
replica id for the :class:`~repro.serving.router.Router`.

A monitor thread polls the children; a worker that exits while the
supervisor is live (crash, OOM-kill, the fault-injection tests'
SIGKILL) fires ``on_death(rid, returncode)`` exactly once — the
launcher wires that straight to ``Router.mark_dead`` so the dead
replica drains from the affinity ring while its in-flight connections
surface their own errors.  With ``max_respawns > 0`` the monitor then
**heals the fleet**: it respawns the dead replica (bounded attempts,
linear backoff), waits out the fresh READY handshake, and fires
``on_respawn(rid, client)`` — wired to :meth:`~repro.serving.router.
Router.readmit`, which puts the replica back in the affinity ring.  A
replica that keeps dying stays dead once its attempts are spent.
``shutdown()`` is SIGTERM -> bounded wait -> SIGKILL, and the
orphan-free guarantee (every child reaped, including pre-respawn
corpses) is what ``tests/test_router.py`` asserts after the fault
drills.
"""

from __future__ import annotations

import collections
import os
import subprocess
import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from .router import HttpWorkerClient


class WorkerStartupError(RuntimeError):
    """A worker exited or went silent before its READY handshake."""


def _worker_env() -> Dict[str, str]:
    """Child env whose ``PYTHONPATH`` can resolve ``repro`` exactly as
    this process does (repo src layout or installed — either way the
    package's parent directory is on the path)."""
    env = dict(os.environ)
    pkg_parent = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    env["PYTHONPATH"] = os.pathsep.join(
        [pkg_parent, env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    return env


class Supervisor:
    """Owns N engine-worker subprocesses for one serving deployment.

    ``worker_args`` is the CLI tail forwarded to every worker (arch and
    engine knobs, e.g. ``["--arch", "tiny", "--max-running", "4"]``);
    each worker additionally gets ``--host``/``--port 0`` and its own
    ephemeral port is read back from the handshake.
    """

    def __init__(self, n_replicas: int,
                 worker_args: Optional[List[str]] = None, *,
                 host: str = "127.0.0.1", ready_timeout: float = 180.0,
                 on_death: Optional[Callable[[int, int], None]] = None,
                 max_respawns: int = 0, respawn_backoff: float = 0.5,
                 on_respawn: Optional[
                     Callable[[int, HttpWorkerClient], None]] = None,
                 ) -> None:
        if n_replicas < 1:
            raise ValueError("need at least one replica")
        if max_respawns < 0:
            raise ValueError("max_respawns must be >= 0")
        self.n_replicas = n_replicas
        self.worker_args = list(worker_args or [])
        self.host = host
        self.ready_timeout = ready_timeout
        self.on_death = on_death
        #: restart budget *per replica*; 0 keeps the legacy
        #: notify-only behaviour (dead replicas stay dead)
        self.max_respawns = max_respawns
        self.respawn_backoff = respawn_backoff
        self.on_respawn = on_respawn
        self.procs: Dict[int, subprocess.Popen] = {}
        self.clients: Dict[int, HttpWorkerClient] = {}
        #: trailing stdout lines per worker, for death diagnostics
        self._tails: Dict[int, collections.deque] = {}
        self._lock = threading.Lock()
        self._notified: set = set()
        self._respawns: Dict[int, int] = {}     # attempts burned per rid
        self._retired: List[subprocess.Popen] = []  # pre-respawn corpses
        self._closing = False
        self._monitor: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def start(self) -> Dict[int, HttpWorkerClient]:
        """Spawn all replicas, block until every handshake lands (or
        raise, reaping whatever started)."""
        try:
            for rid in range(self.n_replicas):
                self._spawn(rid)
            for rid in range(self.n_replicas):
                port = self._await_ready(rid)
                self.clients[rid] = HttpWorkerClient(
                    self.host, port, proc=self.procs[rid])
        except BaseException:
            self.shutdown()
            raise
        self._monitor = threading.Thread(target=self._watch,
                                         name="worker-monitor",
                                         daemon=True)
        self._monitor.start()
        return dict(self.clients)

    def _spawn(self, rid: int) -> None:
        cmd = [sys.executable, "-m", "repro.serving.worker",
               "--host", self.host, "--port", "0", *self.worker_args]
        self.procs[rid] = subprocess.Popen(
            cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            env=_worker_env(), text=True)
        self._tails[rid] = collections.deque(maxlen=20)

    def _await_ready(self, rid: int) -> int:
        """Read the worker's stdout until ``READY port=N`` (the model
        build + first bind happen here), then hand the pipe to a drain
        thread so the child never blocks on a full pipe buffer."""
        proc = self.procs[rid]
        deadline = time.monotonic() + self.ready_timeout
        while True:
            if time.monotonic() > deadline:
                raise WorkerStartupError(
                    f"worker {rid} not READY within "
                    f"{self.ready_timeout} s; last output: "
                    f"{list(self._tails[rid])}")
            line = proc.stdout.readline()
            if not line:
                raise WorkerStartupError(
                    f"worker {rid} exited before READY "
                    f"(rc={proc.wait()}); output: "
                    f"{list(self._tails[rid])}")
            line = line.strip()
            self._tails[rid].append(line)
            if line.startswith("READY port="):
                port = int(line.split("=", 1)[1])
                threading.Thread(target=self._drain, args=(rid, proc),
                                 name=f"worker-{rid}-drain",
                                 daemon=True).start()
                return port

    def _drain(self, rid: int, proc: subprocess.Popen) -> None:
        for line in proc.stdout:
            self._tails[rid].append(line.strip())

    # ------------------------------------------------------------------
    def _watch(self) -> None:
        while not self._closing:
            for rid, proc in list(self.procs.items()):
                rc = proc.poll()
                if rc is None:
                    continue
                if self.on_death is None and self.max_respawns <= 0:
                    # no callback attached yet: stay un-notified so a
                    # late-bound callback still hears about this death
                    continue
                with self._lock:
                    if self._closing or rid in self._notified:
                        continue
                    self._notified.add(rid)
                if self.on_death is not None:
                    self.on_death(rid, rc)
                if self.max_respawns > 0:
                    self._respawn_one(rid)
            time.sleep(0.05)

    def _respawn_one(self, rid: int) -> None:
        """Heal one dead replica: bounded attempts with linear backoff,
        each a full spawn + READY handshake.  On success the fresh
        client replaces ``clients[rid]``, ``on_respawn`` re-admits the
        replica upstream, and the rid is un-notified so a *later* death
        fires ``on_death`` again.  Attempts spent -> the replica stays
        dead (rid stays notified, so the monitor stops retrying)."""
        while True:
            with self._lock:
                if self._closing:
                    return
                if self._respawns.get(rid, 0) >= self.max_respawns:
                    return          # budget spent: stays dead
                self._respawns[rid] = self._respawns.get(rid, 0) + 1
                attempt = self._respawns[rid]
            time.sleep(self.respawn_backoff * attempt)
            if self._closing:
                return
            # keep the corpse for shutdown() to close its pipe; it is
            # already reaped (poll() returned), so no zombie risk
            self._retired.append(self.procs[rid])
            try:
                self._spawn(rid)
                port = self._await_ready(rid)
            except WorkerStartupError:
                continue            # attempt burned; back off and retry
            client = HttpWorkerClient(self.host, port,
                                      proc=self.procs[rid])
            with self._lock:
                self.clients[rid] = client
                self._notified.discard(rid)
            if self.on_respawn is not None:
                self.on_respawn(rid, client)
            return

    def respawns(self) -> Dict[int, int]:
        """Respawn attempts burned per replica (diagnostics/tests)."""
        with self._lock:
            return dict(self._respawns)

    def alive(self) -> Dict[int, bool]:
        return {rid: p.poll() is None for rid, p in self.procs.items()}

    def kill(self, rid: int, sig: int = 9) -> None:
        """Hard-kill one replica (fault injection)."""
        self.procs[rid].send_signal(sig)

    def tail(self, rid: int) -> List[str]:
        return list(self._tails.get(rid, ()))

    def shutdown(self, *, timeout: float = 10.0) -> None:
        """SIGTERM every child, bounded wait, SIGKILL stragglers, reap
        everything — no orphans, whatever state the fleet is in."""
        with self._lock:
            self._closing = True
        for proc in self.procs.values():
            if proc.poll() is None:
                proc.terminate()
        deadline = time.monotonic() + timeout
        for proc in self.procs.values():
            try:
                proc.wait(timeout=max(deadline - time.monotonic(), 0.1))
            except subprocess.TimeoutExpired:
                proc.kill()
                proc.wait()
        for proc in (*self.procs.values(), *self._retired):
            if proc.stdout is not None:
                proc.stdout.close()
        if self._monitor is not None:
            self._monitor.join(timeout=2.0)
            self._monitor = None

    def __enter__(self) -> "Supervisor":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.shutdown()
