"""repro.data substrate."""
