"""Byte-level tokenizer (vocab 256 + specials) for the examples/tests."""

from __future__ import annotations

from typing import List, Sequence



PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 259


class ByteTokenizer:
    vocab_size = VOCAB_SIZE
    pad_id, bos_id, eos_id = PAD_ID, BOS_ID, EOS_ID

    def encode(self, text: str, *, bos: bool = True,
               eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        raw = bytes(i for i in ids if i < 256)
        return raw.decode("utf-8", errors="replace")
