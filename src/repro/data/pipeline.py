"""Synthetic LM data pipeline: corpus synthesis, packing, batching.

Deterministic, dependency-free stand-in for a real corpus: sentences
are drawn from a small grammar with a seeded RNG, then byte-tokenized
and *packed* into fixed-length rows (documents separated by EOS, no
padding waste) — the standard LM pretraining layout.  Batches come out
as numpy so the launcher can shard them onto the mesh
(batch axis -> ("pod","data")).
"""

from __future__ import annotations

import itertools
from typing import Dict, Iterator, List, Optional

import numpy as np

from .tokenizer import ByteTokenizer


_SUBJECTS = ["the scheduler", "a numa node", "the tensor", "one thread",
             "the memory pool", "a weight shard", "the kv cache",
             "the gather op", "this barrier", "the decode loop"]
_VERBS = ["binds", "streams", "partitions", "synchronizes", "allocates",
          "scatters", "gathers", "prefetches", "saturates", "overlaps"]
_OBJECTS = ["local memory", "remote pages", "the activation buffer",
            "attention heads", "the expert weights", "both subgraphs",
            "every cacheline", "the ring buffer", "the mlp block",
            "its thread group"]


def synth_corpus(n_docs: int, seed: int = 0) -> List[str]:
    rng = np.random.default_rng(seed)
    docs = []
    for _ in range(n_docs):
        n_sent = int(rng.integers(1, 6))
        sents = []
        for _ in range(n_sent):
            s = (f"{rng.choice(_SUBJECTS)} {rng.choice(_VERBS)} "
                 f"{rng.choice(_OBJECTS)}")
            sents.append(s)
        docs.append(". ".join(sents) + ".")
    return docs


class PackedLMDataset:
    """Packs tokenized documents into (seq_len,) rows, loops forever."""

    def __init__(self, seq_len: int, *, n_docs: int = 2000, seed: int = 0,
                 vocab_size: Optional[int] = None) -> None:
        tok = ByteTokenizer()
        stream: List[int] = []
        for doc in synth_corpus(n_docs, seed):
            stream.extend(tok.encode(doc, bos=True, eos=True))
        self.tokens = np.asarray(stream, np.int32)
        if vocab_size is not None:
            self.tokens = self.tokens % vocab_size
        self.seq_len = seq_len
        self.n_rows = len(self.tokens) // (seq_len + 1)
        if self.n_rows < 1:
            raise ValueError("corpus too small for seq_len")

    def row(self, i: int) -> Dict[str, np.ndarray]:
        i = i % self.n_rows
        s = self.seq_len
        chunk = self.tokens[i * (s + 1):(i + 1) * (s + 1)]
        return {"tokens": chunk[:-1], "labels": chunk[1:]}

    def batches(self, batch_size: int, *, seed: int = 0,
                extra_fn=None) -> Iterator[Dict[str, np.ndarray]]:
        rng = np.random.default_rng(seed)
        for step in itertools.count():
            idx = rng.integers(0, self.n_rows, size=batch_size)
            rows = [self.row(int(i)) for i in idx]
            batch = {k: np.stack([r[k] for r in rows]) for k in rows[0]}
            if extra_fn is not None:
                batch.update(extra_fn(step, batch_size))
            yield batch


def stub_frames(batch_size: int, n_frames: int, d_model: int,
                seed: int = 0) -> np.ndarray:
    """Stub audio frame embeddings (the conv frontend carve-out)."""
    rng = np.random.default_rng(seed)
    return rng.normal(0, 1, (batch_size, n_frames, d_model)).astype(
        np.float32)


def stub_image_embeds(batch_size: int, n_tokens: int, d_model: int,
                      seed: int = 0) -> np.ndarray:
    """Stub vision-encoder patch embeddings (the ViT carve-out)."""
    rng = np.random.default_rng(seed + 1)
    return rng.normal(0, 1, (batch_size, n_tokens, d_model)).astype(
        np.float32)
