"""ArcLight engine + serving benchmarks (end-to-end on CPU).

  engine.*  — the faithful graph-builder engine: TP vs non-TP MLP
              execution, barrier counts, per-node memory split
  serving.* — the decoding frontend on a tiny dense model: decode and
              prefill throughput (paper §4's measurement, laptop scale)
  syncab.*  — collective-op counts of Sync A vs Sync B TP blocks
              (jaxpr-level; the TPU analogue of Fig 9)
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def engine_rows() -> List[Row]:
    from repro.core import (Engine, EngineConfig, build_tp_mlp_graph,
                            split_mlp_weights)
    d, f, t = 256, 1024, 8
    rng = np.random.default_rng(0)
    w = {"w_gate": (rng.normal(size=(f, d)) * 0.05).astype(np.float32),
         "w_up": (rng.normal(size=(f, d)) * 0.05).astype(np.float32),
         "w_down": (rng.normal(size=(d, f)) * 0.05).astype(np.float32)}
    x = rng.normal(size=(d, t)).astype(np.float32)
    rows: List[Row] = []
    for n in (1, 4):
        eng = Engine(EngineConfig(n_nodes=n, n_threads=8))
        _, zout = build_tp_mlp_graph(eng, d, f, t)
        weights = dict(w) if n == 1 else split_mlp_weights(w, n)
        t0 = time.perf_counter()
        rep = eng.execute({"x": x}, weights)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"engine.tp{n}.exec", us,
                     f"nodes={rep.node_count},barriers={rep.barrier_count}"))
        per_node = rep.per_node_bytes
        rows.append((f"engine.tp{n}.mem_nodes", us,
                     f"{len([v for v in per_node.values() if v])}"))
    return rows


def serving_rows() -> List[Row]:
    from repro.models import ModelConfig, build_model
    from repro.serving.engine import Request, ServingEngine, \
        throughput_report
    from repro.serving.sampler import SamplingParams

    cfg = ModelConfig(name="bench-tiny", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_len=128)
    reqs = [Request(uid=i, prompt=list(range(1, 17)),
                    sampling=SamplingParams(max_new_tokens=16))
            for i in range(8)]
    t0 = time.perf_counter()
    comps = eng.generate(reqs, max_batch=8)
    us = (time.perf_counter() - t0) * 1e6
    rep = throughput_report(comps)
    return [
        ("serving.decode_toks_per_s", us, f"{rep['decode_tok_per_s']:.1f}"),
        ("serving.prefill_toks_per_s", us,
         f"{rep['prefill_tok_per_s']:.1f}"),
    ]


def syncab_rows() -> List[Row]:
    """Collective-op counts: Sync A inserts one all-gather per op."""
    from repro.core import tp
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("model",))
    rng = np.random.default_rng(0)
    d, f, t = 32, 64, 4
    params = {k: (rng.normal(size=s) * 0.1).astype(np.float32)
              for k, s in [("w_gate", (d, f)), ("w_up", (d, f)),
                           ("w_down", (f, d))]}
    x = rng.normal(size=(t, d)).astype(np.float32)
    rows: List[Row] = []
    for mode in ("sync_a", "sync_b"):
        t0 = time.perf_counter()
        blk = tp.make_tp_block(mesh, "mlp", sync_mode=mode)
        counts = tp.collective_ops_in(blk, params, x)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"syncab.mlp.{mode}.collectives", us,
                     f"{sum(counts.values())}:{counts}"))
    return rows


def all_rows() -> List[Row]:
    return engine_rows() + serving_rows() + syncab_rows()
