"""Paper-figure reproductions via the calibrated NUMA cost model.

One function per paper table/figure:
  table1   — cross-node bandwidth matrix (Table 1)
  fig10    — single-NUMA-node decode scaling
  fig11    — multi-node decode: llama.cpp-distribute vs ArcLight-TP
  fig9     — Sync A vs Sync B makespans (thread-group schedules)
  fig12_13 — prompt-300 decode + prefill
  headline — the "up to 46%" and "+5 tok/s" claims
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core.numa import (KUNPENG_920_4NODE, async_gain_tokens_per_s,
                             fig10_single_node, fig11_multi_node,
                             fig12_13_long_prompt, headline_gain)
from repro.core.threads import SyncSchedule


Row = Tuple[str, float, str]


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def table1() -> List[Row]:
    m, us = _timed(KUNPENG_920_4NODE.bandwidth_matrix)
    local = float(np.diag(m).mean())
    remote = float(m[~np.eye(4, dtype=bool)].mean())
    return [
        ("table1.local_gbs", us, f"{local:.1f}"),
        ("table1.remote_gbs", us, f"{remote:.1f}"),
        ("table1.local_over_remote", us, f"{local / remote:.2f}"),
    ]


def fig10() -> List[Row]:
    f, us = _timed(fig10_single_node)
    rows: List[Row] = []
    for sys in ("llama.cpp", "arclight"):
        for t, v in zip(f["threads"], f[sys]):
            rows.append((f"fig10.{sys}.t{t}", us, f"{v:.1f}"))
    return rows


def fig11() -> List[Row]:
    f, us = _timed(fig11_multi_node)
    rows: List[Row] = []
    for sys in ("llama.cpp", "arclight_tp", "arclight_tp_sync_a"):
        for n in (2, 4):
            rows.append((f"fig11.{sys}.n{n}.max_toks",
                         us, f"{max(f[sys][n]):.1f}"))
    return rows


def fig9() -> List[Row]:
    # representative skewed per-group op durations (ms)
    rng = np.random.default_rng(0)
    d = np.abs(rng.normal(1.0, 0.3, size=(4, 14)))
    a, us1 = _timed(lambda: SyncSchedule.sync_a(d, barrier_cost=0.01))
    b, us2 = _timed(lambda: SyncSchedule.sync_b(d, barrier_cost=0.01))
    return [
        ("fig9.sync_a.makespan_ms", us1, f"{a.makespan:.3f}"),
        ("fig9.sync_b.makespan_ms", us2, f"{b.makespan:.3f}"),
        ("fig9.async_speedup", us1 + us2, f"{a.makespan / b.makespan:.3f}"),
        ("fig9.sync_a.idle_ms", us1, f"{a.idle_time:.3f}"),
        ("fig9.sync_b.idle_ms", us2, f"{b.idle_time:.3f}"),
    ]


def fig12_13() -> List[Row]:
    f, us = _timed(fig12_13_long_prompt)
    rows: List[Row] = []
    for phase in ("decode", "prefill"):
        for sys in ("llama.cpp", "arclight_tp"):
            for n in (2, 4):
                rows.append((f"fig12_13.{phase}.{sys}.n{n}", us,
                             f"{f[phase][sys][n]:.1f}"))
    return rows


def headline() -> List[Row]:
    g, us1 = _timed(headline_gain)
    a, us2 = _timed(async_gain_tokens_per_s)
    return [
        ("headline.tp_gain_pct (paper: up to 46%)", us1, f"{100 * g:.1f}"),
        ("headline.async_gain_toks (paper: ~5)", us2, f"{a:.1f}"),
    ]


def all_rows() -> List[Row]:
    rows: List[Row] = []
    for fn in (table1, fig10, fig11, fig9, fig12_13, headline):
        rows.extend(fn())
    return rows
