"""Benchmark harness (deliverable d) — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sections:
  numa_sim       Table 1, Figs 10/11/9/12/13, headline claims
  engine_bench   ArcLight engine + serving frontend + Sync A/B
  serving_bench  bucket vs continuous-batching engines, Poisson arrivals
  kernels_bench  Q4_0 GEMM + decode attention kernels
  roofline_bench per-(arch x shape) dominant roofline terms
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (engine_bench, kernels_bench, numa_sim, roofline_bench,
                   serving_bench)
    print("name,us_per_call,derived")
    for mod in (numa_sim, engine_bench, serving_bench, kernels_bench,
                roofline_bench):
        try:
            for name, us, derived in mod.all_rows():
                print(f"{name},{us:.1f},{derived}")
        except Exception:  # noqa: BLE001 — keep other sections alive
            traceback.print_exc()
            print(f"{mod.__name__},0.0,SECTION-FAILED", file=sys.stderr)


if __name__ == "__main__":
    main()
