"""Serving-engine comparison under staggered (Poisson) arrivals.

The experiment behind the continuous-batching subsystem: requests with
mixed prompt lengths arrive as a Poisson process; the length-bucket
baseline can only start once its batch is assembled (and then runs
buckets strictly sequentially), while the continuous engine admits each
request on arrival into the slot-indexed running batch.  Reported rows:

  serving_cb.bucket.*      bucket engine, work starts at the LAST arrival
  serving_cb.continuous.*  paged-KV continuous engine, per-step admission
  serving_cb.speedup       continuous / bucket decode tok/s (>1 = win)

Wall times include the arrival span — that is the point: decode tok/s
here is throughput *as the client sees it*, not device-only.

Two further sections exercise the prefix-caching / chunked-prefill
follow-ons (see ``docs/serving.md``):

  serving_prefix.*   shared-system-prompt Poisson workload, prefix
                     cache off vs on: prefill pages allocated, pages
                     shared, prompt tokens served from cache, and a
                     greedy-token parity check (caching must be
                     invisible in the output)
  serving_chunk.*    long-prompt admission into a busy decode batch,
                     one-shot vs chunked prefill: max wall gap between
                     consecutive decode steps (chunking bounds it)

The async section measures the layered stack's live front-end
(``AsyncEngine``: background stepper thread, lock-guarded inbox)
against the batch-mode driver on the SAME Poisson workload:

  serving_async.ttft_p50_ms / ttft_p99_ms
                     time-to-first-token under open-loop wall-clock
                     submission (client stamps submit, engine stamps
                     the first sampled token)
  serving_async.itl_mean_ms.p50
                     per-request mean inter-token latency, median
                     across requests
  serving_async.batch.ttft_p50_ms / ttft_p99_ms
                     the same arrivals through the synchronous
                     ``generate(arrivals=)`` driver — the async layer
                     must not tax TTFT
  serving_async.greedy_parity
                     async and batch tokens must be identical

The TP section (``serving_tp.*``, see :func:`serving_tp_rows`) runs
the paged engine over ``model``-axis meshes of 1/2/4 shards in a child
process with forced host devices: decode tok/s + TTFT per shard count,
byte-identical greedy parity vs the plain engine, and the collective
budget (one psum per layer, zero KV-page gathers) probed via
``core.tp.collective_ops_in``.

The scan-escape section is the evidence for the per-layer paged-cache
layout (``Model.init_cache`` docstring, docs/serving.md "Cache memory
layout"): per-step cost must be **flat in pool size** at fixed touched
bytes —

  serving_scan_escape.decode_step_ms.pN    compiled decode step, pool
                     swept 64 -> 512 pages (8x), same 4-sequence batch
  serving_scan_escape.prefill_chunk_ms.pN  compiled 16-token resumed
                     prefill chunk over the same sweep
  serving_scan_escape.*_flatness           t(p512) / t(p64), ~1 = flat
  serving_scan_escape.nodonate.*           same decode step WITHOUT
                     buffer donation: XLA must copy every pool buffer
                     per call — the O(pool bytes) behaviour the paged
                     engine escaped (real-model "before" anchor)
  serving_scan_escape.micro.*              XLA microbench of just the
                     cache update: the old stacked-pool-through-
                     lax.scan-carry layout (O(pool bytes) copy floor,
                     scaling ~= pool ratio) vs the per-layer unrolled
                     layout (in-place row scatter, flat)

The quantization section (``serving_quant.*``, see
:func:`serving_quant_rows`) serves the same fixed workload full
precision and under ``--quant q4 --kv-dtype int8``
(``docs/quantization.md``): decode tok/s both ways, the teacher-forced
greedy token-match rate against its documented divergence bound
(``QUANT_MATCH_BOUND``), and the page-capacity rows — bytes per KV
page and whole pages per fixed 16 MiB budget, fp32 vs int8 (the int8
format must fit >= 1.9x the pages).

The HTTP section (``serving_http.*``, see :func:`serving_http_rows`)
drives the full network stack — client HTTP -> ``HttpFrontend`` ->
``Router`` -> engine-worker subprocesses — under a saturating
open-loop Poisson workload of shared-prefix groups, at 1 and 2
replicas: client-side TTFT/ITL percentiles off the socket, aggregate
streamed tok/s, the r2/r1 throughput speedup (2 replicas must win
under saturation given >= 2 cores; on a single-core host the row
measures the oversubscription penalty instead — see
:func:`serving_http_rows`), the prefix-affinity hit rate, and greedy
parity vs an in-process ``AsyncEngine`` on the same prompts (the
wire must be byte-invisible).

The speculative section (``serving_spec.*``, see
:func:`serving_spec_rows`) serves a shared-prefix repetitive-text
Poisson workload with and without ``spec_decode=4`` on a bench-tiny
warm-trained on periodic text: decode tok/s and ITL percentiles both
ways, tokens emitted per lane-step (> 1.0 is the point — every extra
token is a decode forward never run), the draft accept rate, and
greedy byte parity vs k=0 (the acceptance contract).

The SLO section (``serving_slo.*``, see :func:`serving_slo_rows`)
saturates the paged engine with a mixed workload — a deep backlog of
heavy batch requests, latency-sensitive interactive chat, and
"hopeless" heavy requests whose budget can never be met — and serves
it twice: once with every overload-protection knob off (uniform
priority, no deadlines — the pre-SLO engine) and once protected
(interactive priority + deadlines).  Reported per mode: **goodput**
(tokens of completions that met their class's SLO window, per wall
second — tokens served past their deadline are wasted work, not
goodput), interactive TTFT p99, and the protected/unprotected goodput
ratio (the gate metric: protection must not cost goodput at
saturation).  Deadline sheds are counted from ``scheduler.expired``
and greedy byte parity is asserted over requests completed in both
modes (docs/robustness.md).
"""

from __future__ import annotations

import os
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _setup():
    from repro.models import ModelConfig, build_model
    from repro.serving import Request, SamplingParams

    cfg = ModelConfig(name="bench-tiny", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    reqs = [Request(uid=i,
                    prompt=list(rng.integers(1, 258, 4 + 4 * (i % 3))),
                    sampling=SamplingParams(max_new_tokens=16))
            for i in range(8)]
    # Poisson process: exponential inter-arrival gaps, mean 0.25 s
    arrivals = np.cumsum(rng.exponential(0.25, size=len(reqs)))
    return model, params, reqs, arrivals.tolist()


def serving_cb_rows(mean_gap_scale: float = 1.0) -> List[Row]:
    from repro.serving import (ContinuousServingEngine, ServingEngine,
                               throughput_report)

    model, params, reqs, arrivals = _setup()
    arrivals = [a * mean_gap_scale for a in arrivals]
    max_len = max(len(r.prompt) for r in reqs) + 16 + 8

    # --- bucket baseline: batching by length needs the whole workload,
    # so the engine cannot start before the last arrival ---
    beng = ServingEngine(model, params, max_len=max_len)
    beng.generate(reqs[:1], max_batch=8)        # warm compile caches
    t0 = time.perf_counter()
    time.sleep(max(arrivals))                   # waiting for arrivals
    bc = beng.generate(reqs, max_batch=8)
    bwall = time.perf_counter() - t0
    brep = throughput_report(bc, wall_s=bwall,
                             prefill_s=beng.last_phase_s["prefill_s"],
                             decode_s=bwall - beng.last_phase_s["prefill_s"])

    # --- continuous engine: admission interleaves with decode ---
    ceng = ContinuousServingEngine(model, params, max_len=max_len,
                                   max_running=8, page_size=8)
    ceng.generate(reqs[:1])                     # warm compile caches
    ceng2 = ContinuousServingEngine(model, params, max_len=max_len,
                                    max_running=8, page_size=8)
    t0 = time.perf_counter()
    cc = ceng2.generate(reqs, arrivals=arrivals)
    cwall = time.perf_counter() - t0
    crep = throughput_report(cc, wall_s=cwall,
                             prefill_s=ceng2.last_phase_s["prefill_s"],
                             decode_s=cwall - ceng2.last_phase_s["prefill_s"])

    speedup = crep["decode_tok_per_s"] / max(brep["decode_tok_per_s"], 1e-9)
    return [
        ("serving_cb.bucket.decode_toks_per_s", bwall * 1e6,
         f"{brep['decode_tok_per_s']:.1f}"),
        ("serving_cb.continuous.decode_toks_per_s", cwall * 1e6,
         f"{crep['decode_tok_per_s']:.1f}"),
        ("serving_cb.continuous.preemptions", 0.0,
         f"{ceng2.scheduler.n_preemptions}"),
        ("serving_cb.speedup", 0.0, f"{speedup:.2f}x"),
    ]


def serving_prefix_rows() -> List[Row]:
    """Shared-system-prompt workload: N requests = one long system
    prompt + a short unique suffix, Poisson arrivals.  Prefix caching
    should cut the pages *allocated* for prefill (matched pages are
    shared, not allocated) without changing a single greedy token."""
    from repro.models import ModelConfig, build_model
    from repro.serving import (ContinuousServingEngine, Request,
                               SamplingParams)

    cfg = ModelConfig(name="bench-tiny", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(13)
    system = list(rng.integers(1, 258, 64))      # 8 full pages @ ps=8
    reqs = [Request(uid=i, prompt=system + list(rng.integers(1, 258, 8)),
                    sampling=SamplingParams(max_new_tokens=24))
            for i in range(8)]
    arrivals = np.cumsum(rng.exponential(0.08, size=len(reqs))).tolist()
    max_len = len(reqs[0].prompt) + 24 + 8

    results = {}
    for cached in (False, True):
        eng = ContinuousServingEngine(model, params, max_len=max_len,
                                      max_running=8, page_size=8,
                                      prefix_cache=cached)
        eng.generate(reqs[:1])                  # warm compile caches
        for k in eng.pool.stats:
            eng.pool.stats[k] = 0
        comps = eng.generate(reqs, arrivals=arrivals)
        results[cached] = (eng.pool.stats.copy(),
                           [c.tokens for c in comps])
    st_off, toks_off = results[False]
    st_on, toks_on = results[True]
    parity = "OK" if toks_on == toks_off else "MISMATCH"
    saved = st_off["fresh_pages"] - st_on["fresh_pages"]
    return [
        ("serving_prefix.pages_allocated.nocache", 0.0,
         f"{st_off['fresh_pages']}"),
        ("serving_prefix.pages_allocated.cached", 0.0,
         f"{st_on['fresh_pages']}"),
        ("serving_prefix.pages_shared", 0.0, f"{st_on['shared_pages']}"),
        ("serving_prefix.cow_copies", 0.0, f"{st_on['cow_copies']}"),
        ("serving_prefix.prompt_tokens_from_cache", 0.0,
         f"{st_on['cached_tokens']}"),
        ("serving_prefix.pages_saved", 0.0, f"{saved}"),
        ("serving_prefix.greedy_parity", 0.0, parity),
    ]


def serving_chunk_rows() -> List[Row]:
    """Long-prompt admission stall: a 768-token prompt arrives while 4
    requests are mid-decode.  One-shot prefill stalls every decode for
    the whole prompt; chunked prefill (32 tokens/step) interleaves, so
    the max gap between consecutive decode steps stays near one chunk's
    cost.  A wider model than the other sections so prefill *compute*
    (not dispatch overhead) is what stalls the batch."""
    from repro.models import ModelConfig, build_model
    from repro.serving import (ContinuousServingEngine, Request,
                               SamplingParams)

    cfg = ModelConfig(name="bench-wide", arch_type="dense", n_layers=8,
                      d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(29)
    short = [Request(uid=i, prompt=list(rng.integers(1, 258, 8)),
                     sampling=SamplingParams(max_new_tokens=200))
             for i in range(4)]
    long_r = Request(uid=4, prompt=list(rng.integers(1, 258, 768)),
                     sampling=SamplingParams(max_new_tokens=8))
    arrivals = [0.0] * 4 + [0.15]               # long prompt mid-decode
    max_len = 1024
    # pool sized to the workload's true peak (4 shorts + the long
    # prompt).  Since the scan-escape layout, per-step cost is flat in
    # pool size (see serving_scan_escape below), so this is now just a
    # memory choice — kept at the PR 2 value so anchors stay comparable
    n_pages = 208

    gaps = {}
    for chunk in (None, 32):
        eng = ContinuousServingEngine(model, params, max_len=max_len,
                                      max_running=5, page_size=8,
                                      n_pages=n_pages,
                                      prefill_chunk=chunk,
                                      prefix_cache=False)
        eng.generate([long_r], arrivals=[0.0])  # warm prefill compiles
        eng.generate(short[:1])
        eng.generate(short + [long_r], arrivals=arrivals)   # full warm
        eng.generate(short + [long_r], arrivals=arrivals)
        gaps[chunk] = max(eng.decode_gaps_s) if eng.decode_gaps_s else 0.0
    ratio = gaps[None] / max(gaps[32], 1e-9)
    return [
        ("serving_chunk.max_decode_gap_ms.oneshot", gaps[None] * 1e6,
         f"{gaps[None] * 1e3:.1f}"),
        ("serving_chunk.max_decode_gap_ms.chunked32", gaps[32] * 1e6,
         f"{gaps[32] * 1e3:.1f}"),
        ("serving_chunk.stall_reduction", 0.0, f"{ratio:.2f}x"),
    ]


def _pct(sorted_vals: List[float], q: float) -> float:
    i = min(int(q * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[i]


def serving_async_rows() -> List[Row]:
    """Open-loop Poisson submission into the live ``AsyncEngine`` vs
    the same workload through the batch-mode driver.  TTFT is what a
    client sees: submit stamped by the caller, first token stamped by
    the engine core (``Completion.t_first``).  Inter-token latency is
    each request's (t1 - t_first) / (n_tokens - 1)."""
    from repro.models import ModelConfig, build_model
    from repro.serving import (AsyncEngine, ContinuousServingEngine,
                               Request, SamplingParams)

    cfg = ModelConfig(name="bench-tiny", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(23)
    reqs = [Request(uid=i,
                    prompt=list(rng.integers(1, 258, 4 + 4 * (i % 3))),
                    sampling=SamplingParams(max_new_tokens=12))
            for i in range(16)]
    arrivals = np.cumsum(rng.exponential(0.06, size=len(reqs))).tolist()
    max_len = max(len(r.prompt) for r in reqs) + 12 + 8

    # --- batch-mode anchor: the same arrivals, synchronous driver ---
    beng = ContinuousServingEngine(model, params, max_len=max_len,
                                   max_running=8, page_size=8,
                                   prefix_cache=False)
    beng.generate(reqs[:3])                     # warm compile caches
    bcomps = beng.generate(reqs, arrivals=arrivals)
    batch_ttft = sorted(c.t_first - c.t0 for c in bcomps)

    # --- live open-loop submission into the async engine ---
    eng = AsyncEngine(model, params, max_len=max_len, max_running=8,
                      page_size=8, prefix_cache=False)
    warm = [eng.submit(r) for r in reqs[:3]]    # warm the live path
    for h in warm:
        eng.result(h, timeout=300)
    t0 = time.perf_counter()
    handles, t_submit = [], []
    for r, a in zip(reqs, arrivals):
        gap = t0 + a - time.perf_counter()
        if gap > 0:
            time.sleep(gap)
        t_submit.append(time.perf_counter())
        handles.append(eng.submit(r))
    acomps = [eng.result(h, timeout=600) for h in handles]
    eng.shutdown()

    ttft = sorted(c.t_first - ts for c, ts in zip(acomps, t_submit))
    itl = sorted((c.t1 - c.t_first) / max(len(c.tokens) - 1, 1)
                 for c in acomps)
    parity = ("OK" if [c.tokens for c in acomps]
              == [c.tokens for c in bcomps] else "MISMATCH")
    return [
        ("serving_async.ttft_p50_ms", _pct(ttft, 0.5) * 1e6,
         f"{_pct(ttft, 0.5) * 1e3:.1f}"),
        ("serving_async.ttft_p99_ms", _pct(ttft, 0.99) * 1e6,
         f"{_pct(ttft, 0.99) * 1e3:.1f}"),
        ("serving_async.itl_mean_ms.p50", _pct(itl, 0.5) * 1e6,
         f"{_pct(itl, 0.5) * 1e3:.2f}"),
        ("serving_async.batch.ttft_p50_ms", _pct(batch_ttft, 0.5) * 1e6,
         f"{_pct(batch_ttft, 0.5) * 1e3:.1f}"),
        ("serving_async.batch.ttft_p99_ms", _pct(batch_ttft, 0.99) * 1e6,
         f"{_pct(batch_ttft, 0.99) * 1e3:.1f}"),
        ("serving_async.greedy_parity", 0.0, parity),
    ]


def serving_obs_rows() -> List[Row]:
    """Observability overhead gate (``docs/observability.md``): the
    same saturated decode workload served twice — once under
    ``NullRegistry`` + ``NullTracer`` (every instrument call a no-op)
    and once fully instrumented (real registry, real tracer) — must
    agree on decode tok/s within the 3% budget.  The two modes run
    **interleaved** (alternating which goes first each round) and the
    overhead is the minimum of two estimators — the median of
    per-round paired throughput ratios and the best-of-N ceiling
    comparison — because on a shared container either one alone
    false-positives on noise while a real per-token cost registers
    in both (see the comment at the computation).  The throughput
    rows report best-of-round per mode.

      serving_obs.decode_toks_per_s.noop / .instrumented
      serving_obs.overhead_pct     min(paired-median, best-vs-best)
      serving_obs.overhead_budget  OK when overhead_pct <= 3
      serving_obs.trace_events     events the instrumented run recorded
      serving_obs.snapshot_valid   snapshot passes the repro.obs schema
    """
    from repro.obs import (NullRegistry, NullTracer, RequestTracer,
                           validate_events, validate_snapshot)
    from repro.obs.metrics import MetricsRegistry
    from repro.serving import ContinuousServingEngine

    import dataclasses

    model, params, reqs, _arrivals = _setup()
    for r in reqs:                  # saturate: every request at t=0
        r.sampling = dataclasses.replace(r.sampling, max_new_tokens=96)
    max_len = max(len(r.prompt) for r in reqs) + 96 + 8
    REPEATS = 8

    def make(registry, tracer):
        eng = ContinuousServingEngine(
            model, params, max_len=max_len, max_running=8, page_size=8,
            prefix_cache=False, registry=registry, tracer=tracer)
        eng.generate(reqs)          # warm every prefill/decode shape
        return eng

    def timed(eng):
        t0 = time.perf_counter()
        comps = eng.generate(reqs)
        wall = time.perf_counter() - t0
        return sum(len(c.tokens) for c in comps) / wall

    noop_eng = make(NullRegistry(), NullTracer())
    registry, tracer = MetricsRegistry(), RequestTracer()
    eng = make(registry, tracer)
    ratios = []
    noop = instr = 0.0
    for round_ in range(REPEATS):   # alternate modes within each round
        if round_ % 2:              # swap order to cancel position bias
            i = timed(eng)
            n = timed(noop_eng)
        else:
            n = timed(noop_eng)
            i = timed(eng)
        noop, instr = max(noop, n), max(instr, i)
        ratios.append(i / n)        # paired: same round, same drift

    # Two estimators with opposite failure modes, overhead = their
    # minimum.  Median paired ratio: adjacent samples share the same
    # machine state, so their ratio isolates instrumentation cost —
    # but correlated jitter across rounds can still skew the median.
    # Best-vs-best: with contention noise strictly one-sided (the
    # machine only ever slows a sample down), best-of-N per mode
    # converges on each mode's clean ceiling — but a single lucky
    # noop draw can fake an overhead.  A *real* per-token cost (a
    # dict build or lock acquisition inside ``EngineCore.step()``)
    # depresses every instrumented sample and shows up in both.
    ratios.sort()
    mid = len(ratios) // 2
    med = (ratios[mid] if len(ratios) % 2
           else (ratios[mid - 1] + ratios[mid]) / 2.0)
    paired = max((1.0 - med) * 100.0, 0.0)
    ceiling = max((noop - instr) / max(noop, 1e-9) * 100.0, 0.0)
    overhead = min(paired, ceiling)
    snap_ok = not validate_snapshot(registry.snapshot())
    # the warm-up + repeats reuse uids, so lifecycles repeat per uid;
    # validate uid 0's FIRST lifecycle (submit .. FINISHED)
    ev0 = tracer.events(0)
    end = next((i for i, e in enumerate(ev0) if e.name == "FINISHED"),
               None)
    trace_ok = end is not None and not validate_events(ev0[:end + 1])
    return [
        ("serving_obs.decode_toks_per_s.noop", 0.0, f"{noop:.1f}"),
        ("serving_obs.decode_toks_per_s.instrumented", 0.0,
         f"{instr:.1f}"),
        ("serving_obs.overhead_pct", 0.0, f"{overhead:.2f}"),
        ("serving_obs.overhead_budget", 0.0,
         "OK" if overhead <= 3.0 else "OVER"),
        ("serving_obs.trace_events", 0.0, f"{len(tracer.events())}"),
        ("serving_obs.snapshot_valid", 0.0,
         "OK" if snap_ok and trace_ok else "INVALID"),
    ]


def _best_of(fn, *, repeats: int = 3, steps: int = 16) -> float:
    """Best-of-``repeats`` mean seconds per call of ``fn(steps)``."""
    best = float("inf")
    for _ in range(repeats):
        best = min(best, fn(steps) / steps)
    return best


def serving_scan_escape_rows() -> List[Row]:
    """Per-step cost vs pool size at fixed touched bytes.

    Builds the same 4-sequence paged batch (32 resident tokens each)
    over page pools of 64 -> 512 pages and times the compiled decode
    step and a resumed 16-token prefill chunk.  With the per-layer
    scan-escape cache layout both must be flat in pool size; the micro
    pair isolates why — a stacked (L, rows, H, D) pool threaded through
    a ``lax.scan`` carry pays an O(pool bytes) ys copy per call, while
    the unrolled per-layer buffers update in place under donation.
    """
    import functools

    from repro.models import ModelConfig, build_model

    cfg = ModelConfig(name="bench-tiny", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ps, B, ctx, max_len = 8, 4, 32, 64
    pages_per_slot = ctx // ps + 1          # resident ctx + decode page
    pools = (64, 128, 256, 512)

    def make_cache(n_pages: int):
        cache = model.init_cache(B, max_len, page_size=ps,
                                 n_pages=n_pages)
        bt = np.zeros((B, max_len // ps), np.int32)
        for b in range(B):                  # pages 1.. are real; 0 scratch
            bt[b, :pages_per_slot] = (1 + b * pages_per_slot
                                      + np.arange(pages_per_slot))
        cache["block_tables"] = jnp.asarray(bt)
        return cache

    decode = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                               page_size=ps),
        donate_argnums=1)
    toks = jnp.ones((B, 1), jnp.int32)
    pos = jnp.full((B,), ctx, jnp.int32)

    def timed_loop(step_fn, state, steps):
        t0 = time.perf_counter()
        for _ in range(steps):
            state = step_fn(state)
            jax.block_until_ready(state)
        return time.perf_counter() - t0

    dec_t = {}
    for P in pools:
        def run(steps, P=P):
            # fresh pool per repeat: the previous repeat donated it away
            logits, c = decode(params, make_cache(P), toks, pos)
            jax.block_until_ready(logits)
            return timed_loop(
                lambda c: decode(params, c, toks, pos)[1], c, steps)

        dec_t[P] = _best_of(run, steps=50)

    # "before" anchor at the real-model level: the same step without
    # donation forces XLA to copy every pool buffer each call, which is
    # the O(pool bytes) floor the stacked scan-carry layout paid too
    decode_nd = jax.jit(
        lambda p, c, t, pos: model.decode_step(p, c, t, pos,
                                               page_size=ps))
    nd_t = {}
    for P in (pools[0], pools[-1]):
        def run(steps, P=P):
            cache = make_cache(P)
            logits, _ = decode_nd(params, cache, toks, pos)
            jax.block_until_ready(logits)
            return timed_loop(
                lambda c: decode_nd(params, c, toks, pos)[1], cache,
                steps)

        nd_t[P] = _best_of(run, steps=50)

    # resumed prefill chunk: 16 tokens at start=16, ctx bucket 8 pages
    prefill = jax.jit(
        lambda p, b, c, slot, plen, start: model.prefill_paged(
            p, b, c, slot, plen, start=start, ctx_pages=8,
            page_size=ps),
        donate_argnums=2)
    chunk = {"tokens": jnp.ones((1, 16), jnp.int32)}
    pf_t = {}
    zero = jnp.asarray(0, jnp.int32)
    sixteen = jnp.asarray(16, jnp.int32)
    for P in (pools[0], pools[-1]):
        def run(steps, P=P):
            logits, c = prefill(params, chunk, make_cache(P), zero,
                                sixteen, sixteen)
            jax.block_until_ready(logits)
            return timed_loop(
                lambda c: prefill(params, chunk, c, zero, sixteen,
                                  sixteen)[1], c, steps)

        pf_t[P] = _best_of(run, steps=32)

    # --- micro pair: cache update alone, carry vs unrolled ---
    L, H, D = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    rows = jnp.arange(B, dtype=jnp.int32) * ps + 1
    newk = jnp.ones((B, H, D), jnp.float32)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def carry_step(pk, pv):
        # the pre-refactor layout: stacked pool as scan xs -> ys forces
        # a fresh O(pool bytes) ys allocation+copy every call
        def body(_, kv):
            k, v = kv
            return None, (k.at[rows].set(newk), v.at[rows].set(newk))
        _, out = jax.lax.scan(body, None, (pk, pv))
        return out

    @functools.partial(jax.jit, donate_argnums=0)
    def unrolled_step(bufs):
        return [(k.at[rows].set(newk), v.at[rows].set(newk))
                for k, v in bufs]

    micro = {}
    for P in (pools[0], pools[-1]):
        shape = (P * ps, H, D)

        def run_carry(steps, shape=shape):
            kv = carry_step(jnp.zeros((L,) + shape, jnp.float32),
                            jnp.zeros((L,) + shape, jnp.float32))
            jax.block_until_ready(kv)
            t0 = time.perf_counter()
            for _ in range(steps):
                kv = carry_step(*kv)
            jax.block_until_ready(kv)
            return time.perf_counter() - t0

        def run_unrolled(steps, shape=shape):
            b = unrolled_step([(jnp.zeros(shape, jnp.float32),
                                jnp.zeros(shape, jnp.float32))
                               for _ in range(L)])
            jax.block_until_ready(b)
            t0 = time.perf_counter()
            for _ in range(steps):
                b = unrolled_step(b)
            jax.block_until_ready(b)
            return time.perf_counter() - t0

        micro[P] = (_best_of(run_carry, steps=32),
                    _best_of(run_unrolled, steps=32))

    lo, hi = pools[0], pools[-1]
    rows_out: List[Row] = []
    for P in pools:
        rows_out.append((f"serving_scan_escape.decode_step_ms.p{P}",
                         dec_t[P] * 1e6, f"{dec_t[P] * 1e3:.3f}"))
    rows_out += [
        ("serving_scan_escape.decode_flatness", 0.0,
         f"{dec_t[hi] / dec_t[lo]:.2f}"),
        (f"serving_scan_escape.nodonate.decode_step_ms.p{lo}",
         nd_t[lo] * 1e6, f"{nd_t[lo] * 1e3:.3f}"),
        (f"serving_scan_escape.nodonate.decode_step_ms.p{hi}",
         nd_t[hi] * 1e6, f"{nd_t[hi] * 1e3:.3f}"),
        ("serving_scan_escape.nodonate.decode_scaling", 0.0,
         f"{nd_t[hi] / max(nd_t[lo], 1e-12):.2f}"),
        (f"serving_scan_escape.prefill_chunk_ms.p{lo}", pf_t[lo] * 1e6,
         f"{pf_t[lo] * 1e3:.3f}"),
        (f"serving_scan_escape.prefill_chunk_ms.p{hi}", pf_t[hi] * 1e6,
         f"{pf_t[hi] * 1e3:.3f}"),
        ("serving_scan_escape.prefill_flatness", 0.0,
         f"{pf_t[hi] / pf_t[lo]:.2f}"),
        (f"serving_scan_escape.micro.carry_ms.p{lo}", micro[lo][0] * 1e6,
         f"{micro[lo][0] * 1e3:.3f}"),
        (f"serving_scan_escape.micro.carry_ms.p{hi}", micro[hi][0] * 1e6,
         f"{micro[hi][0] * 1e3:.3f}"),
        ("serving_scan_escape.micro.carry_scaling", 0.0,
         f"{micro[hi][0] / max(micro[lo][0], 1e-12):.2f}"),
        (f"serving_scan_escape.micro.unrolled_ms.p{lo}",
         micro[lo][1] * 1e6, f"{micro[lo][1] * 1e3:.3f}"),
        (f"serving_scan_escape.micro.unrolled_ms.p{hi}",
         micro[hi][1] * 1e6, f"{micro[hi][1] * 1e3:.3f}"),
        ("serving_scan_escape.micro.unrolled_flatness", 0.0,
         f"{micro[hi][1] / max(micro[lo][1], 1e-12):.2f}"),
    ]
    return rows_out


TP_SHARDS = (1, 2, 4)


def _tp_child() -> None:
    """Child-process body of the ``serving_tp`` section (needs forced
    host devices, which must be set before the first jax import — the
    parent bench process keeps its single real CPU device).  Runs a
    fixed Poisson workload through the paged engine plain and over
    ``model``-axis meshes of every ``TP_SHARDS`` size, and prints one
    JSON dict of measurements to stdout."""
    import json

    from repro.core.tp import collective_ops_in
    from repro.launch.mesh import make_mesh
    from repro.models import ModelConfig, build_model
    from repro.serving import (ContinuousServingEngine, Request,
                               SamplingParams, throughput_report)

    cfg = ModelConfig(name="bench-tp", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(17)
    reqs = [Request(uid=i,
                    prompt=list(rng.integers(1, 258, 6 + 4 * (i % 3))),
                    sampling=SamplingParams(max_new_tokens=12))
            for i in range(8)]
    arrivals = np.cumsum(rng.exponential(0.05, size=len(reqs))).tolist()
    max_len = max(len(r.prompt) for r in reqs) + 12 + 8

    def run(mesh=None, n_nodes=1):
        eng = ContinuousServingEngine(
            model, params, max_len=max_len, max_running=8, page_size=8,
            mesh=mesh, n_nodes=n_nodes)
        eng.generate(reqs[:3])      # warm every prompt-length bucket
        t0 = time.perf_counter()
        comps = eng.generate(reqs, arrivals=arrivals)
        wall = time.perf_counter() - t0
        rep = throughput_report(
            comps, wall_s=wall,
            prefill_s=eng.last_phase_s["prefill_s"],
            decode_s=wall - eng.last_phase_s["prefill_s"])
        ttft = sorted(c.t_first - c.t0 for c in comps)
        return eng, ([c.tokens for c in comps],
                     rep["decode_tok_per_s"],
                     ttft[len(ttft) // 2])

    _, (ref_tokens, *_rest) = run()
    out = {"parity": True}
    for s in TP_SHARDS:
        mesh = make_mesh((s,), ("model",))
        eng, (tokens, toks_per_s, ttft_p50) = run(mesh, n_nodes=s)
        out[f"s{s}"] = {"decode_toks_per_s": toks_per_s,
                        "ttft_p50_ms": ttft_p50 * 1e3}
        out["parity"] = out["parity"] and tokens == ref_tokens
        if s == TP_SHARDS[-1]:
            r = eng.core.runner
            counts = collective_ops_in(
                r.tp_raw_decode, r.params, r.cache,
                jnp.ones((8, 1), jnp.int32), jnp.zeros((8,), jnp.int32))
            out["psum_per_layer"] = counts.get("psum", 0) / cfg.n_layers
            out["kv_gather_collectives"] = sum(
                v for k, v in counts.items() if k != "psum")
    print(json.dumps(out))


def serving_tp_rows() -> List[Row]:
    """Tensor-parallel paged serving over the ``model`` mesh axis
    (shard ≅ NUMA node, forced host devices): per-shard KV page pools,
    head-sharded paged attention, one psum per layer.

      serving_tp.decode_toks_per_s.sN  continuous decode throughput on
                         the fixed Poisson workload at N shards
      serving_tp.ttft_p50_ms.sN        median time-to-first-token
      serving_tp.greedy_parity         every shard count must produce
                         byte-identical greedy tokens vs the plain
                         single-shard engine
      serving_tp.psum_per_layer        collectives in the compiled
                         decode body (exactly 1 all-reduce per layer)
      serving_tp.kv_gather_collectives non-psum collectives (must be 0:
                         KV-page bytes never cross shards)
    """
    import json
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count="
                        + str(max(TP_SHARDS)))
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    proc = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.serving_bench import _tp_child; _tp_child()"],
        capture_output=True, text=True, env=env, cwd=root, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"serving_tp child failed:\n{proc.stderr[-3000:]}")
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    rows: List[Row] = []
    for s in TP_SHARDS:
        m = out[f"s{s}"]
        rows.append((f"serving_tp.decode_toks_per_s.s{s}", 0.0,
                     f"{m['decode_toks_per_s']:.1f}"))
        rows.append((f"serving_tp.ttft_p50_ms.s{s}",
                     m["ttft_p50_ms"] * 1e3,
                     f"{m['ttft_p50_ms']:.1f}"))
    rows += [
        ("serving_tp.greedy_parity", 0.0,
         "OK" if out["parity"] else "MISMATCH"),
        ("serving_tp.psum_per_layer", 0.0,
         f"{out['psum_per_layer']:.2f}"),
        ("serving_tp.kv_gather_collectives", 0.0,
         f"{out['kv_gather_collectives']}"),
    ]
    return rows


HTTP_GROUPS = 8          # distinct shared 2-block prefixes
HTTP_PER_GROUP = 3       # requests per prefix (2 affinity hits each)
HTTP_MAX_NEW = 16


def _http_workload():
    """Deterministic saturating Poisson workload: 8 shared-prefix
    groups x 3 requests, near-zero inter-arrival gaps (open loop —
    clients do not wait for each other), 16 greedy tokens each."""
    rng = np.random.default_rng(11)
    prompts = []
    for g in range(HTTP_GROUPS):
        prefix = [int(t) for t in
                  rng.integers(1, 250, 32)]          # 2 full 16-blocks
        for j in range(HTTP_PER_GROUP):
            prompts.append(prefix + [251 + g % 8, 1 + j])
    arrivals = np.cumsum(rng.exponential(0.01, size=len(prompts)))
    return prompts, arrivals.tolist()


def _http_poisson_run(n_replicas: int):
    """Serve the workload over the full network stack — client HTTP ->
    ``HttpFrontend`` -> ``Router`` -> worker HTTP -> ``AsyncEngine``
    subprocess — and return per-request timings/tokens + router stats."""
    import http.client as hc
    import json as _json
    import threading

    from repro.serving import HttpFrontend, Router, Supervisor

    prompts, arrivals = _http_workload()
    sup = Supervisor(n_replicas,
                     ["--arch", "tiny", "--max-running", "4"])
    clients = sup.start()
    router = Router(clients, page_size=16)
    sup.on_death = lambda rid, rc: router.mark_dead(rid)
    fe = HttpFrontend(router).start()
    try:
        # compile warm-up: 4 concurrent full-shape requests per replica
        # (keyed to land there), so every prefill shape and decode
        # batch size 1..max_running is compiled on every worker before
        # the clock starts — measured TTFT is serving latency, not XLA
        def _post_blocking(p) -> None:
            conn = hc.HTTPConnection(fe.host, fe.port, timeout=600)
            conn.request("POST", "/v1/completions",
                         _json.dumps({"prompt": p, "max_tokens": 8}),
                         {"Content-Type": "application/json"})
            assert conn.getresponse().read()
            conn.close()

        for rid in clients:
            warm = []
            for s in range(100_000):
                p = [(s * 13 + i) % 250 + 1 for i in range(32)]
                if router.ring.pick(router.affinity_key(p)) == rid:
                    warm.append(p + [253, len(warm)])
                    if len(warm) == 4:
                        break
            ts = [threading.Thread(target=_post_blocking, args=(p,))
                  for p in warm]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
        for c in ("router.affinity.keyed", "router.affinity.hits"):
            inst = router.registry.get(c)
            if inst is not None:
                inst.reset()

        results = [None] * len(prompts)
        t0 = time.perf_counter()

        def run_one(i: int) -> None:
            time.sleep(max(arrivals[i] - (time.perf_counter() - t0), 0))
            conn = hc.HTTPConnection(fe.host, fe.port, timeout=600)
            t_submit = time.perf_counter()
            conn.request("POST", "/v1/completions",
                         _json.dumps({"prompt": prompts[i],
                                      "max_tokens": HTTP_MAX_NEW,
                                      "stream": True}),
                         {"Content-Type": "application/json"})
            resp = conn.getresponse()
            toks, stamps = [], []
            while True:
                line = resp.readline().strip()
                if not line or not line.startswith(b"data:"):
                    continue
                payload = line[5:].strip()
                if payload == b"[DONE]":
                    break
                ev = _json.loads(payload)
                if "token" in ev:
                    toks.append(ev["token"])
                    stamps.append(time.perf_counter())
                elif "error" in ev:
                    raise RuntimeError(f"request {i}: {ev['error']}")
            conn.close()
            results[i] = {"t_submit": t_submit, "stamps": stamps,
                          "tokens": toks}

        threads = [threading.Thread(target=run_one, args=(i,))
                   for i in range(len(prompts))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        def _count(name: str) -> float:
            inst = router.registry.get(name)
            return inst.value() if inst is not None else 0.0

        keyed = _count("router.affinity.keyed")
        hits = _count("router.affinity.hits")
    finally:
        fe.close()
        router.shutdown()
        sup.shutdown()
    assert all(r is not None and len(r["tokens"]) == HTTP_MAX_NEW
               for r in results), "incomplete HTTP run"
    return {"results": results, "wall": wall, "keyed": keyed,
            "hits": hits, "prompts": prompts}


def serving_http_rows() -> List[Row]:
    """Network serving stack under saturating open-loop Poisson load,
    1 vs 2 engine-worker replicas (``docs/serving.md`` "HTTP serving
    front-end"):

      serving_http.ttft_p50_ms.rN / ttft_p99_ms.rN
                         client-side time-to-first-token over the full
                         wire path (HTTP front door -> router -> worker
                         HTTP -> engine)
      serving_http.itl_p50_ms.rN   median per-request mean inter-token
                         latency off the socket
      serving_http.toks_per_s.rN   aggregate client-visible decode
                         throughput (all streamed tokens / wall)
      serving_http.speedup_r2      r2 / r1 toks_per_s.  On a host with
                         >= 2 cores, 2 replicas must beat 1 under
                         saturation.  On a single-core host (CI
                         containers — see serving_http.host_cpus) the
                         replicas time-slice one core and the row
                         instead quantifies the oversubscription
                         penalty of process replication vs one
                         continuously-batched engine — the paper's
                         argument for a lightweight single-process
                         core, measured
      serving_http.host_cpus       cores visible to this process; the
                         context for reading speedup_r2
      serving_http.affinity_hit_rate.r2
                         keyed requests routed to a replica that
                         already served their prefix (8 groups x 3:
                         2/3 is the deterministic ceiling)
      serving_http.greedy_parity   tokens off the socket vs in-process
                         ``AsyncEngine`` greedy tokens — the network
                         stack must be byte-invisible
    """
    from repro.serving import AsyncEngine, Request, SamplingParams

    runs = {n: _http_poisson_run(n) for n in (1, 2)}

    # in-process reference for the SAME prompts: network serving must
    # not change a single greedy token
    model, params, _, _ = _setup()
    prompts = runs[1]["prompts"]
    with AsyncEngine(model, params,
                     max_len=len(prompts[0]) + HTTP_MAX_NEW + 16,
                     max_running=4, page_size=16) as eng:
        handles = [eng.submit(Request(
            uid=i, prompt=p,
            sampling=SamplingParams(max_new_tokens=HTTP_MAX_NEW)))
            for i, p in enumerate(prompts)]
        ref = [eng.result(h, timeout=600).tokens for h in handles]
    parity = all(runs[n]["results"][i]["tokens"] == ref[i]
                 for n in (1, 2) for i in range(len(prompts)))

    rows: List[Row] = []
    tput = {}
    for n in (1, 2):
        res = runs[n]["results"]
        ttft = sorted((r["stamps"][0] - r["t_submit"]) * 1e3
                      for r in res)
        itl = sorted(float(np.mean(np.diff(r["stamps"])) * 1e3)
                     for r in res)
        tput[n] = sum(len(r["tokens"]) for r in res) / runs[n]["wall"]
        rows += [
            (f"serving_http.ttft_p50_ms.r{n}", ttft[len(ttft) // 2] * 1e3,
             f"{ttft[len(ttft) // 2]:.1f}"),
            (f"serving_http.ttft_p99_ms.r{n}", ttft[-1] * 1e3,
             f"{ttft[-1]:.1f}"),
            (f"serving_http.itl_p50_ms.r{n}", itl[len(itl) // 2] * 1e3,
             f"{itl[len(itl) // 2]:.2f}"),
            (f"serving_http.toks_per_s.r{n}", 0.0, f"{tput[n]:.1f}"),
        ]
    hit_rate = (runs[2]["hits"] / runs[2]["keyed"]
                if runs[2]["keyed"] else 0.0)
    try:
        n_cpus = len(os.sched_getaffinity(0))
    except AttributeError:                        # non-Linux fallback
        n_cpus = os.cpu_count() or 1
    rows += [
        ("serving_http.host_cpus", 0.0, str(n_cpus)),
        ("serving_http.speedup_r2", 0.0, f"{tput[2] / tput[1]:.2f}x"),
        ("serving_http.affinity_hit_rate.r2", 0.0, f"{hit_rate:.2f}"),
        ("serving_http.greedy_parity", 0.0,
         "OK" if parity else "MISMATCH"),
    ]
    return rows


#: documented greedy-divergence bound for the quantized serving path
#: (docs/quantization.md "The divergence gate"): teacher-forced
#: next-token agreement of --quant q4 --kv-dtype int8 vs the fp32
#: engine must stay at or above this on the fixed workload (measured
#: 0.917 at PR 8; the margin absorbs backend numeric drift)
QUANT_MATCH_BOUND = 0.80
#: fixed byte budget the capacity rows size page pools against
QUANT_BUDGET_BYTES = 16 * 1024 * 1024


def serving_quant_rows() -> List[Row]:
    """Quantized serving path vs fp32 (``docs/quantization.md``):
    Q4_0 weights + int8 KV pages through the SAME paged engine on the
    same fixed workload.

      serving_quant.decode_toks_per_s.fp32 / .q4int8
                         continuous-engine decode throughput under the
                         fixed Poisson arrivals, full precision vs
                         --quant q4 --kv-dtype int8
      serving_quant.token_match_rate
                         teacher-forced next-token agreement: the fp32
                         engine's greedy continuations are replayed
                         through the quantized engine one position at a
                         time (prompt + fp32 tokens[:j], max_new=1) and
                         each greedy pick is compared to the fp32 token
                         at that position.  Cascade-free — a flipped
                         token cannot poison later comparisons — so the
                         rate measures per-step quantization error, not
                         trajectory luck.  The replay prompts share
                         pages heavily, so this also exercises
                         prefix-cache sharing + CoW over int8 pages.
      serving_quant.match_budget
                         OK when token_match_rate >= QUANT_MATCH_BOUND
      serving_quant.page_bytes.fp32 / .int8
                         device bytes per KV page (all layers/heads)
      serving_quant.pages_at_16MiB.fp32 / .int8
                         whole pages that fit in the fixed budget
      serving_quant.page_capacity_ratio
                         int8 pages per fp32 page at equal bytes —
                         4*D/(D+4), 3.56x at bench-tiny's D=32; the
                         acceptance floor is 1.9x

    The model is warm-trained briefly (fixed seed, deterministic) so
    greedy argmax has real margins — on random weights every logit gap
    is noise and the match rate measures luck, not quantization.
    """
    from repro.data.pipeline import PackedLMDataset
    from repro.quant.policy import QuantPolicy
    from repro.serving import (ContinuousServingEngine, Request,
                               SamplingParams, throughput_report)
    from repro.training.loop import train
    from repro.training.optimizer import AdamWConfig

    model, params0, reqs, arrivals = _setup()
    ds = PackedLMDataset(seq_len=64, n_docs=500,
                         vocab_size=model.cfg.vocab_size)
    params, _, _ = train(model, params0, ds.batches(8),
                         AdamWConfig(lr=2e-3, warmup_steps=5,
                                     total_steps=80),
                         steps=80, log_every=1000)
    max_new = reqs[0].sampling.max_new_tokens
    max_len = max(len(r.prompt) for r in reqs) + 2 * max_new + 8
    q4int8 = QuantPolicy(weights="q4", kv_dtype="int8")

    def engine(quant):
        return ContinuousServingEngine(
            model, params, max_len=max_len, max_running=8, page_size=8,
            quant=quant)

    def throughput(quant):
        engine(quant).generate(reqs[:1])        # warm compile caches
        eng = engine(quant)
        t0 = time.perf_counter()
        comps = eng.generate(reqs, arrivals=arrivals)
        wall = time.perf_counter() - t0
        rep = throughput_report(
            comps, wall_s=wall,
            prefill_s=eng.last_phase_s["prefill_s"],
            decode_s=wall - eng.last_phase_s["prefill_s"])
        return eng, comps, rep["decode_tok_per_s"]

    feng, fcomps, ftoks = throughput(None)
    qeng, _qcomps, qtoks = throughput(q4int8)

    # teacher-forced replay: every fp32 continuation position becomes
    # its own max_new=1 request against the quantized engine
    one = SamplingParams(temperature=0.0, max_new_tokens=1)
    replay, want = [], []
    for r, c in zip(reqs, fcomps):
        for j in range(len(c.tokens)):
            replay.append(Request(uid=len(replay),
                                  prompt=list(r.prompt) + c.tokens[:j],
                                  sampling=one))
            want.append(c.tokens[j])
    eng = engine(q4int8)
    got = {c.uid: c.tokens for c in eng.generate(replay)}
    match = sum(int(got[u][0] == want[u]) for u in range(len(want)))
    rate = match / len(want)

    pb = {"fp32": feng.pool.cfg.page_bytes,
          "int8": qeng.pool.cfg.page_bytes}
    pages = {k: QUANT_BUDGET_BYTES // v for k, v in pb.items()}
    ratio = pb["fp32"] / pb["int8"]
    return [
        ("serving_quant.decode_toks_per_s.fp32", 0.0, f"{ftoks:.1f}"),
        ("serving_quant.decode_toks_per_s.q4int8", 0.0, f"{qtoks:.1f}"),
        ("serving_quant.token_match_rate", 0.0, f"{rate:.3f}"),
        ("serving_quant.match_budget", 0.0,
         "OK" if rate >= QUANT_MATCH_BOUND else "UNDER"),
        ("serving_quant.page_bytes.fp32", 0.0, f"{pb['fp32']}"),
        ("serving_quant.page_bytes.int8", 0.0, f"{pb['int8']}"),
        ("serving_quant.pages_at_16MiB.fp32", 0.0, f"{pages['fp32']}"),
        ("serving_quant.pages_at_16MiB.int8", 0.0, f"{pages['int8']}"),
        ("serving_quant.page_capacity_ratio", 0.0, f"{ratio:.2f}x"),
    ]


def serving_spec_rows() -> List[Row]:
    """Self-speculative decoding vs plain decode (``docs/serving.md``):
    prompt-lookup drafts + batched paged verify through the SAME
    continuous engine on a shared-prefix + repetitive-text Poisson
    workload — the traffic shape speculation exists for.

      serving_spec.decode_toks_per_s.k0 / .k4
                         decode throughput without / with
                         ``spec_decode=4`` on the same arrivals
      serving_spec.itl_ms.p50.k0 / .k4  (and .p99.*)
                         per-step inter-token latency percentiles from
                         the engines' ``serving.decode.itl_ms``
                         histograms — a verify step costs more wall
                         time than a decode step, but emits up to k+1
                         tokens for it
      serving_spec.tokens_per_step.k0 / .k4
                         tokens emitted per lane per decode/verify
                         step (``serving.tokens.decode`` over the
                         occupancy histogram's lane-step sum) — 1.0 by
                         construction at k=0; > 1.0 is the point of
                         speculation: every extra token is a decode
                         forward the device never ran
      serving_spec.accept_rate
                         accepted / drafted draft tokens over the run
      serving_spec.speedup
                         k4 / k0 decode tok/s
      serving_spec.greedy_parity
                         OK when the k=4 token streams are
                         byte-identical to k=0 — the acceptance
                         contract (also asserted per-scenario in
                         ``tests/test_spec_decode.py``)
      serving_spec.budget
                         OK when parity holds and
                         tokens_per_step.k4 > 1.0

    Warm-trained on PERIODIC text (fixed seed, deterministic): each
    training row tiles a short random pattern, so the model learns to
    continue repetitions — the induction behavior repetitive serving
    traffic exercises and prompt-lookup drafting bets on.  On that
    traffic the drafter's proposals match the model's own greedy
    continuation, acceptance is high, and the verify step's extra cost
    is paid back several tokens at a time.
    """
    from repro.serving import (ContinuousServingEngine, Request,
                               SamplingParams, throughput_report)
    from repro.training.loop import train
    from repro.training.optimizer import AdamWConfig

    model, params0, _reqs, _arr = _setup()
    vocab = model.cfg.vocab_size
    seq_len = 64

    def periodic_batches(batch_size=8, seed=5):
        prng = np.random.default_rng(seed)
        while True:
            rows = []
            for _ in range(batch_size):
                period = int(prng.integers(2, 5))
                pat = prng.integers(1, vocab, size=period)
                row = np.tile(pat, seq_len // period + 2)[:seq_len + 1]
                rows.append(row)
            chunk = np.stack(rows).astype(np.int32)
            yield {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}

    params, _, _ = train(model, params0, periodic_batches(),
                         AdamWConfig(lr=2e-3, warmup_steps=5,
                                     total_steps=80),
                         steps=80, log_every=1000)

    rng = np.random.default_rng(11)
    system = list(rng.integers(1, 258, 8))      # shared prefix block
    pats = [list(rng.integers(1, 258, 3)) for _ in range(4)]
    reqs = []
    for i in range(8):
        body = pats[i % 4] * 6
        reqs.append(Request(
            uid=i, prompt=system + body[:14 + (i % 3)],
            sampling=SamplingParams(max_new_tokens=24)))
    arrivals = np.cumsum(rng.exponential(0.1, size=len(reqs))).tolist()
    max_len = max(len(r.prompt) for r in reqs) + 24 + 8

    def scrape(eng):
        snap = eng.registry.snapshot()
        hists = {h["name"]: h for h in snap["histograms"]}
        counters = {c["name"]: c["value"] for c in snap["counters"]}
        return (counters.get("serving.tokens.decode", 0.0),
                hists["serving.batch.occupancy"]["sum"], counters,
                hists["serving.decode.itl_ms"])

    def run(k):
        # warm the SAME engine the timed run uses: the verify step's
        # compile (one per draft width) must not land inside the timed
        # window — reqs[0] is repetitive, so a k>0 warmup drafts and
        # compiles it
        eng = ContinuousServingEngine(
            model, params, max_len=max_len, max_running=8,
            page_size=8, spec_decode=k)
        eng.generate(reqs[:2])
        tok0, lane0, _, _ = scrape(eng)
        t0 = time.perf_counter()
        comps = eng.generate(reqs, arrivals=arrivals)
        wall = time.perf_counter() - t0
        rep = throughput_report(
            comps, wall_s=wall,
            prefill_s=eng.last_phase_s["prefill_s"],
            decode_s=wall - eng.last_phase_s["prefill_s"])
        tok1, lane1, counters, itl = scrape(eng)    # run-scoped ITL
        tps = (tok1 - tok0) / max(lane1 - lane0, 1.0)
        return (comps, rep["decode_tok_per_s"], tps,
                itl["p50"], itl["p99"], counters)

    c0, toks0, tps0, p50_0, p99_0, _ = run(0)
    c4, toks4, tps4, p50_4, p99_4, ctr = run(4)
    parity = all(a.tokens == b.tokens for a, b in zip(c0, c4))
    drafted = ctr.get("spec.drafted", 0.0)
    rate = ctr.get("spec.accepted", 0.0) / max(drafted, 1.0)
    return [
        ("serving_spec.decode_toks_per_s.k0", 0.0, f"{toks0:.1f}"),
        ("serving_spec.decode_toks_per_s.k4", 0.0, f"{toks4:.1f}"),
        ("serving_spec.itl_ms.p50.k0", 0.0, f"{p50_0:.2f}"),
        ("serving_spec.itl_ms.p50.k4", 0.0, f"{p50_4:.2f}"),
        ("serving_spec.itl_ms.p99.k0", 0.0, f"{p99_0:.2f}"),
        ("serving_spec.itl_ms.p99.k4", 0.0, f"{p99_4:.2f}"),
        ("serving_spec.tokens_per_step.k0", 0.0, f"{tps0:.2f}"),
        ("serving_spec.tokens_per_step.k4", 0.0, f"{tps4:.2f}"),
        ("serving_spec.accept_rate", 0.0, f"{rate:.3f}"),
        ("serving_spec.speedup", 0.0,
         f"{toks4 / max(toks0, 1e-9):.2f}x"),
        ("serving_spec.greedy_parity", 0.0,
         "OK" if parity else "MISMATCH"),
        ("serving_spec.budget", 0.0,
         "OK" if tps4 > 1.0 and parity else "UNDER"),
    ]


def serving_slo_rows() -> List[Row]:
    """Goodput under SLOs at saturation, protection off vs on.

    The workload holds three request classes over one bench-tiny model
    (4 slots, so the 20-deep heavy backlog saturates the batch):

    * 20 **heavy** requests at t=0 — throughput work, no latency SLO
      (``priority="batch"`` when protected);
    * 8 **interactive** shared-prefix chats arriving just after, SLO =
      2.5x one 4-wide heavy wave's wall time (``W``), deadline-stamped
      when protected;
    * 8 **hopeless** heavies with a half-wave budget that queue-depth
      arithmetic says can never be met — protection must shed them
      from the *queue* (zero compute burned); unprotected they run to
      completion and every token they produce is waste.

    Goodput credits a completion's tokens only when it finished inside
    its class window, so the unprotected run pays twice: hopeless work
    dilutes the denominator (wall) and earns nothing, and interactive
    completions miss their window queued behind the backlog.
    """
    from repro.models import ModelConfig, build_model
    from repro.serving import (ContinuousServingEngine, Request,
                               SamplingParams)

    cfg = ModelConfig(name="bench-tiny", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(23)
    heavy_prompts = [list(rng.integers(1, 258, 96)) for _ in range(28)]
    system = list(rng.integers(1, 258, 16))     # 2 full pages @ ps=8
    inter_prompts = [system + list(rng.integers(1, 258, 8))
                     for _ in range(8)]
    max_len = 96 + 96 + 8

    # ONE engine serves calibration and both measured runs: compiles
    # (per-engine jit caches) are paid once up front, and with the
    # prefix cache off no KV reuse can leak between the two modes —
    # the pool drains to empty at every generate() boundary.
    eng = ContinuousServingEngine(model, params, max_len=max_len,
                                  max_running=4, page_size=8,
                                  prefix_cache=False)

    # calibrate W = one full 4-wide wave of heavies (the engine's
    # natural service quantum here: 20 queued heavies drain as 5 such
    # waves), post-compile
    def heavies(uids):
        return [Request(uid=u, prompt=heavy_prompts[u],
                        sampling=SamplingParams(max_new_tokens=96))
                for u in uids]

    eng.generate(heavies([0, 1, 2, 3]))
    t0 = time.perf_counter()
    eng.generate(heavies([4, 5, 6, 7]))
    W = time.perf_counter() - t0
    # warm the interactive + mixed-admission shapes too (heavies decode
    # while chats queue, then chats admit at the wave boundary) so
    # neither measured run pays a first-compile stall mid-flight
    warm = heavies([8, 9, 10, 11]) + [
        Request(uid=900 + i, prompt=inter_prompts[i],
                sampling=SamplingParams(max_new_tokens=8))
        for i in range(8)]
    eng.generate(warm, arrivals=[0.0] * 4 + [0.02 * i for i in range(8)])
    # 2.5 waves leaves the interactive window real but meetable:
    # protected they admit at the first wave boundary (priority) and
    # finish inside it; unprotected they queue behind the whole heavy
    # backlog (7 waves) and blow it.  Half a wave can never fit a
    # heavy that must wait waves for a slot — the hopeless class.
    slo = {"heavy": float("inf"), "interactive": 2.5 * W,
           "hopeless": 0.5 * W}

    def workload(protected):
        reqs, arrivals, cls = [], [], {}
        for i in range(20):             # heavy backlog, all at t=0
            reqs.append(Request(
                uid=i, prompt=heavy_prompts[i],
                sampling=SamplingParams(max_new_tokens=96),
                priority="batch" if protected else "interactive"))
            arrivals.append(0.0)
            cls[i] = "heavy"
        for i in range(8):              # hopeless: W/2 budget, 5W queue
            reqs.append(Request(
                uid=100 + i, prompt=heavy_prompts[20 + i],
                sampling=SamplingParams(max_new_tokens=96),
                priority="batch" if protected else "interactive",
                deadline_s=slo["hopeless"] if protected else None))
            arrivals.append(0.01)
            cls[100 + i] = "hopeless"
        for i in range(8):              # interactive chat
            reqs.append(Request(
                uid=200 + i, prompt=inter_prompts[i],
                sampling=SamplingParams(max_new_tokens=8),
                deadline_s=slo["interactive"] if protected else None))
            arrivals.append(0.02 + 0.02 * i)
            cls[200 + i] = "interactive"
        return reqs, arrivals, cls

    results = {}
    for protected in (False, True):
        reqs, arrivals, cls = workload(protected)
        exp0 = eng.registry.get("scheduler.expired").value()
        t0 = time.perf_counter()
        comps = eng.generate(reqs, arrivals=arrivals)
        wall = time.perf_counter() - t0
        good = sum(len(c.tokens) for c in comps
                   if c.t1 - c.t0 <= slo[cls[c.uid]])
        ttft = sorted(c.t_first - c.t0 for c in comps
                      if cls[c.uid] == "interactive")
        expired = eng.registry.get("scheduler.expired").value() - exp0
        results[protected] = {
            "goodput": good / wall, "wall": wall, "expired": expired,
            "ttft": ttft, "tokens": {c.uid: list(c.tokens)
                                     for c in comps}}
    un, pr = results[False], results[True]
    ratio = pr["goodput"] / max(un["goodput"], 1e-9)
    # greedy byte parity over requests completed in BOTH modes: the
    # SLO layer may drop requests, never change a survivor's tokens
    both = set(un["tokens"]) & set(pr["tokens"])
    parity = "OK" if all(un["tokens"][u] == pr["tokens"][u]
                         for u in both) else "MISMATCH"
    return [
        ("serving_slo.calib_wave_wall_ms", W * 1e6, f"{W * 1e3:.0f}"),
        ("serving_slo.goodput_toks_per_s.unprotected",
         un["wall"] * 1e6, f"{un['goodput']:.1f}"),
        ("serving_slo.goodput_toks_per_s.protected",
         pr["wall"] * 1e6, f"{pr['goodput']:.1f}"),
        ("serving_slo.goodput_ratio", 0.0, f"{ratio:.2f}x"),
        ("serving_slo.interactive_ttft_p99_ms.unprotected",
         _pct(un["ttft"], 0.99) * 1e6 if un["ttft"] else 0.0,
         f"{_pct(un['ttft'], 0.99) * 1e3:.0f}" if un["ttft"] else "n/a"),
        ("serving_slo.interactive_ttft_p99_ms.protected",
         _pct(pr["ttft"], 0.99) * 1e6 if pr["ttft"] else 0.0,
         f"{_pct(pr['ttft'], 0.99) * 1e3:.0f}" if pr["ttft"] else "n/a"),
        ("serving_slo.deadline_sheds.protected", 0.0,
         f"{pr['expired']:.0f}"),
        ("serving_slo.completed.unprotected", 0.0,
         f"{len(un['tokens'])}"),
        ("serving_slo.completed.protected", 0.0,
         f"{len(pr['tokens'])}"),
        ("serving_slo.greedy_parity", 0.0, parity),
    ]


def all_rows() -> List[Row]:
    return (serving_cb_rows() + serving_prefix_rows() +
            serving_chunk_rows() + serving_async_rows() +
            serving_obs_rows() + serving_scan_escape_rows() +
            serving_tp_rows() + serving_http_rows() +
            serving_quant_rows() + serving_spec_rows() +
            serving_slo_rows())


if __name__ == "__main__":
    for name, us, derived in all_rows():
        print(f"{name},{us:.1f},{derived}")
