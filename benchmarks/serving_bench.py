"""Serving-engine comparison under staggered (Poisson) arrivals.

The experiment behind the continuous-batching subsystem: requests with
mixed prompt lengths arrive as a Poisson process; the length-bucket
baseline can only start once its batch is assembled (and then runs
buckets strictly sequentially), while the continuous engine admits each
request on arrival into the slot-indexed running batch.  Reported rows:

  serving_cb.bucket.*      bucket engine, work starts at the LAST arrival
  serving_cb.continuous.*  paged-KV continuous engine, per-step admission
  serving_cb.speedup       continuous / bucket decode tok/s (>1 = win)

Wall times include the arrival span — that is the point: decode tok/s
here is throughput *as the client sees it*, not device-only.

Two further sections exercise the prefix-caching / chunked-prefill
follow-ons (see ``docs/serving.md``):

  serving_prefix.*   shared-system-prompt Poisson workload, prefix
                     cache off vs on: prefill pages allocated, pages
                     shared, prompt tokens served from cache, and a
                     greedy-token parity check (caching must be
                     invisible in the output)
  serving_chunk.*    long-prompt admission into a busy decode batch,
                     one-shot vs chunked prefill: max wall gap between
                     consecutive decode steps (chunking bounds it)
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _setup():
    from repro.models import ModelConfig, build_model
    from repro.serving import Request, SamplingParams

    cfg = ModelConfig(name="bench-tiny", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    reqs = [Request(uid=i,
                    prompt=list(rng.integers(1, 258, 4 + 4 * (i % 3))),
                    sampling=SamplingParams(max_new_tokens=16))
            for i in range(8)]
    # Poisson process: exponential inter-arrival gaps, mean 0.25 s
    arrivals = np.cumsum(rng.exponential(0.25, size=len(reqs)))
    return model, params, reqs, arrivals.tolist()


def serving_cb_rows(mean_gap_scale: float = 1.0) -> List[Row]:
    from repro.serving import (ContinuousServingEngine, ServingEngine,
                               throughput_report)

    model, params, reqs, arrivals = _setup()
    arrivals = [a * mean_gap_scale for a in arrivals]
    max_len = max(len(r.prompt) for r in reqs) + 16 + 8

    # --- bucket baseline: batching by length needs the whole workload,
    # so the engine cannot start before the last arrival ---
    beng = ServingEngine(model, params, max_len=max_len)
    beng.generate(reqs[:1], max_batch=8)        # warm compile caches
    t0 = time.perf_counter()
    time.sleep(max(arrivals))                   # waiting for arrivals
    bc = beng.generate(reqs, max_batch=8)
    bwall = time.perf_counter() - t0
    brep = throughput_report(bc, wall_s=bwall,
                             prefill_s=beng.last_phase_s["prefill_s"],
                             decode_s=bwall - beng.last_phase_s["prefill_s"])

    # --- continuous engine: admission interleaves with decode ---
    ceng = ContinuousServingEngine(model, params, max_len=max_len,
                                   max_running=8, page_size=8)
    ceng.generate(reqs[:1])                     # warm compile caches
    ceng2 = ContinuousServingEngine(model, params, max_len=max_len,
                                    max_running=8, page_size=8)
    t0 = time.perf_counter()
    cc = ceng2.generate(reqs, arrivals=arrivals)
    cwall = time.perf_counter() - t0
    crep = throughput_report(cc, wall_s=cwall,
                             prefill_s=ceng2.last_phase_s["prefill_s"],
                             decode_s=cwall - ceng2.last_phase_s["prefill_s"])

    speedup = crep["decode_tok_per_s"] / max(brep["decode_tok_per_s"], 1e-9)
    return [
        ("serving_cb.bucket.decode_toks_per_s", bwall * 1e6,
         f"{brep['decode_tok_per_s']:.1f}"),
        ("serving_cb.continuous.decode_toks_per_s", cwall * 1e6,
         f"{crep['decode_tok_per_s']:.1f}"),
        ("serving_cb.continuous.preemptions", 0.0,
         f"{ceng2.scheduler.n_preemptions}"),
        ("serving_cb.speedup", 0.0, f"{speedup:.2f}x"),
    ]


def serving_prefix_rows() -> List[Row]:
    """Shared-system-prompt workload: N requests = one long system
    prompt + a short unique suffix, Poisson arrivals.  Prefix caching
    should cut the pages *allocated* for prefill (matched pages are
    shared, not allocated) without changing a single greedy token."""
    from repro.models import ModelConfig, build_model
    from repro.serving import (ContinuousServingEngine, Request,
                               SamplingParams)

    cfg = ModelConfig(name="bench-tiny", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(13)
    system = list(rng.integers(1, 258, 64))      # 8 full pages @ ps=8
    reqs = [Request(uid=i, prompt=system + list(rng.integers(1, 258, 8)),
                    sampling=SamplingParams(max_new_tokens=24))
            for i in range(8)]
    arrivals = np.cumsum(rng.exponential(0.08, size=len(reqs))).tolist()
    max_len = len(reqs[0].prompt) + 24 + 8

    results = {}
    for cached in (False, True):
        eng = ContinuousServingEngine(model, params, max_len=max_len,
                                      max_running=8, page_size=8,
                                      prefix_cache=cached)
        eng.generate(reqs[:1])                  # warm compile caches
        for k in eng.pool.stats:
            eng.pool.stats[k] = 0
        comps = eng.generate(reqs, arrivals=arrivals)
        results[cached] = (eng.pool.stats.copy(),
                           [c.tokens for c in comps])
    st_off, toks_off = results[False]
    st_on, toks_on = results[True]
    parity = "OK" if toks_on == toks_off else "MISMATCH"
    saved = st_off["fresh_pages"] - st_on["fresh_pages"]
    return [
        ("serving_prefix.pages_allocated.nocache", 0.0,
         f"{st_off['fresh_pages']}"),
        ("serving_prefix.pages_allocated.cached", 0.0,
         f"{st_on['fresh_pages']}"),
        ("serving_prefix.pages_shared", 0.0, f"{st_on['shared_pages']}"),
        ("serving_prefix.cow_copies", 0.0, f"{st_on['cow_copies']}"),
        ("serving_prefix.prompt_tokens_from_cache", 0.0,
         f"{st_on['cached_tokens']}"),
        ("serving_prefix.pages_saved", 0.0, f"{saved}"),
        ("serving_prefix.greedy_parity", 0.0, parity),
    ]


def serving_chunk_rows() -> List[Row]:
    """Long-prompt admission stall: a 768-token prompt arrives while 4
    requests are mid-decode.  One-shot prefill stalls every decode for
    the whole prompt; chunked prefill (32 tokens/step) interleaves, so
    the max gap between consecutive decode steps stays near one chunk's
    cost.  A wider model than the other sections so prefill *compute*
    (not dispatch overhead) is what stalls the batch."""
    from repro.models import ModelConfig, build_model
    from repro.serving import (ContinuousServingEngine, Request,
                               SamplingParams)

    cfg = ModelConfig(name="bench-wide", arch_type="dense", n_layers=8,
                      d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(29)
    short = [Request(uid=i, prompt=list(rng.integers(1, 258, 8)),
                     sampling=SamplingParams(max_new_tokens=200))
             for i in range(4)]
    long_r = Request(uid=4, prompt=list(rng.integers(1, 258, 768)),
                     sampling=SamplingParams(max_new_tokens=8))
    arrivals = [0.0] * 4 + [0.15]               # long prompt mid-decode
    max_len = 1024
    # size the pool to the workload's true peak (4 shorts + the long
    # prompt), not to max_running * max_len: every engine call pays an
    # O(pool bytes) cache materialisation (ROADMAP: paged pool in the
    # layer scan), so an oversized pool drowns the signal in memcpy
    n_pages = 208

    gaps = {}
    for chunk in (None, 32):
        eng = ContinuousServingEngine(model, params, max_len=max_len,
                                      max_running=5, page_size=8,
                                      n_pages=n_pages,
                                      prefill_chunk=chunk,
                                      prefix_cache=False)
        eng.generate([long_r], arrivals=[0.0])  # warm prefill compiles
        eng.generate(short[:1])
        eng.generate(short + [long_r], arrivals=arrivals)   # full warm
        eng.generate(short + [long_r], arrivals=arrivals)
        gaps[chunk] = max(eng.decode_gaps_s) if eng.decode_gaps_s else 0.0
    ratio = gaps[None] / max(gaps[32], 1e-9)
    return [
        ("serving_chunk.max_decode_gap_ms.oneshot", gaps[None] * 1e6,
         f"{gaps[None] * 1e3:.1f}"),
        ("serving_chunk.max_decode_gap_ms.chunked32", gaps[32] * 1e6,
         f"{gaps[32] * 1e3:.1f}"),
        ("serving_chunk.stall_reduction", 0.0, f"{ratio:.2f}x"),
    ]


def all_rows() -> List[Row]:
    return serving_cb_rows() + serving_prefix_rows() + serving_chunk_rows()


if __name__ == "__main__":
    for name, us, derived in all_rows():
        print(f"{name},{us:.1f},{derived}")
