"""Serving-engine comparison under staggered (Poisson) arrivals.

The experiment behind the continuous-batching subsystem: requests with
mixed prompt lengths arrive as a Poisson process; the length-bucket
baseline can only start once its batch is assembled (and then runs
buckets strictly sequentially), while the continuous engine admits each
request on arrival into the slot-indexed running batch.  Reported rows:

  serving_cb.bucket.*      bucket engine, work starts at the LAST arrival
  serving_cb.continuous.*  paged-KV continuous engine, per-step admission
  serving_cb.speedup       continuous / bucket decode tok/s (>1 = win)

Wall times include the arrival span — that is the point: decode tok/s
here is throughput *as the client sees it*, not device-only.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]


def _setup():
    from repro.models import ModelConfig, build_model
    from repro.serving import Request, SamplingParams

    cfg = ModelConfig(name="bench-tiny", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    reqs = [Request(uid=i,
                    prompt=list(rng.integers(1, 258, 4 + 4 * (i % 3))),
                    sampling=SamplingParams(max_new_tokens=16))
            for i in range(8)]
    # Poisson process: exponential inter-arrival gaps, mean 0.25 s
    arrivals = np.cumsum(rng.exponential(0.25, size=len(reqs)))
    return model, params, reqs, arrivals.tolist()


def serving_cb_rows(mean_gap_scale: float = 1.0) -> List[Row]:
    from repro.serving import (ContinuousServingEngine, ServingEngine,
                               throughput_report)

    model, params, reqs, arrivals = _setup()
    arrivals = [a * mean_gap_scale for a in arrivals]
    max_len = max(len(r.prompt) for r in reqs) + 16 + 8

    # --- bucket baseline: batching by length needs the whole workload,
    # so the engine cannot start before the last arrival ---
    beng = ServingEngine(model, params, max_len=max_len)
    beng.generate(reqs[:1], max_batch=8)        # warm compile caches
    t0 = time.perf_counter()
    time.sleep(max(arrivals))                   # waiting for arrivals
    bc = beng.generate(reqs, max_batch=8)
    bwall = time.perf_counter() - t0
    brep = throughput_report(bc, wall_s=bwall,
                             prefill_s=beng.last_phase_s["prefill_s"],
                             decode_s=bwall - beng.last_phase_s["prefill_s"])

    # --- continuous engine: admission interleaves with decode ---
    ceng = ContinuousServingEngine(model, params, max_len=max_len,
                                   max_running=8, page_size=8)
    ceng.generate(reqs[:1])                     # warm compile caches
    ceng2 = ContinuousServingEngine(model, params, max_len=max_len,
                                    max_running=8, page_size=8)
    t0 = time.perf_counter()
    cc = ceng2.generate(reqs, arrivals=arrivals)
    cwall = time.perf_counter() - t0
    crep = throughput_report(cc, wall_s=cwall,
                             prefill_s=ceng2.last_phase_s["prefill_s"],
                             decode_s=cwall - ceng2.last_phase_s["prefill_s"])

    speedup = crep["decode_tok_per_s"] / max(brep["decode_tok_per_s"], 1e-9)
    return [
        ("serving_cb.bucket.decode_toks_per_s", bwall * 1e6,
         f"{brep['decode_tok_per_s']:.1f}"),
        ("serving_cb.continuous.decode_toks_per_s", cwall * 1e6,
         f"{crep['decode_tok_per_s']:.1f}"),
        ("serving_cb.continuous.preemptions", 0.0,
         f"{ceng2.scheduler.n_preemptions}"),
        ("serving_cb.speedup", 0.0, f"{speedup:.2f}x"),
    ]


def all_rows() -> List[Row]:
    return serving_cb_rows()


if __name__ == "__main__":
    for name, us, derived in all_rows():
        print(f"{name},{us:.1f},{derived}")
