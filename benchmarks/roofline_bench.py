"""Roofline summary rows from the saved dry-run sweeps.

Reads ``experiments/dryrun_single_pod.json`` (written by
``python -m repro.launch.dryrun --all``) and emits one row per
(arch × shape) with the dominant term — the benchmark counterpart of
EXPERIMENTS.md §Roofline.  Skipped gracefully when the sweep artifact
is absent.
"""

from __future__ import annotations

import json
import os
from typing import List, Tuple

Row = Tuple[str, float, str]

_PATH = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "dryrun_single_pod.json")


def all_rows() -> List[Row]:
    if not os.path.exists(_PATH):
        return [("roofline.sweep", 0.0, "missing (run repro.launch.dryrun)")]
    with open(_PATH) as f:
        data = json.load(f)
    rows: List[Row] = []
    for r in data.get("results", []):
        us = r.get("compile_s", 0.0) * 1e6
        dom = r["dominant"]
        t_dom = {"compute": r["t_compute"], "memory": r["t_memory"],
                 "collective": r["t_collective"]}[dom]
        rows.append((f"roofline.{r['arch']}.{r['shape']}", us,
                     f"dom={dom}:{t_dom:.3e}s,useful="
                     f"{r['useful_flops_ratio']:.2f}"))
    for f_ in data.get("failures", []):
        rows.append((f"roofline.{f_['arch']}.{f_['shape']}", 0.0, "FAILED"))
    return rows
