"""Kernel micro-benchmarks: Q4_0 GEMM + decode attention vs refs.

On this CPU container the Pallas kernels run in interpret mode (slow,
correctness-only), so wall-times compare the jnp reference paths and
report the kernels' interpret-mode overhead separately; the derived
column carries the analytic TPU-side expectation (bytes moved /
HBM bandwidth) for the same shape.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ops import gqa_decode_attention, q4_matmul
from repro.launch.mesh import HBM_BW
from repro.quant.q4_0 import quantize, quantized_bytes

Row = Tuple[str, float, str]


def _time_it(fn, *args, iters=5) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def q4_gemm_rows() -> List[Row]:
    rows: List[Row] = []
    for (M, K, N) in [(1, 2048, 2048), (8, 2048, 2048), (1, 4096, 11008)]:
        w = (np.random.default_rng(0).normal(size=(K, N)) * 0.1
             ).astype(np.float32)
        x = jnp.asarray(np.random.default_rng(1).normal(size=(M, K)),
                        jnp.float32)
        p, s = quantize(w)
        us = _time_it(lambda a, b, c: q4_matmul(a, b, c, impl="ref"),
                      x, p, s)
        tpu_us = quantized_bytes((K, N)) / HBM_BW * 1e6
        rows.append((f"q4_gemm.ref.M{M}K{K}N{N}", us,
                     f"tpu_hbm_bound_us={tpu_us:.1f}"))
    return rows


def decode_attn_rows() -> List[Row]:
    rows: List[Row] = []
    for (B, S, Hq, Hkv, D) in [(1, 4096, 32, 8, 128), (8, 2048, 16, 8, 128)]:
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(B, 1, Hq, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
        us = _time_it(
            lambda a, b, c: gqa_decode_attention(a, b, c, S, impl="ref"),
            q, k, v)
        cache_bytes = 2 * B * S * Hkv * D * 2  # bf16 k+v on TPU
        rows.append((f"decode_attn.ref.B{B}S{S}", us,
                     f"tpu_hbm_bound_us={cache_bytes / HBM_BW * 1e6:.1f}"))
    return rows


def interpret_overhead_rows() -> List[Row]:
    """Pallas interpret-mode sanity timing on one small shape."""
    from repro.kernels.q4_gemm import q4_gemm
    w = (np.random.default_rng(0).normal(size=(256, 256)) * 0.1
         ).astype(np.float32)
    p, s = quantize(w)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1, 256)),
                    jnp.float32)
    t0 = time.perf_counter()
    out = q4_gemm(x, p, s, block_n=128, block_k=128, interpret=True)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) * 1e6
    return [("q4_gemm.pallas_interpret.M1K256N256", us,
             "correctness-mode")]


def rglru_rows() -> List[Row]:
    from repro.kernels.ops import rglru_linear_scan
    rng = np.random.default_rng(0)
    B, T, W = 1, 2048, 2560          # recurrentgemma-2b prefill shape
    a = jnp.asarray(rng.uniform(0.9, 0.999, (B, T, W)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(B, T, W)) * 0.1, jnp.float32)
    us = _time_it(lambda x, y: rglru_linear_scan(x, y, impl="ref"), a, u)
    hbm_us = 3 * B * T * W * 4 / HBM_BW * 1e6   # read a,u + write h
    return [(f"rglru_scan.ref.B{B}T{T}W{W}", us,
             f"tpu_hbm_bound_us={hbm_us:.1f}")]


def all_rows() -> List[Row]:
    return (q4_gemm_rows() + decode_attn_rows() + rglru_rows()
            + interpret_overhead_rows())
