"""NUMA cost-model explorer: sweep placements/hardware (paper §3-§4).

Reproduces the paper's figures and then goes beyond them: what happens
with 8 NUMA nodes? With HBM-class local bandwidth? With a bigger model?
The model is mechanistic, so these extrapolations are napkin math made
executable — the same numbers drive EXPERIMENTS.md.

Run:  PYTHONPATH=src python examples/numa_sweep.py
"""

import dataclasses


from repro.core.numa import (KUNPENG_920_4NODE, QWEN3_4B, ModelTraffic,
                             decode_throughput, headline_gain)


def show_curve(label, topo, model, nodes, policy, sync="sync_b"):
    per_node = (6, 12, 24, 48)
    vals = [decode_throughput(model, topo, t * nodes, nodes, policy,
                              sync_mode=sync).tokens_per_s
            for t in per_node]
    print(f"  {label:42s} {[round(v, 1) for v in vals]} tok/s")


def main() -> None:
    topo = KUNPENG_920_4NODE
    print("== paper platform (4 x 48 Kunpeng-920, Table 1 bandwidths)")
    show_curve("llama.cpp --numa distribute (4 nodes)", topo, QWEN3_4B, 4,
               "llama_uma_distribute")
    show_curve("ArcLight cross-NUMA TP      (4 nodes)", topo, QWEN3_4B, 4,
               "arclight_numa_tp")
    show_curve("ArcLight TP, Sync A         (4 nodes)", topo, QWEN3_4B, 4,
               "arclight_numa_tp", sync="sync_a")
    print(f"  headline gain: {100 * headline_gain():.1f}%")

    print("\n== beyond the paper: 8 NUMA nodes (same per-node hw)")
    topo8 = dataclasses.replace(topo, n_nodes=8)
    show_curve("llama.cpp distribute (8 nodes)", topo8, QWEN3_4B, 8,
               "llama_uma_distribute")
    show_curve("ArcLight TP          (8 nodes)", topo8, QWEN3_4B, 8,
               "arclight_numa_tp")
    g8 = (decode_throughput(QWEN3_4B, topo8, 384, 8,
                            "arclight_numa_tp").tokens_per_s
          / decode_throughput(QWEN3_4B, topo8, 384, 8,
                              "llama_uma_distribute").tokens_per_s - 1)
    print(f"  TP gain at 8 nodes: {100 * g8:.1f}% "
          f"(remote traffic grows with (N-1)/N -> gain rises)")

    print("\n== beyond the paper: bigger model (Qwen2-72B class, Q4_0)")
    big = ModelTraffic(name="qwen2-72b", n_layers=80, d_model=8192,
                       d_ff=29568, n_heads=64, n_kv_heads=8,
                       vocab=152064)
    show_curve("ArcLight TP (4 nodes)", topo, big, 4, "arclight_numa_tp")
    print(f"  weight bytes: {big.weight_bytes / 1e9:.1f} GB -> decode is"
          " purely bandwidth-bound; TP gain tracks the remote/local gap")

    print("\n== sensitivity: what if remote bandwidth doubled?")
    fast = dataclasses.replace(topo, remote_bw=48.0)
    for t, label in [(topo, "paper remote 24 GB/s"),
                     (fast, "2x remote 48 GB/s")]:
        g = (decode_throughput(QWEN3_4B, t, 192, 4,
                               "arclight_numa_tp").tokens_per_s
             / decode_throughput(QWEN3_4B, t, 192, 4,
                                 "llama_uma_distribute").tokens_per_s - 1)
        print(f"  {label:24s} TP gain {100 * g:5.1f}%")
    print("  -> the technique's win shrinks as the NUMA gap closes, "
          "exactly the paper's premise")


if __name__ == "__main__":
    main()
