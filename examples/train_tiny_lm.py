"""End-to-end training driver (deliverable b).

Trains a GPT-style dense LM on the synthetic packed corpus with the
full production pipeline: data pipeline -> model zoo -> AdamW + cosine
-> checkpointing -> eval.  The default config is a genuine ~100M-param
model trained for a few hundred steps; on this CPU container that takes
a while, so ``--preset small`` (the default) runs a reduced variant
that finishes in minutes and ``--preset 100m`` selects the full one
(identical code path).

Run:  PYTHONPATH=src python examples/train_tiny_lm.py [--preset 100m]
      [--steps N]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.data.pipeline import PackedLMDataset
from repro.models import ModelConfig, build_model
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.loop import make_eval_step, train
from repro.training.optimizer import AdamWConfig


PRESETS = {
    # ~100M params: 12L x 768 (GPT-2 small shape), byte-level vocab
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                 d_ff=3072, seq_len=512, batch=16, steps=300),
    "small": dict(n_layers=4, d_model=256, n_heads=8, n_kv_heads=4,
                  d_ff=1024, seq_len=128, batch=8, steps=120),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="small")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt", default="/tmp/repro_tiny_lm")
    args = ap.parse_args()
    p = PRESETS[args.preset]
    steps = args.steps or p["steps"]

    cfg = ModelConfig(
        name=f"tiny-lm-{args.preset}", arch_type="dense",
        n_layers=p["n_layers"], d_model=p["d_model"],
        n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"],
        vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    print(f"model: {cfg.name}  params={cfg.param_count() / 1e6:.1f}M")

    params = model.init(jax.random.PRNGKey(0))
    ds = PackedLMDataset(seq_len=p["seq_len"], n_docs=4000,
                         vocab_size=cfg.vocab_size)
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=max(steps // 20, 5),
                          total_steps=steps)

    def log(step, m):
        print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}  "
              f"{m['elapsed_s']:.1f}s")

    params, opt_state, hist = train(model, params,
                                    ds.batches(p["batch"]), opt_cfg,
                                    steps=steps, log_every=10,
                                    callback=log)

    path = save_checkpoint(args.ckpt, steps, {"params": params})
    print(f"checkpoint: {path}")

    # eval on held-out rows (different sampling seed)
    eval_step = jax.jit(make_eval_step(model))
    batches = ds.batches(p["batch"], seed=999)
    losses = [float(eval_step(params, next(batches))["loss"])
              for _ in range(5)]
    print(f"eval loss: {sum(losses) / len(losses):.4f} "
          f"(train started at {hist[0]['loss']:.3f})")

    # restore check
    step, out = load_checkpoint(args.ckpt, {"params": params})
    print(f"restored step {step}; "
          f"loss drop {hist[0]['loss'] - hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
