"""Quickstart: the ArcLight-in-JAX stack in five minutes (CPU).

1. Build the faithful ArcLight engine (graph builder + per-node memory
   pools + thread groups) and run a cross-NUMA TP MLP.
2. Reproduce the paper's headline numbers from the calibrated NUMA
   cost model.
3. Build an assigned architecture (reduced) and generate text with the
   serving frontend.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Engine, EngineConfig, build_tp_mlp_graph,
                        split_mlp_weights)
from repro.core.numa import (async_gain_tokens_per_s, fig11_multi_node,
                             headline_gain)
from repro.configs import get_config
from repro.models import build_model, reduced_config
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import SamplingParams


def part1_engine():
    print("== 1. ArcLight engine: cross-NUMA TP MLP (paper §2, §3)")
    d, f, t, nodes = 64, 256, 4, 4
    rng = np.random.default_rng(0)
    w = {"w_gate": (rng.normal(size=(f, d)) * .1).astype(np.float32),
         "w_up": (rng.normal(size=(f, d)) * .1).astype(np.float32),
         "w_down": (rng.normal(size=(d, f)) * .1).astype(np.float32)}
    x = rng.normal(size=(d, t)).astype(np.float32)

    eng = Engine(EngineConfig(n_nodes=nodes, n_threads=8))
    _, zout = build_tp_mlp_graph(eng, d, f, t)
    rep = eng.execute({"x": x}, split_mlp_weights(w, nodes))
    print(f"   graph nodes: {rep.node_count}, barriers: {rep.barrier_count}")
    print(f"   per-NUMA-node bytes: {rep.per_node_bytes}")
    ref = np.asarray(w["w_down"] @ (
        np.asarray(jax.nn.silu(w["w_gate"] @ x)) * (w["w_up"] @ x)))
    err = np.abs(np.asarray(rep.outputs[zout.single.name]) - ref).max()
    print(f"   TP output matches single-node reference: max err {err:.2e}")


def part2_cost_model():
    print("\n== 2. Paper claims from the calibrated cost model (§4)")
    print(f"   4-node TP gain vs llama.cpp-distribute: "
          f"{100 * headline_gain():.1f}%  (paper: up to 46%)")
    print(f"   async subgraph gain: {async_gain_tokens_per_s():.1f} tok/s "
          f"(paper: ~5)")
    f11 = fig11_multi_node()
    print(f"   4-node decode curves (threads/node 6..48):")
    print(f"     llama.cpp   {[round(x, 1) for x in f11['llama.cpp'][4]]}")
    print(f"     arclight-tp {[round(x, 1) for x in f11['arclight_tp'][4]]}")


def part3_serve():
    print("\n== 3. Serve a reduced assigned arch (qwen3 family)")
    cfg = reduced_config(get_config("qwen3-1.7b"))
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_len=64)
    reqs = [Request(uid=i, prompt=[1, 2, 3, 4, 5],
                    sampling=SamplingParams(max_new_tokens=8,
                                            temperature=0.8, top_k=40))
            for i in range(4)]
    comps = eng.generate(reqs, max_batch=4)
    for c in comps:
        print(f"   req {c.uid}: {c.tokens}")


if __name__ == "__main__":
    part1_engine()
    part2_cost_model()
    part3_serve()
