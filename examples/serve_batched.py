"""Serving driver (deliverable b): batched requests through the engine.

Trains a small model briefly so outputs aren't pure noise, then serves
a mixed batch of requests (different lengths, temperatures and
max-token budgets) through the length-bucketing scheduler, printing a
throughput report — the paper's §4 measurement protocol at CPU scale.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import jax
import jax.numpy as jnp

from repro.data.pipeline import PackedLMDataset
from repro.data.tokenizer import ByteTokenizer
from repro.models import ModelConfig, build_model
from repro.serving.engine import Request, ServingEngine, throughput_report
from repro.serving.sampler import SamplingParams
from repro.training.loop import train
from repro.training.optimizer import AdamWConfig


def main() -> None:
    tok = ByteTokenizer()
    cfg = ModelConfig(name="serve-demo", arch_type="dense", n_layers=4,
                      d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                      vocab_size=tok.vocab_size, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print("warm-up training (80 steps) ...")
    ds = PackedLMDataset(seq_len=96, n_docs=2000,
                         vocab_size=cfg.vocab_size)
    params, _, _ = train(model, params, ds.batches(8),
                         AdamWConfig(lr=2e-3, warmup_steps=10,
                                     total_steps=80),
                         steps=80, log_every=40)

    eng = ServingEngine(model, params, max_len=192)
    prompts = [
        "the scheduler binds",
        "a numa node streams",
        "the kv cache",
        "one thread gathers",
        "the memory pool allocates",
        "the gather op",
    ]
    reqs = []
    for i, p in enumerate(prompts):
        reqs.append(Request(
            uid=i, prompt=tok.encode(p),
            sampling=SamplingParams(
                temperature=0.0 if i % 2 == 0 else 0.7,
                top_k=0 if i % 2 == 0 else 20,
                max_new_tokens=24 + 8 * (i % 3))))
    comps = eng.generate(reqs, max_batch=4)
    for c, p in zip(comps, prompts):
        print(f"[{c.uid}] {p!r} -> {tok.decode(c.tokens)!r}")
    rep = throughput_report(comps)
    print("\nthroughput report:")
    for k, v in rep.items():
        print(f"  {k}: {v:.2f}" if isinstance(v, float) else
              f"  {k}: {v}")


if __name__ == "__main__":
    main()
