"""SLO-aware overload protection (PR: deadlines, priority classes,
load shedding, circuit breaking).

Scheduler level (no model): priority-ordered admission that degrades to
byte-identical FCFS under uniform priorities, batch-first victim
picking, and deadline expiry at every awkward moment — queued,
mid-prefill-chunk, mid-decode, holding a queued CoW copy, holding
shared prefix pages — with the pool draining clean each time.

Engine level: ``EngineCore.step`` reports expired uids and counts
``scheduler.expired``; ``AsyncEngine`` fails the handle with a chained
``DeadlineExceededError`` (slow lane).

Edge level: the HTTP front-end's bounded admission (429 + Retry-After +
structured error body), SLO field parsing/propagation, and the
router's per-replica circuit breaker + deadline-aware retry budget.

Spec satellite: the per-sequence acceptance auto-off
(``spec.note_accept`` / ``lookahead_for``).
"""

import json
import time

import jax
import jax.numpy as jnp
import pytest

from repro.models import ModelConfig, build_model
from repro.obs import MetricsRegistry
from repro.serving import (AsyncEngine, ContinuousScheduler,
                           DeadlineExceededError, EngineCore, KVCachePool,
                           KVPoolConfig, Request, RequestState, Router,
                           RouterError, SamplingParams, VirtualClock,
                           WorkerDiedError)
from repro.serving.scheduler import PRIORITY_RANK
from repro.serving.spec import lookahead_for, note_accept


def _pool(n_pages=17, page_size=4):
    return KVCachePool(KVPoolConfig(
        n_pages=n_pages, page_size=page_size, n_layers=2, n_kv_heads=2,
        head_dim=8, dtype_bytes=4))


def _req(uid, prompt, *, priority="interactive", deadline_s=None,
         max_new=4):
    return Request(uid=uid, prompt=list(prompt),
                   sampling=SamplingParams(max_new_tokens=max_new),
                   priority=priority, deadline_s=deadline_s)


def _sched(pool=None, *, max_running=2, max_len=64, registry=None,
           **kw):
    return ContinuousScheduler(pool or _pool(), max_running=max_running,
                               max_len=max_len, registry=registry, **kw)


# ----------------------------------------------------------------------
# priority classes
# ----------------------------------------------------------------------
class TestPriorityAdmission:
    def test_rank_order(self):
        assert PRIORITY_RANK["interactive"] < PRIORITY_RANK["batch"]

    def test_unknown_priority_rejected(self):
        with pytest.raises(ValueError, match="priority"):
            _sched().submit(_req(0, [1, 2], priority="bulk"))

    def test_interactive_admits_ahead_of_earlier_batch(self):
        sched = _sched(max_running=2)
        sched.submit(_req(0, [1, 2, 3], priority="batch"), arrival=0.0)
        sched.submit(_req(1, [4, 5, 6], priority="batch"), arrival=1.0)
        sched.submit(_req(2, [7, 8, 9]), arrival=2.0)   # interactive
        sched.step(now=2.0)
        admitted = {s.uid for s in sched.running.values()}
        assert admitted == {2, 0}       # interactive jumps the queue
        assert [s.uid for s in sched.waiting] == [1]

    def test_uniform_priorities_degrade_to_fcfs(self):
        # same-class traffic must admit in exact arrival order — the
        # pre-SLO byte-parity contract
        for prio in ("interactive", "batch"):
            sched = _sched(max_running=3)
            for uid, t in ((0, 0.0), (1, 0.5), (2, 1.0)):
                sched.submit(_req(uid, [uid + 1, 2, 3], priority=prio),
                             arrival=t)
            assert [s.uid for s in sched.waiting] == [0, 1, 2]
            sched.step(now=1.0)
            assert sorted(sched.running) == [0, 1, 2]
            assert [sched.running[s].uid for s in sorted(sched.running)] \
                == [0, 1, 2]

    def test_future_interactive_does_not_block_arrived_batch(self):
        sched = _sched(max_running=1)
        sched.submit(_req(0, [1, 2], priority="batch"), arrival=0.0)
        sched.submit(_req(1, [3, 4]), arrival=10.0)     # not here yet
        sched.step(now=0.0)
        assert {s.uid for s in sched.running.values()} == {0}

    def test_victim_is_batch_before_interactive(self):
        sched = _sched(max_running=2)
        sched.submit(_req(0, [1, 2, 3], priority="batch"), arrival=0.0)
        sched.submit(_req(1, [4, 5, 6]), arrival=1.0)   # interactive
        sched.step(now=1.0)
        inter = next(s for s in sched.running.values() if s.uid == 1)
        victim = sched._pick_victim(exclude=inter)
        assert victim.uid == 0          # batch loses despite older arrival


# ----------------------------------------------------------------------
# deadline expiry at awkward moments
# ----------------------------------------------------------------------
class TestDeadlineExpiry:
    def test_queued_request_shed_before_any_prefill(self):
        reg = MetricsRegistry()
        sched = _sched(max_running=1, registry=reg)
        sched.submit(_req(0, [1] * 8, max_new=50), arrival=0.0)
        sched.submit(_req(1, [2, 3, 4], deadline_s=1.0), arrival=0.0)
        plan = sched.step(now=0.0)
        assert not plan.expired
        plan = sched.step(now=2.0)      # budget gone while queued
        assert [s.uid for s in plan.expired] == [1]
        assert not sched.waiting
        assert reg.get("scheduler.expired").value() == 1

    def test_expiry_mid_prefill_chunk_drains_pool(self):
        pool = _pool()
        free0 = pool.n_free()
        sched = _sched(pool, max_running=1, prefill_chunk=2)
        seq = sched.submit(_req(0, [1, 2, 3, 4, 5, 6, 7, 8],
                                deadline_s=5.0), arrival=0.0)
        plan = sched.step(now=0.0)
        assert plan.prefills == [seq] and sched.chunk_for(seq) == 2
        seq.n_prefilled += 2            # engine ran one chunk
        plan = sched.step(now=1.0)      # still mid-prefill
        assert plan.prefills == [seq] and seq.is_prefilling
        seq.n_prefilled += 2
        plan = sched.step(now=6.0)      # budget gone mid-prompt
        assert plan.expired == [seq] and seq.slot == -1
        assert not sched.running and not sched.waiting
        assert pool.n_free() == free0   # partial prompt fully released

    def test_expiry_mid_decode_frees_slot_and_pages(self):
        pool = _pool()
        free0 = pool.n_free()
        sched = _sched(pool, max_running=1)
        seq = sched.submit(_req(0, [1, 2, 3, 4], deadline_s=2.0,
                                max_new=50), arrival=0.0)
        sched.step(now=0.0)
        seq.n_prefilled = seq.prefill_target    # prefill done
        seq.generated.append(7)                 # one decoded token
        plan = sched.step(now=1.0)
        assert plan.decodes == [seq]
        plan = sched.step(now=3.0)
        assert plan.expired == [seq]
        assert sched._free_slots and not sched.running
        assert pool.n_free() == free0

    def test_expiry_with_queued_cow_copy_drops_it(self):
        # a mid-page prefix divergence queues a pending CoW copy at
        # admission; shedding the sequence before the engine drains the
        # copy must drop it with the pages — no dangling copy into a
        # freed page
        pool = _pool(page_size=4)
        sched = _sched(pool, max_running=1)
        a = sched.submit(_req(0, [1, 2, 3, 4, 5, 6, 7, 8]), arrival=0.0)
        sched.step(now=0.0)
        a.n_prefilled = a.prefill_target
        pool.register_prefix(a.uid, a.request.prompt)
        sched.cancel(a)                 # pages retire to the retained LRU
        free0 = pool.n_free()

        # same two leading blocks, divergence INSIDE the second one ->
        # match = full page + cow_src on the partial tail
        sched.submit(_req(1, [1, 2, 3, 4, 5, 6, 9, 9], deadline_s=1.0),
                     arrival=0.0)
        sched.step(now=0.0)
        assert pool.pending_copies      # CoW clone of the partial page
        plan = sched.step(now=2.0)
        assert [s.uid for s in plan.expired] == [1]
        assert pool.pending_copies == []
        assert pool.n_free() == free0

    def test_expiry_holding_shared_prefix_pages(self):
        # the expired sequence only drops ITS references: the survivor
        # sharing the prefix keeps its pages
        pool = _pool(page_size=4)
        sched = _sched(pool, max_running=2)
        # 5-token prompt: a's decode writes land in its private second
        # page, so the shared full page is never CoW-cloned from under
        # this test's refcount assertions
        a = sched.submit(_req(0, [1, 2, 3, 4, 5], max_new=50),
                         arrival=0.0)
        sched.step(now=0.0)
        a.n_prefilled = a.prefill_target
        pool.register_prefix(a.uid, a.request.prompt)
        b = sched.submit(_req(1, [1, 2, 3, 4, 9, 9], deadline_s=1.0),
                         arrival=0.0)
        sched.step(now=0.0)
        shared = pool.block_table(a.uid)[0]
        assert shared in pool.block_table(b.uid)
        assert pool.refcount(shared) == 2
        plan = sched.step(now=2.0)
        assert plan.expired == [b]
        assert pool.refcount(shared) == 1       # a's reference survives
        assert a.slot >= 0 and sched.running    # a untouched
        assert sched.cancel(a)                  # and still tears down clean

    def test_no_deadlines_means_no_expiry_scan(self):
        sched = _sched()
        sched.submit(_req(0, [1, 2, 3]), arrival=0.0)
        assert not sched._has_deadlines
        plan = sched.step(now=1e9)
        assert not plan.expired and sched.running


# ----------------------------------------------------------------------
# engine core + async engine
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


class TestEngineDeadlines:
    def test_step_reports_expired_and_counts(self, tiny):
        model, params = tiny
        core = EngineCore(model, params, max_len=32, max_running=2,
                          page_size=4, clock=VirtualClock())
        core.submit(_req(0, [1, 2, 3], deadline_s=0.5, max_new=3),
                    arrival=0.0)
        core.submit(_req(1, [4, 5, 6], max_new=3), arrival=0.0)
        expired, finished = [], []
        now = 1.0                       # past uid 0's budget already
        while core.has_work():
            res = core.step(now=now)
            expired += res.expired
            finished += res.finished
            now += 0.01
        assert expired == [0]
        assert [c.uid for c in finished] == [1]
        assert core.registry.get("scheduler.expired").value() == 1

    def test_uniform_priority_token_parity(self, tiny):
        # marking everything batch must not change one sampled token
        model, params = tiny

        def run(priority):
            core = EngineCore(model, params, max_len=48, max_running=2,
                              page_size=4, clock=VirtualClock())
            for uid, p in enumerate(([1, 2, 3, 4, 5], [7, 8, 9],
                                     [9, 9, 2, 1])):
                core.submit(_req(uid, p, priority=priority, max_new=5))
            out = {}
            while core.has_work():
                for c in core.step().finished:
                    out[c.uid] = list(c.tokens)
            return out

        assert run("interactive") == run("batch")

    @pytest.mark.slow
    def test_async_handle_fails_with_deadline_cause(self, tiny):
        model, params = tiny
        eng = AsyncEngine(model, params, max_len=32, max_running=2,
                          page_size=4)
        try:
            h = eng.submit(_req(0, [1, 2, 3], deadline_s=1e-9,
                                max_new=8))
            t0 = time.time()
            while not h.done and time.time() - t0 < 10:
                time.sleep(0.005)
            assert h.state is RequestState.FAILED
            assert isinstance(h.error, DeadlineExceededError)
            with pytest.raises(Exception) as ei:
                eng.result(h, timeout=1)
            assert isinstance(ei.value.__cause__, DeadlineExceededError)
        finally:
            eng.shutdown()


# ----------------------------------------------------------------------
# HTTP edge: bounded admission + SLO field propagation
# ----------------------------------------------------------------------
class TestHttpOverload:
    def _fe(self, backend, **kw):
        from repro.serving.http import HttpFrontend
        return HttpFrontend(backend, **kw).start()

    def test_inflight_cap_sheds_with_429(self):
        from test_http_serving import FakeBackend, _post

        fe = self._fe(FakeBackend(), max_inflight=1, retry_after_s=2.5)
        try:
            assert fe._admit()          # occupy the only slot
            conn, resp = _post(fe, {"prompt": [1, 2], "max_tokens": 1})
            assert resp.status == 429
            assert resp.getheader("Retry-After") == "2.5"
            doc = json.loads(resp.read())
            assert doc["error"]["type"] == "Overloaded"
            assert doc["error"]["retryable"] is True
            conn.close()
            fe._release()
            conn, resp = _post(fe, {"prompt": [1, 2], "max_tokens": 1})
            assert resp.status == 200   # slot free again -> serves
            conn.close()
            assert fe.registry.get("http.shed").value() == 1
        finally:
            fe.close()

    def test_queue_depth_cap_sheds(self):
        from test_http_serving import FakeBackend, _post

        backend = FakeBackend()
        g = backend.registry.gauge("scheduler.queue_depth", "t").labels()
        g.set(3.0)                      # scheduler already backed up
        fe = self._fe(backend, max_queue_depth=3)
        try:
            conn, resp = _post(fe, {"prompt": [1, 2], "max_tokens": 1})
            assert resp.status == 429
            conn.close()
            g.set(0.0)
            conn, resp = _post(fe, {"prompt": [1, 2], "max_tokens": 1})
            assert resp.status == 200
            conn.close()
        finally:
            fe.close()

    def test_slo_fields_reach_the_backend_request(self):
        from test_http_serving import FakeBackend, _post

        class Recording(FakeBackend):
            def submit(self, request, *, on_token=None):
                self.seen = request
                return super().submit(request, on_token=on_token)

        backend = Recording()
        fe = self._fe(backend)
        try:
            conn, resp = _post(fe, {"prompt": [1, 2, 3], "max_tokens": 2,
                                    "priority": "batch",
                                    "deadline_ms": 250.0})
            assert resp.status == 200
            conn.close()
        finally:
            fe.close()
        assert backend.seen.priority == "batch"
        assert backend.seen.deadline_s == pytest.approx(0.25)

    def test_slo_headers_apply_when_body_is_silent(self):
        import http.client

        from test_http_serving import FakeBackend

        class Recording(FakeBackend):
            def submit(self, request, *, on_token=None):
                self.seen = request
                return super().submit(request, on_token=on_token)

        backend = Recording()
        fe = self._fe(backend)
        try:
            conn = http.client.HTTPConnection(fe.host, fe.port, timeout=5)
            conn.request("POST", "/v1/completions",
                         json.dumps({"prompt": [1, 2], "max_tokens": 1}),
                         {"Content-Type": "application/json",
                          "X-Priority": "batch",
                          "X-Deadline-Ms": "500"})
            assert conn.getresponse().status == 200
            conn.close()
        finally:
            fe.close()
        assert backend.seen.priority == "batch"
        assert backend.seen.deadline_s == pytest.approx(0.5)

    def test_bad_slo_fields_are_400(self):
        from test_http_serving import FakeBackend, _post

        fe = self._fe(FakeBackend())
        try:
            for body in ({"prompt": [1], "priority": "bulk"},
                         {"prompt": [1], "deadline_ms": 0},
                         {"prompt": [1], "deadline_ms": -5}):
                conn, resp = _post(fe, body)
                assert resp.status == 400
                doc = json.loads(resp.read())
                assert doc["error"]["retryable"] is False
                conn.close()
        finally:
            fe.close()

    def test_error_payload_retryability(self):
        from repro.serving.http import (BadRequest, Overloaded,
                                        error_payload)

        assert error_payload(Overloaded("x"))["error"]["retryable"]
        assert not error_payload(BadRequest("x"))["error"]["retryable"]
        wrapped = RuntimeError("boom")
        wrapped.__cause__ = DeadlineExceededError("late")
        assert not error_payload(wrapped)["error"]["retryable"]
        assert error_payload(TimeoutError("slow"))["error"]["retryable"]


# ----------------------------------------------------------------------
# router: circuit breaker + deadline-aware retry budget
# ----------------------------------------------------------------------
KEYED = list(range(1, 33))


class LossyWorker:
    """Streams one token short of what its done frame reports — the
    router's lossy-stream check fails the request and records a
    worker-attributable failure.  ``heal()`` makes it honest again."""

    def __init__(self):
        self.lossy = True
        self.probed = 0

    def alive(self):
        return True

    def describe(self):
        return "lossy"

    def healthy(self, *, timeout=2.0):
        self.probed += 1
        return True

    def stream_completion(self, body, *, timeout):
        sent = 0
        for t in (21, 22, 23)[:int(body["max_tokens"])]:
            if self.lossy and sent >= 1:
                break                   # silently drop the tail
            sent += 1
            yield {"index": 0, "text": "", "token": t}
        yield {"done": {"prompt_tokens": len(body["prompt"]),
                        "completion_tokens": int(body["max_tokens"]),
                        "finish_reason": "length"}}


class SlowDeathWorker:
    def __init__(self, delay=0.1):
        self.delay = delay
        self.bodies = []

    def alive(self):
        return False

    def describe(self):
        return "slow-death"

    def stream_completion(self, body, *, timeout):
        self.bodies.append(dict(body))
        time.sleep(self.delay)
        raise WorkerDiedError("injected slow death")
        yield  # pragma: no cover — makes this a generator


class TestRouterBreaker:
    def test_breaker_opens_on_lossy_stream_and_probes_back(self):
        w = LossyWorker()
        r = Router({0: w}, page_size=16, breaker_threshold=1,
                   breaker_probation_s=0.05)
        with pytest.raises(RouterError) as ei:
            r.result(r.submit(_req(0, KEYED, max_new=3)), timeout=5)
        assert "frames arrived" in str(ei.value.__cause__)
        assert r.registry.get("router.breaker_open").value() == 1
        assert r.health()["replicas"]["0"]["breaker_open"]
        assert r.health()["live"] == 0

        # breaker open, probation not elapsed: nothing to serve with
        with pytest.raises(RouterError) as ei:
            r.result(r.submit(_req(0, KEYED, max_new=3)), timeout=5)
        assert "breaker-open" in str(ei.value.__cause__)

        w.lossy = False                 # the replica "heals"
        time.sleep(0.06)                # probation elapses
        comp = r.result(r.submit(_req(0, KEYED, max_new=3)), timeout=5)
        assert comp.tokens == [21, 22, 23]
        assert w.probed >= 1
        assert r.registry.get("router.breaker_probes").value() >= 1
        assert r.registry.get("router.breaker_closed").value() == 1
        assert not r.health()["replicas"]["0"]["breaker_open"]
        r.shutdown()

    def test_success_resets_the_failure_streak(self):
        w = LossyWorker()
        r = Router({0: w}, page_size=16, breaker_threshold=2,
                   breaker_probation_s=10.0)
        with pytest.raises(RouterError):
            r.result(r.submit(_req(0, KEYED, max_new=3)), timeout=5)
        w.lossy = False                 # one good request in between
        r.result(r.submit(_req(0, KEYED, max_new=3)), timeout=5)
        w.lossy = True
        with pytest.raises(RouterError):
            r.result(r.submit(_req(0, KEYED, max_new=3)), timeout=5)
        # two failures total, but never two CONSECUTIVE ones
        assert r.registry.get("router.breaker_open").value() == 0
        r.shutdown()

    def test_breaker_threshold_validated(self):
        with pytest.raises(ValueError, match="breaker_threshold"):
            Router({0: LossyWorker()}, page_size=16, breaker_threshold=0)


class TestRouterDeadlines:
    def test_slo_fields_ride_the_wire(self):
        from test_router import FakeWorker

        w = FakeWorker([5, 6, 7])
        r = Router({0: w}, page_size=16)
        r.result(r.submit(_req(0, KEYED, priority="batch",
                               deadline_s=5.0, max_new=3)), timeout=5)
        body = w.bodies[0]
        assert body["priority"] == "batch"
        assert 0 < body["deadline_ms"] <= 5000.0
        r.result(r.submit(_req(0, KEYED, max_new=3)), timeout=5)
        assert "priority" not in w.bodies[1]        # defaults stay off
        assert "deadline_ms" not in w.bodies[1]     # the wire
        r.shutdown()

    def test_spent_budget_fails_before_dispatch(self):
        from test_router import FakeWorker

        w = FakeWorker()
        r = Router({0: w}, page_size=16)
        h = r.submit(_req(0, KEYED, deadline_s=1e-9, max_new=3))
        with pytest.raises(RouterError) as ei:
            r.result(h, timeout=5)
        assert isinstance(ei.value.__cause__, DeadlineExceededError)
        assert w.bodies == []           # never even dispatched
        r.shutdown()

    def test_no_retry_after_the_budget_is_spent(self):
        from test_router import FakeWorker

        from repro.serving.kv_pool import prefix_chain_key
        from repro.serving.router import AffinityRing

        first = AffinityRing([0, 1]).pick(
            prefix_chain_key(KEYED, 16, max_blocks=2))
        slow = SlowDeathWorker(delay=0.15)
        workers = {first: slow, 1 - first: FakeWorker([9, 9, 9])}
        r = Router(workers, page_size=16, max_retries=3)
        h = r.submit(_req(0, KEYED, deadline_s=0.05, max_new=3))
        with pytest.raises(RouterError):
            r.result(h, timeout=5)
        # a survivor existed and retries remained, but the budget was
        # spent — the router must not burn a second attempt
        assert h.n_retries == 0
        assert 0 < slow.bodies[0]["deadline_ms"] <= 50.0
        r.shutdown()


# ----------------------------------------------------------------------
# spec-decode acceptance auto-off (satellite)
# ----------------------------------------------------------------------
class TestSpecAutoOff:
    def _seq(self):
        from repro.serving.scheduler import Sequence
        return Sequence(request=_req(0, [1, 2, 3, 4], max_new=50))

    def test_collapsed_acceptance_trips_once(self):
        seq = self._seq()
        fired = [note_accept(seq, 0, 3) for _ in range(4)]
        assert fired == [False, False, False, True]
        assert seq.spec_disabled
        assert not note_accept(seq, 3, 3)       # latched: never re-fires
        seq.n_prefilled = seq.prefill_target = 4
        assert lookahead_for(seq, 3, max_len=64) == 0

    def test_healthy_acceptance_stays_enabled(self):
        seq = self._seq()
        assert not any(note_accept(seq, 3, 3) for _ in range(8))
        assert not seq.spec_disabled
        seq.n_prefilled = seq.prefill_target = 4
        assert lookahead_for(seq, 3, max_len=64) == 3

    def test_window_is_sliding(self):
        seq = self._seq()
        for _ in range(6):                      # old good steps age out
            note_accept(seq, 3, 3)
        fired = [note_accept(seq, 0, 3) for _ in range(4)]
        assert fired[-1] and seq.spec_disabled
