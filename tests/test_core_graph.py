"""ArcLight graph builder + scheduler (paper §2.5/2.6, A.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Engine, EngineConfig, ForwardGraph, GraphScheduler,
                        build_tp_mlp_graph, split_mlp_weights)
from repro.core.graph import GraphError
from repro.core.tensor import OpType, TensorBundle


def _mlp_weights(d, f, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w_gate": (rng.normal(size=(f, d)) * 0.1).astype(np.float32),
        "w_up": (rng.normal(size=(f, d)) * 0.1).astype(np.float32),
        "w_down": (rng.normal(size=(d, f)) * 0.1).astype(np.float32),
    }


def _ref_mlp(w, x):
    y = np.array(jax.nn.silu(w["w_gate"] @ x)) * (w["w_up"] @ x)
    return w["w_down"] @ y


class TestStaticList:
    def test_append_order_is_topological(self):
        g = ForwardGraph()
        x = g.input((4, 2), name="x")
        w = g.weight((8, 4), name="w")
        y = g.gemm(w, x)
        z = g.silu(y)
        assert g.verify_topological()
        assert g.node_count() == 2
        # successor indices chain
        assert g.order[0].next_index == 1

    def test_scatter_gather_modes(self):
        g = ForwardGraph(n_nodes=4)
        x = g.input((8, 2))
        xs = g.scatter(x, n=4)                 # scatter mode
        assert len(xs) == 4
        assert all(h.op is OpType.SCATTER for h in xs)
        ws = TensorBundle([g.weight((3, 8), node_id=i).single
                           for i in range(4)])
        ys = g.gemm(ws, xs)                    # parallel mode
        assert len(ys) == 4
        z = g.gather(ys, mode="concat", axis=0)  # gather mode
        assert z.single.shape == (12, 2)
        assert g.verify_topological()

    def test_gather_requires_parallel_bundle(self):
        g = ForwardGraph()
        x = g.input((4, 2))
        with pytest.raises(GraphError):
            g.gather(x)

    def test_scatter_axis_divisibility(self):
        g = ForwardGraph(n_nodes=3)
        x = g.input((8, 2))
        with pytest.raises(GraphError):
            g.scatter(x, n=3, axis=0)

    def test_bundle_single_enforcement(self):
        g = ForwardGraph(n_nodes=2)
        x = g.input((4, 2))
        xs = g.scatter(x, n=2)
        with pytest.raises(ValueError):
            _ = xs.single


class TestEngineExecution:
    @pytest.mark.parametrize("n_nodes", [1, 2, 4])
    def test_tp_mlp_matches_reference(self, n_nodes):
        d, f, t = 16, 32, 5
        w = _mlp_weights(d, f)
        x = np.random.default_rng(1).normal(size=(d, t)).astype(np.float32)
        eng = Engine(EngineConfig(n_nodes=n_nodes, n_threads=8))
        _, zout = build_tp_mlp_graph(eng, d, f, t)
        weights = dict(w) if n_nodes == 1 else split_mlp_weights(w, n_nodes)
        rep = eng.execute({"x": x}, weights)
        z = np.asarray(rep.outputs[zout.single.name])
        np.testing.assert_allclose(z, _ref_mlp(w, x), rtol=1e-4, atol=1e-5)

    def test_barrier_per_node(self):
        eng = Engine(EngineConfig(n_nodes=2, n_threads=4))
        _, _ = build_tp_mlp_graph(eng, 8, 16, 3)
        rep = eng.execute({"x": np.zeros((8, 3), np.float32)},
                          split_mlp_weights(_mlp_weights(8, 16), 2))
        # scheduler barriers once per node (§2.6)
        assert rep.barrier_count == rep.node_count

    def test_numa_memory_isolation(self):
        eng = Engine(EngineConfig(n_nodes=4, n_threads=8, numa=True))
        build_tp_mlp_graph(eng, 16, 32, 2)
        eng.plan()
        per_node = eng.memory.per_node_bytes()
        assert set(per_node) == {0, 1, 2, 3}
        # weight partitions spread evenly over node pools
        weights = eng.memory.weight_bytes()
        node_w = [v for k, v in weights.items() if "node" in k]
        assert len(set(node_w)) == 1

    def test_kv_cache_ops(self):
        g = ForwardGraph()
        g.kv_create("k0", (1, 8, 4))
        val = g.input((1, 2, 4), name="v")
        pos = g.input((), jnp.int32, name="p")
        g.kv_set("k0", val, pos)
        got = g.kv_get("k0")
        sched = GraphScheduler(g)
        out = sched.run({"v": np.ones((1, 2, 4), np.float32),
                         "p": np.asarray(3)}, {})
        cache = np.asarray(out[got.single.name])
        assert cache[0, 3:5].sum() == 8.0 and cache[0, :3].sum() == 0.0
