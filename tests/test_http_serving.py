"""HTTP serving front-end (PR: HTTP front-end + prefix-affinity router).

Fast lane: body parsing + SSE framing are checked against a fake
backend (no model, no threads beyond the server's own), including the
client-disconnect -> ``cancel()`` path and error-frame cause chaining.

Slow lane (real ``AsyncEngine`` on a tiny model): **wire parity** —
the SSE token frames read off the socket byte-compare against frames
rebuilt from ``AsyncEngine.stream()`` for the same seeded request —
and the mid-stream client disconnect drill: the engine must cancel the
abandoned request and its KV pages must return to the pool, asserted
through the ``/metrics.json`` scrape (not engine internals), because
that is the only view an operator has.
"""

import http.client
import json
import socket
import struct
import time

import jax
import jax.numpy as jnp
import pytest

from repro.obs import MetricsRegistry
from repro.serving import Completion, Request, SamplingParams
from repro.serving.http import (SSE_DONE, BadRequest, HttpFrontend,
                                error_payload, parse_completion_body,
                                sse_frame)


# ----------------------------------------------------------------------
# fakes
# ----------------------------------------------------------------------
class FakeHandle:
    def __init__(self, request):
        self.uid = 0
        self.request = request


class FakeBackend:
    """Engine-shaped backend replaying a fixed token list."""

    def __init__(self, tokens=(11, 12, 13), *, fail=None, delay=0.0):
        self.tokens = list(tokens)
        self.fail = fail
        self.delay = delay
        self.registry = MetricsRegistry()
        self.cancelled = []
        self.shut_down = False

    def submit(self, request, *, on_token=None):
        return FakeHandle(request)

    def _out(self, handle):
        return self.tokens[:handle.request.sampling.max_new_tokens]

    def stream(self, handle, *, timeout=None):
        for t in self._out(handle):
            if self.fail is not None:
                raise self.fail
            if self.delay:
                time.sleep(self.delay)
            yield t

    def result(self, handle, *, timeout=None):
        if self.fail is not None:
            raise self.fail
        out = self._out(handle)
        return Completion(uid=handle.uid,
                          prompt_len=len(handle.request.prompt),
                          tokens=out, latency_s=0.5, prefill_s=0.1,
                          t0=0.0, t1=0.5, t_first=0.1, t_sched=0.0)

    def cancel(self, handle):
        self.cancelled.append(handle)
        return True

    def shutdown(self, **kw):
        self.shut_down = True


def _post(fe, body, path="/v1/completions", timeout=10):
    conn = http.client.HTTPConnection(fe.host, fe.port, timeout=timeout)
    conn.request("POST", path, json.dumps(body),
                 {"Content-Type": "application/json"})
    return conn, conn.getresponse()


def _raw_post(fe, body):
    """Hand-rolled streaming POST on a raw socket — the disconnect
    tests need the socket itself (``http.client`` hides it) to force an
    RST close."""
    s = socket.create_connection((fe.host, fe.port), timeout=30)
    payload = json.dumps(body).encode()
    s.sendall(b"POST /v1/completions HTTP/1.1\r\nHost: t\r\n"
              b"Content-Type: application/json\r\nContent-Length: "
              + str(len(payload)).encode() + b"\r\n\r\n" + payload)
    f = s.makefile("rb")
    status = f.readline()
    assert b"200" in status, status
    while f.readline() not in (b"\r\n", b"\n", b""):
        pass                        # drain response headers
    return s, f


def _rst_close(sock, fileobj):
    """Close with SO_LINGER(1, 0): RST instead of FIN, so the server's
    next write fails immediately instead of filling buffers."""
    sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                    struct.pack("ii", 1, 0))
    fileobj.close()
    sock.close()


def _read_sse(resp):
    """(full frame bytes, parsed events) up to and including [DONE].
    Each raw entry is one complete ``data: ...\\n\\n`` frame, so they
    byte-compare against :func:`sse_frame` output directly."""
    raw, events = [], []
    while True:
        line = resp.readline()
        assert line, "EOF before [DONE]"
        if not line.strip():
            continue
        assert line.startswith(b"data:"), line
        sep = resp.readline()
        assert sep == b"\n", sep
        raw.append(line + sep)
        payload = line.strip()[5:].strip()
        if payload == b"[DONE]":
            return raw, events
        events.append(json.loads(payload))


# ----------------------------------------------------------------------
# body parsing + framing (no server)
# ----------------------------------------------------------------------
class TestParseBody:
    def test_token_id_prompt(self):
        toks, sp, stream, slo = parse_completion_body(
            b'{"prompt": [1, 2, 3], "max_tokens": 4, "stream": true,'
            b' "temperature": 0.5, "top_k": 7, "eos_id": 2}')
        assert toks == [1, 2, 3] and stream
        assert slo == {"priority": "interactive", "deadline_ms": None}
        assert (sp.max_new_tokens, sp.temperature, sp.top_k, sp.eos_id) \
            == (4, 0.5, 7, 2)

    def test_string_prompt_needs_tokenizer(self):
        with pytest.raises(BadRequest):
            parse_completion_body(b'{"prompt": "hi"}')

        class Tok:
            def encode(self, s):
                return [ord(c) for c in s]
        toks, sp, stream, _ = parse_completion_body(
            b'{"prompt": "hi"}', tokenizer=Tok())
        assert toks == [104, 105] and sp.max_new_tokens == 16
        assert not stream

    @pytest.mark.parametrize("body", [
        b"not json", b"[1,2]", b'{"prompt": []}', b'{"prompt": [1.5]}',
        b'{"prompt": [true, false]}', b'{}',
        b'{"prompt": [1], "max_tokens": 0}',
        b'{"prompt": [1], "max_tokens": "x"}',
    ])
    def test_rejects(self, body):
        with pytest.raises(BadRequest):
            parse_completion_body(body)

    def test_sse_frame_bytes_are_deterministic(self):
        assert sse_frame({"b": 1, "a": 2}) == b'data: {"a":2,"b":1}\n\n'

    def test_error_payload_carries_cause(self):
        try:
            try:
                raise ValueError("root cause")
            except ValueError as root:
                raise RuntimeError("outer") from root
        except RuntimeError as e:
            doc = error_payload(e)
        assert doc["error"]["type"] == "RuntimeError"
        assert doc["error"]["cause"] == "ValueError: root cause"


# ----------------------------------------------------------------------
# routes over a fake backend
# ----------------------------------------------------------------------
class TestRoutes:
    @pytest.fixture()
    def fe(self):
        with HttpFrontend(FakeBackend()) as fe:
            yield fe

    def test_healthz(self, fe):
        conn = http.client.HTTPConnection(fe.host, fe.port, timeout=10)
        conn.request("GET", "/healthz")
        r = conn.getresponse()
        assert r.status == 200
        assert json.loads(r.read())["status"] == "ok"
        conn.close()

    def test_metrics_prometheus_and_json(self, fe):
        from repro.obs.validate import validate_snapshot
        _post(fe, {"prompt": [1] * 4, "max_tokens": 2})[1].read()
        conn = http.client.HTTPConnection(fe.host, fe.port, timeout=10)
        conn.request("GET", "/metrics")
        r = conn.getresponse()
        assert r.status == 200 and "text/plain" in r.headers["Content-Type"]
        prom = r.read().decode()
        assert "http_requests 1" in prom.replace("  ", " ")
        conn.request("GET", "/metrics.json")
        doc = json.loads(conn.getresponse().read())
        assert validate_snapshot(doc) == []
        assert any(c["name"] == "http.requests" and c["value"] == 1
                   for c in doc["counters"])
        conn.close()

    def test_unknown_paths_404(self, fe):
        conn = http.client.HTTPConnection(fe.host, fe.port, timeout=10)
        conn.request("GET", "/nope")
        assert conn.getresponse().status == 404
        conn2, r = _post(fe, {}, path="/v2/other")
        assert r.status == 404
        conn.close()
        conn2.close()

    def test_bad_body_400_and_counted(self, fe):
        conn, r = _post(fe, {"prompt": []})
        assert r.status == 400
        assert json.loads(r.read())["error"]["type"] == "BadRequest"
        assert fe.registry.get("http.bad_requests").value() == 1
        conn.close()

    def test_block_completion_document(self, fe):
        conn, r = _post(fe, {"prompt": [1, 2], "max_tokens": 3})
        assert r.status == 200
        doc = json.loads(r.read())
        assert doc["choices"][0]["tokens"] == [11, 12, 13]
        assert doc["usage"] == {"prompt_tokens": 2,
                                "completion_tokens": 3,
                                "total_tokens": 5}
        assert doc["id"] == "cmpl-0"
        conn.close()

    def test_stream_frames_and_done(self, fe):
        conn, r = _post(fe, {"prompt": [1, 2], "max_tokens": 3,
                             "stream": True})
        assert r.status == 200
        assert r.headers["Content-Type"] == "text/event-stream"
        raw, events = _read_sse(r)
        toks = [e["token"] for e in events if "token" in e]
        assert toks == [11, 12, 13]
        # token frames are byte-exact reconstructions
        for line, t in zip(raw, toks):
            assert line == sse_frame(fe.token_frame(t))
        done = [e["done"] for e in events if "done" in e]
        assert done and done[0]["completion_tokens"] == 3
        assert done[0]["finish_reason"] == "length"
        assert raw[-1] == SSE_DONE
        conn.close()

    def test_backend_failure_is_an_error_frame(self):
        try:
            raise OSError("disk gone")
        except OSError as root:
            fail = RuntimeError("request 0 failed")
            fail.__cause__ = root
        with HttpFrontend(FakeBackend(fail=fail)) as fe:
            conn, r = _post(fe, {"prompt": [1], "max_tokens": 2,
                                 "stream": True})
            raw, events = _read_sse(r)
            errs = [e["error"] for e in events if "error" in e]
            assert errs and errs[0]["type"] == "RuntimeError"
            assert errs[0]["cause"] == "OSError: disk gone"
            assert fe.registry.get("http.failed").value() == 1
            conn.close()

    def test_backend_failure_blocking_is_500(self):
        with HttpFrontend(FakeBackend(fail=RuntimeError("boom"))) as fe:
            conn, r = _post(fe, {"prompt": [1]})
            assert r.status == 500
            assert json.loads(r.read())["error"]["message"] == "boom"
            conn.close()

    def test_client_disconnect_cancels_fake_backend(self):
        be = FakeBackend([7] * 200, delay=0.01)
        with HttpFrontend(be) as fe:
            sock, f = _raw_post(fe, {"prompt": [1], "max_tokens": 200,
                                     "stream": True})
            line = f.readline()
            assert line.startswith(b"data:")
            _rst_close(sock, f)
            t0 = time.time()
            while not be.cancelled and time.time() - t0 < 10:
                time.sleep(0.02)
            assert be.cancelled
            assert fe.registry.get(
                "http.client_disconnects").value() == 1

    def test_close_can_shut_backend_down(self):
        be = FakeBackend()
        fe = HttpFrontend(be).start()
        fe.close(shutdown_backend=True)
        assert be.shut_down


# ----------------------------------------------------------------------
# real engine: wire parity + disconnect frees pages (slow)
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def tiny_engine():
    from repro.models import ModelConfig, build_model
    from repro.serving import AsyncEngine
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # prefix cache off: retained prompt pages would otherwise keep the
    # pages_free gauge below its baseline after a cancel (by design),
    # hiding exactly the leak the disconnect test watches for
    eng = AsyncEngine(model, params, max_len=128, max_running=2,
                      page_size=4, n_pages=64, prefix_cache=False)
    yield eng
    eng.shutdown()


def _scrape(fe):
    conn = http.client.HTTPConnection(fe.host, fe.port, timeout=10)
    conn.request("GET", "/metrics.json")
    doc = json.loads(conn.getresponse().read())
    conn.close()
    counters = {}
    for c in doc["counters"]:
        counters[c["name"]] = counters.get(c["name"], 0) + c["value"]
    gauges = {}
    for g in doc["gauges"]:
        gauges[g["name"]] = gauges.get(g["name"], 0) + g["value"]
    return counters, gauges


@pytest.mark.slow
class TestRealEngineWire:
    def test_sse_wire_parity_with_engine_stream(self, tiny_engine):
        prompt, max_new = [3, 1, 4, 1, 5, 9, 2, 6], 6
        ref = tiny_engine.submit(Request(
            uid=0, prompt=prompt,
            sampling=SamplingParams(max_new_tokens=max_new)))
        ref_tokens = list(tiny_engine.stream(ref, timeout=120))
        assert len(ref_tokens) == max_new

        with HttpFrontend(tiny_engine) as fe:
            conn, r = _post(fe, {"prompt": prompt, "max_tokens": max_new,
                                 "stream": True}, timeout=120)
            raw, events = _read_sse(r)
            conn.close()
        token_frames = [line for line, e in zip(raw, events)
                        if "token" in e]
        # byte-for-byte: the wire is exactly the engine's token stream
        expected = [sse_frame(fe.token_frame(t)) for t in ref_tokens]
        assert token_frames == expected

    def test_disconnect_cancels_and_frees_pages(self, tiny_engine):
        with HttpFrontend(tiny_engine) as fe:
            # a completed warm-up request populates the pool gauges and
            # leaves every page free again
            conn, r = _post(fe, {"prompt": [1] * 8, "max_tokens": 2},
                            timeout=120)
            assert r.status == 200 and r.read()
            conn.close()
            c0, g0 = _scrape(fe)
            free0 = g0["kv_pool.pages_free"]
            cancelled0 = c0.get("async.cancelled", 0)
            sock, f = _raw_post(fe, {"prompt": [7] * 12,
                                     "max_tokens": 500, "stream": True})
            for _ in range(2):          # stream is really running
                line = f.readline()
                assert line, "stream ended early"
            _rst_close(sock, f)

            deadline = time.time() + 60
            while time.time() < deadline:
                counters, gauges = _scrape(fe)
                if (counters.get("async.cancelled", 0) > cancelled0
                        and gauges["kv_pool.pages_free"] >= free0):
                    break
                time.sleep(0.05)
            assert counters.get("async.cancelled", 0) == cancelled0 + 1
            # the abandoned request's pages are back in the pool
            assert gauges["kv_pool.pages_free"] >= free0
