"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.ops import gqa_decode_attention
from repro.kernels.q4_gemm import q4_gemm
from repro.quant.q4_0 import BLOCK, dequantize, quantize, quantized_bytes


def _rand(shape, seed, dtype=np.float32, scale=1.0):
    return (np.random.default_rng(seed).normal(size=shape) * scale
            ).astype(dtype)


class TestQ4Gemm:
    @pytest.mark.parametrize("M,K,N,bn,bk", [
        (1, 256, 512, 256, 256),      # decode GEMV
        (4, 512, 256, 128, 128),
        (8, 1024, 768, 256, 256),
        (3, 64, 128, 128, 64),        # small / non-square
        (16, 128, 384, 128, 32),      # bk == BLOCK
        (2, 320, 128, 64, 160),       # odd-ish tiling
    ])
    def test_matches_oracle(self, M, K, N, bn, bk):
        w = _rand((K, N), 0, scale=0.2)
        x = _rand((M, K), 1)
        p, s = quantize(w)
        out = q4_gemm(jnp.asarray(x), p, s, block_n=bn, block_k=bk,
                      interpret=True)
        want = ref.q4_gemm_ref(jnp.asarray(x), p, s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-4)

    @pytest.mark.parametrize("xdtype", [np.float32, jnp.bfloat16])
    def test_dtypes(self, xdtype):
        w = _rand((128, 128), 0, scale=0.2)
        x = jnp.asarray(_rand((2, 128), 1)).astype(xdtype)
        p, s = quantize(w)
        out = q4_gemm(x, p, s, block_n=128, block_k=128, interpret=True)
        want = ref.q4_gemm_ref(x, p, s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    def test_rejects_bad_tiling(self):
        w = _rand((128, 100), 0)
        p, s = quantize(w)
        with pytest.raises(ValueError):
            q4_gemm(jnp.zeros((1, 128)), p, s, block_n=64, block_k=128)


class TestDecodeAttention:
    @pytest.mark.parametrize("B,S,H,G,D,bs", [
        (2, 256, 2, 4, 64, 64),
        (1, 512, 4, 1, 128, 128),
        (3, 128, 2, 8, 32, 32),
        (1, 1024, 1, 4, 256, 256),    # gemma3-like MQA
    ])
    @pytest.mark.parametrize("fill", [0.3, 1.0])
    def test_matches_oracle(self, B, S, H, G, D, bs, fill):
        kv_len = max(1, int(S * fill))
        q = _rand((B, H, G, D), 0)
        k = _rand((B, S, H, D), 1)
        v = _rand((B, S, H, D), 2)
        out = decode_attention(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), kv_len, block_s=bs,
                               interpret=True)
        want = ref.decode_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                        jnp.asarray(v), kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_ops_wrapper_contract(self):
        """gqa_decode_attention matches the model-zoo flash decode."""
        from repro.models.attention import flash_attention
        B, S, Hq, Hkv, D = 2, 64, 8, 2, 32
        kv_len = 40
        q = _rand((B, 1, Hq, D), 0)
        k = np.zeros((B, S, Hkv, D), np.float32)
        v = np.zeros((B, S, Hkv, D), np.float32)
        k[:, :kv_len] = _rand((B, kv_len, Hkv, D), 1)
        v[:, :kv_len] = _rand((B, kv_len, Hkv, D), 2)
        out = gqa_decode_attention(jnp.asarray(q), jnp.asarray(k),
                                   jnp.asarray(v), kv_len)
        want = flash_attention(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), causal=True,
                               q_offset=kv_len - 1, kv_len=kv_len, chunk=16)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=1e-4, atol=1e-5)


class TestQ4Quant:
    @given(k_blocks=st.integers(1, 8), n=st.integers(1, 64),
           scale=st.floats(0.01, 100.0))
    @settings(max_examples=40, deadline=None)
    @pytest.mark.slow
    def test_roundtrip_error_bound(self, k_blocks, n, scale):
        """|dequant(quant(w)) - w| <= |block scale| (+ fp16 rounding)."""
        K = k_blocks * BLOCK
        w = _rand((K, n), k_blocks * 100 + n, scale=scale)
        p, s = quantize(w)
        wd = np.asarray(dequantize(p, s))
        err = np.abs(wd - w)
        bound = np.abs(np.asarray(s)).repeat(BLOCK, axis=0)
        assert np.all(err <= bound * 1.02 + 1e-6)

    @given(k_blocks=st.integers(1, 4), n=st.integers(1, 32))
    @settings(max_examples=20, deadline=None)
    @pytest.mark.slow
    def test_idempotent(self, k_blocks, n):
        """Quantizing an already-quantized weight is exact."""
        K = k_blocks * BLOCK
        w = _rand((K, n), 7)
        p, s = quantize(w)
        wd = dequantize(p, s)
        p2, s2 = quantize(wd)
        np.testing.assert_allclose(np.asarray(dequantize(p2, s2)),
                                   np.asarray(wd), rtol=1e-6, atol=1e-7)

    def test_bytes_accounting(self):
        assert quantized_bytes((256, 100)) == 256 * 100 // 2 + 8 * 100 * 4

    def test_zero_block(self):
        w = np.zeros((BLOCK, 3), np.float32)
        p, s = quantize(w)
        assert np.asarray(dequantize(p, s)).sum() == 0.0


class TestRGLRUScanKernel:
    @pytest.mark.parametrize("B,T,W,bt", [
        (2, 37, 16, 8),      # padded tail chunk
        (1, 128, 64, 128),   # single chunk
        (3, 64, 32, 16),
        (2, 200, 8, 64),
    ])
    @pytest.mark.parametrize("with_h0", [False, True])
    def test_matches_oracle(self, B, T, W, bt, with_h0):
        from repro.kernels.rglru_scan import rglru_scan_kernel
        rng = np.random.default_rng(B * T + W)
        a = jnp.asarray(rng.uniform(0.7, 0.999, (B, T, W)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(B, T, W)) * 0.3, jnp.float32)
        h0 = (jnp.asarray(rng.normal(size=(B, W)), jnp.float32)
              if with_h0 else None)
        out = rglru_scan_kernel(a, u, h0=h0, block_t=bt, interpret=True)
        want = ref.rglru_scan_ref(a, u, h0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_matches_model_zoo_recurrence(self):
        """Kernel == repro.models.recurrent gate semantics."""
        from repro.kernels.rglru_scan import rglru_scan_kernel
        from repro.models.recurrent import (_gates, init_rglru_block,
                                            rglru_scan)
        p = init_rglru_block(jax.random.PRNGKey(0), 16, 24, 4, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 19, 24))
        a, u = _gates(p, x)
        want, _ = rglru_scan(p, x)
        out = rglru_scan_kernel(a, u, block_t=8, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(want, np.float32),
                                   rtol=1e-5, atol=1e-5)

    def test_ops_wrapper(self):
        from repro.kernels.ops import rglru_linear_scan
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.uniform(0.5, 0.99, (1, 10, 4)), jnp.float32)
        u = jnp.asarray(rng.normal(size=(1, 10, 4)), jnp.float32)
        out = rglru_linear_scan(a, u, impl="ref")
        want = ref.rglru_scan_ref(a, u)
        # jit-fused associative scan reorders the products slightly
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
