"""Hypothesis shim so the suite collects (and runs) everywhere.

Re-exports the real ``hypothesis`` when it is installed (listed in
``requirements-dev.txt``).  When it is missing — minimal CI images,
hermetic containers — a small deterministic fallback implements the
strategy surface these tests actually use (``integers``, ``floats``,
``sampled_from``, ``lists``, ``tuples``, ``booleans``) by drawing
``max_examples``
pseudo-random examples from a per-test fixed seed.  No shrinking, no
database; strictly weaker than hypothesis, strictly stronger than
skipping every property test.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import functools
    import inspect
    import random
    import zlib

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):  # rejection sampling
                    x = self._draw(rng)
                    if pred(x):
                        return x
                raise RuntimeError("filter predicate too strict")
            return _Strategy(draw)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class strategies:  # noqa: N801 — mirrors `hypothesis.strategies`
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def tuples(*elements):
            return _Strategy(lambda rng: tuple(e.example_from(rng)
                                               for e in elements))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def lists(elements, *, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example_from(rng) for _ in range(n)]
            return _Strategy(draw)

    def settings(max_examples: int = 20, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 20)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for _ in range(n):
                    drawn = [s.example_from(rng) for s in arg_strategies]
                    drawn_kw = {k: s.example_from(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)
            wrapper._max_examples = getattr(fn, "_max_examples", 20)
            # hide the strategy-filled parameters from pytest's fixture
            # resolution: drop __wrapped__ and publish a reduced signature
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            sig = inspect.signature(fn)
            keep, pos_left = [], len(arg_strategies)
            for p in sig.parameters.values():
                if p.name == "self":
                    keep.append(p)
                elif p.name in kw_strategies:
                    pass
                elif pos_left > 0:
                    pos_left -= 1
                else:
                    keep.append(p)
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper
        return deco


st = strategies
