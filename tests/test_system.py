"""End-to-end system behaviour: the full ArcLight-in-JAX stack.

Train a tiny LM with the real pipeline, quantize it Q4_0, serve it
with the engine, and check the quantized decode agrees with the dense
model on greedy tokens — the paper's whole lifecycle at laptop scale.
Plus the HLO cost parser + roofline plumbing on a real compiled module.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.data.pipeline import PackedLMDataset
from repro.launch.hlo_cost import analyse_hlo
from repro.launch.roofline import collective_bytes, format_table
from repro.models import ModelConfig, build_model
from repro.quant.q4_0 import dequantize, quantize_params
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import SamplingParams
from repro.training.loop import train
from repro.training.optimizer import AdamWConfig


@pytest.fixture(scope="module")
def trained():
    cfg = ModelConfig(name="sys", arch_type="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ds = PackedLMDataset(seq_len=48, n_docs=400, vocab_size=cfg.vocab_size)
    params, _, hist = train(model, params, ds.batches(8),
                            AdamWConfig(lr=2e-3, warmup_steps=10,
                                        total_steps=60),
                            steps=60, log_every=20)
    return cfg, model, params, hist


def test_training_converges(trained):
    _, _, _, hist = trained
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.8


def test_serve_trained_model(trained):
    cfg, model, params, _ = trained
    eng = ServingEngine(model, params, max_len=96)
    reqs = [Request(uid=i, prompt=[257] + list(b"the scheduler"),
                    sampling=SamplingParams(max_new_tokens=12))
            for i in range(3)]
    comps = eng.generate(reqs, max_batch=4)
    assert all(len(c.tokens) == 12 for c in comps)
    # deterministic greedy: identical prompts -> identical outputs
    assert comps[0].tokens == comps[1].tokens == comps[2].tokens


def test_q4_quantized_weights_close(trained):
    """Q4_0 weights stay close enough that the logits barely move."""
    cfg, model, params, _ = trained
    qparams = quantize_params(params, min_size=128)

    def deq(x):
        if isinstance(x, dict) and "q4_packed" in x:
            return dequantize(x["q4_packed"], x["q4_scales"],
                              dtype=jnp.float32)
        return x

    dq = jax.tree.map(deq, qparams,
                      is_leaf=lambda x: isinstance(x, dict)
                      and "q4_packed" in x)
    tokens = jnp.asarray([[257, 116, 104, 101]])
    batch = {"tokens": tokens, "labels": tokens}
    ref_logits, _ = model.forward(params, batch)
    q_logits, _ = model.forward(dq, batch)
    # top-1 agreement on the last position
    assert int(jnp.argmax(ref_logits[0, -1])) == \
        int(jnp.argmax(q_logits[0, -1]))


def test_hlo_cost_parser_on_real_module():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 64), jnp.float32)
                         ).compile()
    r = analyse_hlo(c.as_text())
    assert r.flops == pytest.approx(7 * 2 * 64 ** 3)
    assert r.coll_bytes == 0.0


def test_collective_regex_parser():
    hlo = """
  %ag = bf16[16,128]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[4,4]{1,0} all-reduce(%y), to_apply=%add
  %cp = u8[100]{0} collective-permute(%z)
  %not.a.collective = f32[2]{0} add(%a, %b)
"""
    got = collective_bytes(hlo)
    assert got["all-gather"] == 16 * 128 * 2
    assert got["all-reduce"] == 64
    assert got["collective-permute"] == 100


def test_roofline_table_formatting():
    from repro.launch.roofline import RooflineReport
    r = RooflineReport(arch="a", shape="s", mesh="16x16", chips=256,
                       hlo_flops=1e12, hlo_bytes=1e9, coll_bytes=1e8,
                       coll_breakdown={}, model_flops=2e14,
                       t_compute=1e-3, t_memory=2e-3, t_collective=5e-4,
                       bytes_per_device=2 ** 30)
    table = format_table([r])
    assert "memory" in table and "16x16" in table
