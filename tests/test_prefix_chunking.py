"""Prefix caching + chunked prefill (PR: refcounted prefix sharing).

Pool level: refcount lifecycle (a referenced page is never freed),
share/release protocol, copy-on-write via ``ensure_writable``, prefix
hash-map matching (full pages, mid-page divergence, positional chain).
Scheduler level: prefix-aware admission budget, chunked prefill
interleaving with decode.  Engine level: greedy token parity of shared,
copy-on-write and chunked runs against the no-sharing baseline, with
the pool's allocation stats proving pages were actually reused.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import ModelConfig, build_model
from repro.serving import (ContinuousServingEngine, ContinuousScheduler,
                           KVCachePool, KVPoolConfig, Request,
                           SamplingParams)


def _pool(n_pages=17, page_size=4, n_nodes=1, prefix_cache=True):
    return KVCachePool(KVPoolConfig(
        n_pages=n_pages, page_size=page_size, n_layers=2, n_kv_heads=2,
        head_dim=8, dtype_bytes=4, n_nodes=n_nodes),
        prefix_cache=prefix_cache)


class TestRefcounts:
    def test_share_then_release_keeps_page_until_last_owner(self):
        pool = _pool(n_pages=9)
        assert pool.grow(0, 8)                    # 2 pages
        shared = pool.block_table(0)
        pool.share_pages(1, shared)
        assert all(pool.refcount(p) == 2 for p in shared)
        pool.release(0)
        # still referenced by uid 1: not freed, not reusable
        assert all(pool.refcount(p) == 1 for p in shared)
        assert pool.block_table(1) == shared
        assert pool.n_free() == 8 - 2
        pool.release(1)
        assert pool.n_free() == 8
        assert pool.n_live() == 0

    def test_cannot_share_dead_or_scratch_pages(self):
        pool = _pool(n_pages=9)
        with pytest.raises(ValueError, match="not live"):
            pool.share_pages(1, [3])
        with pytest.raises(ValueError, match="not live"):
            pool.share_pages(1, [0])

    def test_ensure_writable_clones_shared_page(self):
        pool = _pool(n_pages=9)
        pool.grow(0, 4)                           # 1 page
        [src] = pool.block_table(0)
        pool.share_pages(1, [src])
        assert pool.ensure_writable(1, 2)
        [dst] = pool.block_table(1)
        assert dst != src
        assert pool.refcount(src) == 1 and pool.refcount(dst) == 1
        assert pool.drain_copies() == [(src, dst)]
        assert pool.block_table(0) == [src], "donor table untouched"

    def test_ensure_writable_noop_on_private_page(self):
        pool = _pool(n_pages=9)
        pool.grow(0, 4)
        [pid] = pool.block_table(0)
        assert pool.ensure_writable(0, 3)
        assert pool.block_table(0) == [pid]
        assert pool.drain_copies() == []

    def test_ensure_writable_fails_when_pool_dry(self):
        pool = _pool(n_pages=3)                   # 2 usable pages
        pool.grow(0, 4)
        pool.grow(1, 4)
        pool.share_pages(2, pool.block_table(0))
        assert not pool.ensure_writable(2, 0), "no page for the clone"

    @given(ops=st.lists(st.integers(0, 11), min_size=1, max_size=60))
    @settings(max_examples=25, deadline=None)
    def test_referenced_pages_never_reach_free_list(self, ops):
        """Random grow/share/release interleavings: every block-table
        entry stays live (refcount >= 1, not in a free list), refcounts
        equal the number of tables holding the page, and free + live
        always account for the whole usable pool."""
        pool = _pool(n_pages=13)
        for op in ops:
            uid = op % 3
            if op < 6:
                want = 4 * (len(pool.block_table(uid)) + 1)
                if pool.cfg.pages_for(want) <= pool.cfg.max_pages_per_seq:
                    pool.grow(uid, want)
            elif op < 9:
                donor = (uid + 1) % 3
                if pool.block_table(donor):
                    pool.share_pages(uid, pool.block_table(donor)[:1])
            else:
                pool.release(uid)
            free = {p for lst in pool._free.values() for p in lst}
            held = {}
            for u in range(3):
                for p in pool.block_table(u):
                    assert p != 0, "scratch page leaked"
                    assert p not in free, f"page {p} live AND free"
                    held[p] = held.get(p, 0) + 1
            for p, n in held.items():
                assert pool.refcount(p) == n
            assert pool.n_live() + pool.n_free() == pool.cfg.n_pages - 1


class TestPrefixMatching:
    def test_full_page_prefix_match(self):
        pool = _pool()
        pool.grow(0, 9)                           # prompt 8 + decode slot
        pool.register_prefix(0, list(range(1, 9)))
        m = pool.match_prefix(list(range(1, 9)) + [99])   # 9 tokens
        assert list(m.pages) == pool.block_table(0)[:2]
        assert m.n_tokens == 8 and m.cow_src is None

    def test_match_caps_one_token_below_identical_prompt(self):
        """An exact duplicate must still prefill >= 1 token (for the
        first sample's logits); the final page is cloned, not shared."""
        pool = _pool()
        pool.grow(0, 9)
        prompt = list(range(1, 9))
        pool.register_prefix(0, prompt)
        m = pool.match_prefix(prompt)             # limit = 7
        assert list(m.pages) == pool.block_table(0)[:1]
        assert m.cow_src == pool.block_table(0)[1] and m.cow_len == 3
        assert m.n_tokens == 7

    def test_mid_page_divergence_is_cow(self):
        pool = _pool()
        pool.grow(0, 9)
        pool.register_prefix(0, [1, 2, 3, 4, 5, 6, 7, 8])
        m = pool.match_prefix([1, 2, 3, 4, 5, 6, 200, 201, 202])
        assert m.n_tokens == 6                    # page 0 + 2 tokens
        assert m.cow_src == pool.block_table(0)[1] and m.cow_len == 2

    def test_chain_hash_is_position_sensitive(self):
        """The same block content at a different block index must not
        match — KV depends on absolute position (RoPE)."""
        pool = _pool()
        pool.grow(0, 9)
        pool.register_prefix(0, [1, 2, 3, 4, 5, 6, 7, 8])
        m = pool.match_prefix([5, 6, 7, 8, 50, 51])
        assert m.n_tokens == 0 and not m.pages and m.cow_src is None

    def test_entries_die_with_their_page(self):
        """Without retention, release forgets the entries immediately;
        with it (default), they survive until the LRU evicts the page."""
        pool = KVCachePool(KVPoolConfig(
            n_pages=17, page_size=4, n_layers=2, n_kv_heads=2,
            head_dim=8, dtype_bytes=4), retain=False)
        pool.grow(0, 9)
        prompt = list(range(1, 9))
        pool.register_prefix(0, prompt)
        pool.release(0)
        m = pool.match_prefix(prompt + [99])
        assert m.n_tokens == 0 and not m.pages

    def test_adopt_prefix_shares_and_clones(self):
        pool = _pool()
        pool.grow(0, 9)
        pool.register_prefix(0, [1, 2, 3, 4, 5, 6, 7, 8])
        m = pool.match_prefix([1, 2, 3, 4, 5, 6, 9, 9, 9])
        assert pool.adopt_prefix(1, m)
        table = pool.block_table(1)
        assert table[0] == pool.block_table(0)[0]         # shared
        assert pool.refcount(table[0]) == 2
        assert table[1] != pool.block_table(0)[1]         # CoW clone
        assert pool.drain_copies() == [(pool.block_table(0)[1], table[1])]
        # the clone + share satisfy 6 of the 9 tokens; grow covers rest
        assert pool.grow(1, 10)
        assert len(table) != 0 and pool.stats["cow_copies"] == 1


class TestRetention:
    """Prefix-page retention LRU: refcount-0 pages that are prefix-
    indexed retire to a cached-free list instead of being forgotten,
    and are evicted (LRU) only when the free lists run dry."""

    def test_release_retains_indexed_pages(self):
        pool = _pool(n_pages=17)
        pool.grow(0, 9)                           # 3 pages (2 indexed)
        prompt = list(range(1, 9))
        pool.register_prefix(0, prompt)
        pool.release(0)
        assert pool.n_live() == 0
        assert pool.n_retained() == 2             # indexed full pages
        assert pool.n_free() == 16                # retained still count
        m = pool.match_prefix(prompt + [99])
        assert m.n_tokens == 8 and len(m.pages) == 2

    def test_adopt_revives_retained_pages(self):
        pool = _pool(n_pages=17)
        pool.grow(0, 9)
        prompt = list(range(1, 9))
        pool.register_prefix(0, prompt)
        pool.release(0)
        m = pool.match_prefix(prompt + [99])
        assert pool.adopt_prefix(1, m)
        assert pool.n_retained() == 0
        assert all(pool.refcount(p) == 1 for p in m.pages)
        assert pool.stats["retention_hits"] == 2
        pool.release(1)                           # back to retained
        assert pool.n_retained() == 2

    def test_eviction_when_free_list_runs_dry(self):
        pool = _pool(n_pages=5)                   # 4 usable pages
        pool.grow(0, 9)                           # takes 3
        prompt = list(range(1, 9))
        pool.register_prefix(0, prompt)
        pool.release(0)                           # 2 retained + 2 free
        assert pool.grow(1, 16)                   # needs all 4 pages
        assert pool.n_retained() == 0
        assert pool.stats["retained_evictions"] == 2
        m = pool.match_prefix(prompt + [99])      # entries died at evict
        assert m.n_tokens == 0 and not m.pages

    def test_lru_evicts_oldest_retirement_first(self):
        pool = _pool(n_pages=9)                   # 8 usable
        pool.grow(0, 5)                           # 2 pages, 1 indexed
        pool.register_prefix(0, [1, 2, 3, 4])
        pool.grow(1, 5)
        pool.register_prefix(1, [5, 6, 7, 8])
        pool.release(0)                           # retired first
        pool.release(1)
        assert pool.n_retained() == 2
        assert pool.grow(2, 4 * (8 - 2 + 1))      # force ONE eviction
        assert pool.match_prefix([1, 2, 3, 4, 9]).n_tokens == 0
        assert pool.match_prefix([5, 6, 7, 8, 9]).n_tokens == 4

    def test_admission_budget_counts_matched_retained_pages_once(self):
        """A matched retained page is both 'shared, not allocated' AND
        part of n_free()'s reclaimable count — the budget must not use
        it twice.  Here the prompt's tail needs 2 pages and n_free()
        says 2, but one of those IS the matched retained page:
        admission must refuse cleanly instead of adopt-then-rollback
        (which would inflate stats on every retried step)."""
        prompt = list(range(1, 9))                # 2 full pages @ ps=4
        pool = _pool(n_pages=5)                   # 4 usable pages
        pool.grow(0, 9)                           # donor: 3 pages
        pool.register_prefix(0, prompt)
        pool.release(0)                           # 2 retained, 2 free
        pool.grow(9, 8)                           # bystander eats the
        assert pool.n_retained() == 2             # 2 true-free pages
        assert pool.n_free() == 2                 # both are retained
        sched = ContinuousScheduler(pool, max_running=4, max_len=64)
        # repeat prompt: 1 retained page + CoW match; tail needs the
        # clone + decode page = 2, but reviving the match leaves 1
        sched.submit(Request(uid=1, prompt=list(prompt)))
        before = dict(pool.stats)
        plan = sched.step()
        assert not plan.prefills and not sched.running
        assert pool.stats["retention_hits"] == before["retention_hits"]
        assert pool.stats["shared_pages"] == before["shared_pages"]
        assert pool.n_retained() == 2             # LRU undisturbed

    def test_cow_only_match_against_retained_page(self):
        """Divergence inside the FIRST block of a retained prompt:
        the match shares no full page, only a CoW clone — adoption
        must create the block table from scratch (regression: KeyError
        when the clone was the table's first entry)."""
        pool = _pool(n_pages=9, page_size=8)
        pool.grow(0, 9)
        pool.register_prefix(0, list(range(1, 9)))
        pool.release(0)                           # first page retained
        m = pool.match_prefix([1, 2, 3, 200, 201])
        assert not m.pages and m.cow_src is not None and m.cow_len == 3
        assert pool.adopt_prefix(1, m)
        assert len(pool.block_table(1)) == 1
        assert pool.pending_copies == [(m.cow_src, pool.block_table(1)[0])]

    @pytest.mark.slow
    def test_repeat_prompt_hits_cache_after_first_request_finished(
            self, tiny):
        """The cross-request claim: serve a prompt, let the request
        finish completely (refcounts at 0), serve it again — the repeat
        must hit retained pages, not re-prefill, with identical greedy
        tokens."""
        _, model, params = tiny
        req = Request(uid=0, prompt=SHARED_PREFIX + [31, 32, 33],
                      sampling=SamplingParams(max_new_tokens=6))
        eng = ContinuousServingEngine(model, params, max_len=64,
                                      max_running=4, page_size=4)
        first = eng.generate([req])
        assert eng.pool.n_live() == 0             # fully finished
        assert eng.pool.n_retained() > 0
        again = eng.generate([req])
        assert [c.tokens for c in again] == [c.tokens for c in first]
        assert eng.pool.stats["retention_hits"] > 0
        assert eng.pool.stats["cached_tokens"] >= len(SHARED_PREFIX)


class TestSchedulerPrefix:
    def test_cached_pages_do_not_count_against_budget(self):
        """A mostly-cached prompt admits into a pool too full for a cold
        one: 5 usable pages, donor holds 3, prompt needs 3 — only 2 are
        free, but sharing covers the difference."""
        prompt = list(range(1, 9))                # 8 tokens, ps=4
        for cached, want_admitted in ((True, True), (False, False)):
            pool = _pool(n_pages=6, prefix_cache=cached)
            sched = ContinuousScheduler(pool, max_running=4, max_len=64)
            donor = sched.submit(Request(uid=0, prompt=prompt))
            plan = sched.step()
            assert [s.uid for s in plan.prefills] == [0]
            donor.n_prefilled = donor.prefill_target   # engine ran it
            pool.register_prefix(0, prompt)
            assert pool.n_free() == 2
            sched.submit(Request(uid=1, prompt=list(prompt)))
            plan = sched.step()
            admitted = any(s.uid == 1 for s in plan.prefills)
            assert admitted == want_admitted
            if cached:
                # shared full page + CoW clone: only 1 token to prefill
                seq = next(s for s in plan.prefills if s.uid == 1)
                assert seq.n_prefilled == 7 and seq.prefill_target == 8
                assert pool.stats["shared_pages"] == 1
                assert pool.stats["cow_copies"] == 1

    def test_chunked_prefill_never_blocks_decode(self):
        """A long-prompt admission runs as fixed-size chunks, one per
        step, while the resident sequence decodes in *every* step."""
        pool = _pool(n_pages=17)
        sched = ContinuousScheduler(pool, max_running=4, max_len=64,
                                    prefill_chunk=2)
        a = sched.submit(Request(uid=0, prompt=[1, 2, 3]))
        plan = sched.step()
        a.n_prefilled = a.prefill_target
        a.generated.append(7)                     # engine sampled
        b = sched.submit(Request(uid=1, prompt=list(range(10, 20))))
        steps = 0
        while b.is_prefilling or b.slot == -1:
            plan = sched.step()
            assert [s.uid for s in plan.decodes] == [0], \
                "decode must run every step during the long admission"
            assert [s.uid for s in plan.prefills] == [1]
            n = sched.chunk_for(b)
            assert 0 < n <= 2
            b.n_prefilled += n                    # engine ran the chunk
            a.generated.append(7)                 # engine decoded a
            steps += 1
            assert steps < 20
        assert steps == 5                         # ceil(10 / 2)
        plan = sched.step()                       # b decodes from now on
        b.generated.append(9)
        assert {s.uid for s in plan.decodes} == {0, 1}

    def test_preempted_mid_prefill_restarts_clean(self):
        pool = _pool(n_pages=5)                   # 4 usable pages
        sched = ContinuousScheduler(pool, max_running=2, max_len=64,
                                    prefill_chunk=2)
        a = sched.submit(Request(uid=0, prompt=[1] * 6), arrival=0.0)
        sched.step()
        a.n_prefilled = a.prefill_target
        a.generated.append(7)
        b = sched.submit(Request(uid=1, prompt=[2] * 8), arrival=1.0)
        plan = sched.step(now=1.0)                # b admitted? needs 3 pages
        assert plan.prefills == []                # only 2 free: stays queued
        # decode a across its page boundary until the pool forces action
        a.generated.extend([7] * 6)
        plan = sched.step(now=2.0)
        assert a.slot != -1 and pool.block_table(1) == []
        assert b in sched.waiting


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


SHARED_PREFIX = [11, 12, 13, 14, 21, 22, 23, 24]          # 2 full ps=4 pages


def _greedy(prompt_suffixes, max_new=6):
    return [Request(uid=i, prompt=SHARED_PREFIX + s,
                    sampling=SamplingParams(max_new_tokens=max_new))
            for i, s in enumerate(prompt_suffixes)]


class TestEnginePrefixChunking:
    def _run(self, model, params, reqs, *, arrivals=None, **kw):
        eng = ContinuousServingEngine(model, params, max_len=64,
                                      max_running=4, page_size=4, **kw)
        comps = eng.generate(reqs, arrivals=arrivals)
        return eng, [c.tokens for c in comps]

    @pytest.mark.slow
    def test_shared_prefix_parity_and_page_savings(self, tiny):
        _, model, params = tiny
        suffixes = [[31, 32, 33], [41, 42, 43], [51, 52]]
        # staggered arrivals so later requests admit after the donor's
        # prompt pages are resident and registered
        arrivals = [0.0, 0.05, 0.1]
        e_off, toks_off = self._run(model, params, _greedy(suffixes),
                                    arrivals=arrivals, prefix_cache=False)
        e_on, toks_on = self._run(model, params, _greedy(suffixes),
                                  arrivals=arrivals, prefix_cache=True)
        assert toks_on == toks_off, "sharing must not change greedy tokens"
        assert e_on.pool.stats["shared_pages"] >= 2, "prefix pages reused"
        assert (e_on.pool.stats["fresh_pages"]
                < e_off.pool.stats["fresh_pages"])
        assert e_on.pool.stats["cached_tokens"] >= 8

    @pytest.mark.slow
    def test_cow_divergence_parity(self, tiny):
        """Second request diverges mid-page: first page shares, second
        page clones (copy-on-write) and only the suffix recomputes."""
        _, model, params = tiny
        a = Request(uid=0, prompt=[1, 2, 3, 4, 5, 6, 7, 8],
                    sampling=SamplingParams(max_new_tokens=6))
        b = Request(uid=1, prompt=[1, 2, 3, 4, 5, 6, 200, 201, 202],
                    sampling=SamplingParams(max_new_tokens=6))
        arrivals = [0.0, 0.05]
        e_off, toks_off = self._run(model, params, [a, b],
                                    arrivals=arrivals, prefix_cache=False)
        e_on, toks_on = self._run(model, params, [a, b],
                                  arrivals=arrivals, prefix_cache=True)
        assert toks_on == toks_off
        assert e_on.pool.stats["cow_copies"] >= 1
        assert e_on.pool.stats["shared_pages"] >= 1

    @pytest.mark.slow
    def test_chunked_prefill_parity(self, tiny):
        """Chunked prefill (including a 17-token prompt spread over many
        steps) produces the same greedy tokens as one-shot prefill."""
        _, model, params = tiny
        rng = np.random.default_rng(11)
        reqs = [Request(uid=i, prompt=list(rng.integers(1, 258, n)),
                        sampling=SamplingParams(max_new_tokens=5))
                for i, n in enumerate([17, 3, 9, 6])]
        _, toks_one = self._run(model, params, reqs, prefix_cache=False)
        _, toks_chunk = self._run(model, params, reqs, prefix_cache=False,
                                  prefill_chunk=4)
        assert toks_chunk == toks_one

    @pytest.mark.slow
    def test_chunked_plus_prefix_parity(self, tiny):
        _, model, params = tiny
        suffixes = [[31, 32, 33, 34, 35], [41, 42, 43, 44]]
        # long decode keeps the donor resident across the second arrival
        reqs = _greedy(suffixes, max_new=48)
        arrivals = [0.0, 0.02]
        _, base = self._run(model, params, reqs,
                            arrivals=arrivals, prefix_cache=False)
        eng = ContinuousServingEngine(model, params, max_len=64,
                                      max_running=4, page_size=4,
                                      prefix_cache=True, prefill_chunk=4)
        # warm every chunk-shape compile so the measured run's steps are
        # milliseconds — the donor then finishes (and registers) its
        # chunked prefill well before the second arrival at 0.02 s
        eng.generate(reqs)
        assert eng.pool.n_live() == 0             # warm run fully drained
        eng.pool.stats["shared_pages"] = 0
        toks = [c.tokens for c in eng.generate(reqs, arrivals=arrivals)]
        assert toks == base
        assert eng.pool.stats["shared_pages"] >= 1

    def test_pool_drains_clean_after_generate(self, tiny):
        _, model, params = tiny
        e, _ = self._run(model, params, _greedy([[31], [41, 42]]),
                         arrivals=[0.0, 0.05])
        assert e.pool.n_live() == 0
        assert e.pool.n_free() == e.pool.cfg.n_pages - 1
        assert e.pool.pending_copies == []


class TestPerLayerCopies:
    """CoW page copies against the per-layer (scan-escape) cache
    layout: one (src_rows, dst_rows) plan serves every layer buffer."""

    def test_copy_row_plan_expands_pages_to_rows(self):
        pool = _pool(n_pages=9, page_size=4)
        src, dst = pool.copy_row_plan([(2, 5)])
        assert src.tolist() == [8, 9, 10, 11]
        assert dst.tolist() == [20, 21, 22, 23]

    def test_copy_row_plan_pads_with_scratch_noops(self):
        pool = _pool(n_pages=9, page_size=4)
        src, dst = pool.copy_row_plan([(2, 5)], pad_to_pages=4)
        assert src.shape == dst.shape == (16,)
        # pad rows are 0 -> 0: a self-copy into the reserved scratch
        # page, invisible to every live sequence
        assert src[4:].tolist() == [0] * 12
        assert dst[4:].tolist() == [0] * 12
        with pytest.raises(ValueError):
            pool.copy_row_plan([(2, 5), (3, 6)], pad_to_pages=1)

    def test_apply_copies_touches_every_layer_buffer(self, tiny):
        """A queued CoW copy must land in ALL per-layer K and V buffers
        in one dispatch, and leave the runner cache rebound to the
        copied (donated) buffers."""
        _, model, params = tiny
        eng = ContinuousServingEngine(model, params, max_len=32,
                                      max_running=2, page_size=4)
        runner = eng.core.runner
        ps = 4
        src_page, dst_page = 2, 5
        rows = np.arange(src_page * ps, (src_page + 1) * ps)
        for i, lyr in enumerate(runner.cache["layers"]):
            H, D = lyr["self"]["k"].shape[1:]
            vals = np.full((ps, H, D), float(i + 1), np.float32)
            lyr["self"]["k"] = lyr["self"]["k"].at[rows].set(vals)
            lyr["self"]["v"] = lyr["self"]["v"].at[rows].set(-vals)
        eng.pool.pending_copies.append((src_page, dst_page))
        eng.core._apply_copies()
        assert eng.pool.pending_copies == []
        drows = np.arange(dst_page * ps, (dst_page + 1) * ps)
        for i, lyr in enumerate(runner.cache["layers"]):
            np.testing.assert_array_equal(
                np.asarray(lyr["self"]["k"][drows]),
                np.full_like(np.asarray(lyr["self"]["k"][drows]),
                             float(i + 1)))
            np.testing.assert_array_equal(
                np.asarray(lyr["self"]["v"][drows]),
                np.full_like(np.asarray(lyr["self"]["v"][drows]),
                             -float(i + 1)))


class TestBenchGate:
    """tools/bench_gate.py regression logic (pure compare path)."""

    def _report(self, **vals):
        metrics = {}
        for name, (value, direction) in vals.items():
            metrics[name] = {"value": value, "direction": direction}
        return {"metrics": metrics}

    def test_injected_regression_fails_gate(self, tmp_path):
        import json
        import subprocess
        import sys
        import os
        root = os.path.join(os.path.dirname(__file__), "..")
        base = self._report(decode_tok_per_s=(100.0, "higher"),
                            decode_flatness=(1.0, "lower"))
        # decode throughput fell 40% — far past the 20% threshold
        cur = self._report(decode_tok_per_s=(60.0, "higher"),
                           decode_flatness=(1.0, "lower"))
        bp, cp = tmp_path / "base.json", tmp_path / "cur.json"
        bp.write_text(json.dumps(base))
        cp.write_text(json.dumps(cur))
        r = subprocess.run(
            [sys.executable, os.path.join(root, "tools", "bench_gate.py"),
             "compare", str(cp), str(bp)],
            capture_output=True, text=True)
        assert r.returncode == 1, r.stdout + r.stderr
        assert "REGRESSION" in r.stderr

    def test_within_threshold_passes(self):
        import sys
        import os
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "tools"))
        import bench_gate
        base = self._report(decode_tok_per_s=(100.0, "higher"),
                            max_decode_gap_ms=(10.0, "lower"))
        cur = self._report(decode_tok_per_s=(85.0, "higher"),
                           max_decode_gap_ms=(11.5, "lower"))
        assert bench_gate.compare(cur, base, threshold=0.20) == []
        # lower-is-better direction regresses upward
        worse = self._report(decode_tok_per_s=(100.0, "higher"),
                             max_decode_gap_ms=(13.0, "lower"))
        regs = bench_gate.compare(worse, base, threshold=0.20)
        assert len(regs) == 1 and "max_decode_gap_ms" in regs[0]

    def test_missing_metrics_are_skipped(self):
        import sys
        import os
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "tools"))
        import bench_gate
        base = self._report(old_metric=(5.0, "lower"))
        cur = self._report(new_metric=(1.0, "lower"))
        assert bench_gate.compare(cur, base, threshold=0.2) == []

    def test_new_and_dropped_metrics_are_reported_not_failed(self):
        """A metric present only in the current run (first run of a
        fresh bench, e.g. serving_tp.*) must neither fail the gate nor
        vanish silently — ``schema_drift`` names it as ``new``; one
        only in the baseline is named ``dropped``."""
        import sys
        import os
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "tools"))
        import bench_gate
        base = self._report(decode_tok_per_s=(100.0, "higher"),
                            old_metric=(5.0, "lower"))
        cur = self._report(decode_tok_per_s=(99.0, "higher"),
                           tp_decode_tok_per_s=(450.0, "higher"))
        drift = bench_gate.schema_drift(cur, base)
        assert len(drift) == 2
        assert any(d.startswith("tp_decode_tok_per_s: new metric")
                   and "450" in d for d in drift)
        assert any(d.startswith("old_metric: dropped metric")
                   and "5" in d for d in drift)
        assert bench_gate.schema_drift(cur, cur) == []

    def test_compare_cli_prints_new_metric_and_passes(self, tmp_path):
        import json
        import subprocess
        import sys
        import os
        root = os.path.join(os.path.dirname(__file__), "..")
        base = self._report(decode_tok_per_s=(100.0, "higher"))
        cur = self._report(decode_tok_per_s=(100.0, "higher"),
                           tp_decode_tok_per_s=(450.0, "higher"))
        bp, cp = tmp_path / "base.json", tmp_path / "cur.json"
        bp.write_text(json.dumps(base))
        cp.write_text(json.dumps(cur))
        r = subprocess.run(
            [sys.executable, os.path.join(root, "tools", "bench_gate.py"),
             "compare", str(cp), str(bp)],
            capture_output=True, text=True)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "tp_decode_tok_per_s: new metric" in r.stdout
        assert "OK" in r.stdout

    def test_run_baseline_is_the_outfile_itself(self, tmp_path):
        """The committed BENCH_PR3.json must be read as the baseline
        BEFORE a run overwrites it — otherwise the wired gate can
        never fire (it would exclude its own output and find nothing
        to compare against)."""
        import json
        import sys
        import os
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                        "tools"))
        import bench_gate
        out = tmp_path / "BENCH_PR3.json"
        committed = self._report(decode_tok_per_s=(100.0, "higher"))
        out.write_text(json.dumps(committed))
        base, name = bench_gate.load_baseline(str(tmp_path), str(out))
        assert base == committed and "previous" in name
        # without the out-file, fall back to the newest other BENCH_*
        out.unlink()
        other = tmp_path / "BENCH_OLD.json"
        other.write_text(json.dumps(committed))
        base, name = bench_gate.load_baseline(str(tmp_path), str(out))
        assert base == committed and name == "BENCH_OLD.json"
        other.unlink()
        assert bench_gate.load_baseline(str(tmp_path), str(out))[0] is None
