"""Blockwise attention: oracle equivalence + hypothesis invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.attention import attention_reference, flash_attention


def _rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@pytest.mark.parametrize("chunk", [7, 16, 64])
@pytest.mark.parametrize("window", [0, 5])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_reference(chunk, window, causal):
    B, S, Hq, Hkv, D = 2, 33, 4, 2, 16
    q, k, v = (_rand((B, S, Hq, D), 0), _rand((B, S, Hkv, D), 1),
               _rand((B, S, Hkv, D), 2))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          chunk=chunk)
    ref = attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


@given(s=st.integers(2, 40), hq=st.sampled_from([1, 2, 4, 8]),
       g=st.sampled_from([1, 2, 4]), chunk=st.integers(3, 24))
@settings(max_examples=25, deadline=None)
@pytest.mark.slow
def test_flash_gqa_property(s, hq, g, chunk):
    B, D = 1, 8
    hkv = hq
    q = _rand((B, s, hq * g, D), s)
    k = _rand((B, s, hkv, D), s + 1)
    v = _rand((B, s, hkv, D), s + 2)
    out = flash_attention(q, k, v, causal=True, chunk=chunk)
    ref = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_rows_are_convex_combinations():
    """Attention outputs lie in the convex hull of V rows."""
    B, S, H, D = 1, 12, 2, 4
    q, k = _rand((B, S, H, D), 0), _rand((B, S, H, D), 1)
    v = np.ones((B, S, H, D), np.float32)
    out = np.asarray(flash_attention(q, k, v, causal=True, chunk=4))
    np.testing.assert_allclose(out, 1.0, rtol=1e-5)


def test_kv_positions_ring_equivalence():
    """A rotated ring cache with explicit positions gives the same
    result as the linear cache."""
    B, S, H, D, M = 1, 10, 2, 8, 16
    q1 = _rand((B, 1, H, D), 3)
    k = _rand((B, S, H, D), 4)
    v = _rand((B, S, H, D), 5)
    # linear layout
    klin = np.zeros((B, M, H, D), np.float32)
    vlin = np.zeros((B, M, H, D), np.float32)
    klin[:, :S], vlin[:, :S] = k, v
    pos_lin = np.concatenate([np.arange(S), -np.ones(M - S)]).astype(np.int32)
    out_lin = flash_attention(q1, klin, vlin, causal=True, q_offset=S - 1,
                              kv_positions=jnp.asarray(pos_lin), chunk=8)
    # rotated ring layout (shift 5)
    shift = 5
    kr = np.roll(klin, shift, axis=1)
    vr = np.roll(vlin, shift, axis=1)
    pos_r = np.roll(pos_lin, shift)
    out_ring = flash_attention(q1, kr, vr, causal=True, q_offset=S - 1,
                               kv_positions=jnp.asarray(pos_r), chunk=8)
    np.testing.assert_allclose(np.asarray(out_lin), np.asarray(out_ring),
                               rtol=1e-5, atol=1e-6)


@given(n_shards=st.sampled_from([2, 4]), s=st.integers(8, 32))
@settings(max_examples=15, deadline=None)
def test_partial_combine_equals_full(n_shards, s):
    """Flash-decoding LSE merge over sequence shards == full attention."""
    B, H, D = 1, 2, 8
    s = (s // n_shards) * n_shards
    q = _rand((B, 1, H, D), 0)
    k = _rand((B, s, H, D), 1)
    v = _rand((B, s, H, D), 2)
    full = flash_attention(q, k, v, causal=True, q_offset=s - 1, chunk=8)
    size = s // n_shards
    parts = [flash_attention(q, k[:, i * size:(i + 1) * size],
                             v[:, i * size:(i + 1) * size], causal=True,
                             q_offset=s - 1, kv_offset=i * size, chunk=8,
                             return_partial=True)
             for i in range(n_shards)]
    m = np.max([p.m for p in parts], axis=0)
    num = sum(np.asarray(p.out) * np.exp(np.asarray(p.m) - m)[..., None]
              for p in parts)
    den = sum(np.asarray(p.lsum) * np.exp(np.asarray(p.m) - m) for p in parts)
    merged = num / np.where(den > 0, den, 1.0)[..., None]
    np.testing.assert_allclose(merged, np.asarray(full, np.float32),
                               rtol=1e-4, atol=1e-5)
