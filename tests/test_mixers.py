"""MoE dispatch, Mamba-2 SSD and RG-LRU: oracle equivalence + continuation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.moe import init_moe, moe, moe_dense, moe_scatter
from repro.models.recurrent import (init_rglru_block, rglru_block, rglru_scan,
                                    rglru_step)
from repro.models.ssm import (ssd_chunked, ssd_decode_step, ssd_reference)


class TestMoE:
    @pytest.mark.parametrize("E,k", [(4, 1), (4, 2), (8, 2)])
    def test_scatter_equals_dense_with_slack(self, E, k):
        key = jax.random.PRNGKey(0)
        d, f = 16, 32
        p = init_moe(key, d, f, E, "silu", jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, d))
        yd, auxd = moe_dense(p, x, k=k, act="silu")
        ys, auxs = moe_scatter(p, x, k=k, act="silu", capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yd),
                                   rtol=1e-4, atol=1e-5)
        assert float(auxd) == pytest.approx(float(auxs), rel=1e-5)

    def test_aux_loss_lower_bound(self):
        """Load-balance loss >= 1 (perfectly balanced router)."""
        p = init_moe(jax.random.PRNGKey(2), 8, 16, 4, "silu", jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(3), (64, 8))
        _, aux = moe(p, x, k=2, act="silu", impl="dense")
        assert float(aux) >= 0.95

    @given(cf=st.floats(0.3, 1.0))
    @settings(max_examples=10, deadline=None)
    def test_capacity_drops_are_graceful(self, cf):
        p = init_moe(jax.random.PRNGKey(4), 8, 16, 4, "silu", jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(5), (3, 11, 8))
        y, _ = moe_scatter(p, x, k=2, act="silu", capacity_factor=cf)
        assert not np.isnan(np.asarray(y)).any()

    def test_gelu_experts(self):
        p = init_moe(jax.random.PRNGKey(6), 8, 16, 4, "gelu", jnp.float32)
        assert "w_gate" not in p
        x = jax.random.normal(jax.random.PRNGKey(7), (2, 5, 8))
        yd, _ = moe(p, x, k=2, act="gelu", impl="dense")
        ys, _ = moe(p, x, k=2, act="gelu", impl="scatter",
                    capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(ys), np.asarray(yd),
                                   rtol=1e-4, atol=1e-5)


class TestSSD:
    def _inputs(self, B, T, H, P, G, N, seed=0):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(B, T, H, P)).astype(np.float32)
        dt = (np.abs(rng.normal(size=(B, T, H))) * 0.1 + 0.01
              ).astype(np.float32)
        A = -np.abs(rng.normal(size=(H,))).astype(np.float32)
        Bm = (rng.normal(size=(B, T, G, N)) * 0.3).astype(np.float32)
        Cm = (rng.normal(size=(B, T, G, N)) * 0.3).astype(np.float32)
        return map(jnp.asarray, (x, dt, A, Bm, Cm))

    @given(t=st.integers(3, 40), chunk=st.sampled_from([2, 4, 8, 16]),
           g=st.sampled_from([1, 2]))
    @settings(max_examples=20, deadline=None)
    @pytest.mark.slow
    def test_chunked_equals_recurrent(self, t, chunk, g):
        x, dt, A, Bm, Cm = self._inputs(1, t, 2 * g, 4, g, 8, seed=t)
        y, st_ = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
        yr, str_ = ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(st_), np.asarray(str_),
                                   rtol=1e-3, atol=1e-4)

    def test_prefill_then_decode_continuation(self):
        x, dt, A, Bm, Cm = self._inputs(2, 19, 4, 8, 2, 16)
        y1, state = ssd_chunked(x[:, :10], dt[:, :10], A, Bm[:, :10],
                                Cm[:, :10], chunk=4)
        ys = []
        for t in range(10, 19):
            yt, state = ssd_decode_step(state, x[:, t], dt[:, t], A,
                                        Bm[:, t], Cm[:, t])
            ys.append(np.asarray(yt))
        yr, _ = ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.stack(ys, 1),
                                   np.asarray(yr)[:, 10:],
                                   rtol=1e-4, atol=1e-5)

    def test_initial_state_threading(self):
        x, dt, A, Bm, Cm = self._inputs(1, 16, 2, 4, 1, 8, seed=9)
        _, s_half = ssd_chunked(x[:, :8], dt[:, :8], A, Bm[:, :8],
                                Cm[:, :8], chunk=4)
        y2, s_full = ssd_chunked(x[:, 8:], dt[:, 8:], A, Bm[:, 8:],
                                 Cm[:, 8:], chunk=4, initial_state=s_half)
        y_ref, s_ref = ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y2),
                                   np.asarray(y_ref)[:, 8:],
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_ref),
                                   rtol=1e-3, atol=1e-4)


class TestRGLRU:
    def test_scan_equals_stepwise(self):
        p = init_rglru_block(jax.random.PRNGKey(0), 16, 24, 4, jnp.float32)
        r = jax.random.normal(jax.random.PRNGKey(1), (2, 13, 24))
        y_scan, hT = rglru_scan(p, r)
        h = jnp.zeros((2, 24), jnp.float32)
        ys = []
        for t in range(13):
            out, h = rglru_step(p, r[:, t], h)
            ys.append(out)
        np.testing.assert_allclose(np.asarray(y_scan),
                                   np.asarray(jnp.stack(ys, 1)),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(h),
                                   rtol=1e-5, atol=1e-6)

    def test_block_continuation(self):
        p = init_rglru_block(jax.random.PRNGKey(2), 16, 24, 4, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 16))
        y_full, _ = rglru_block(p, x)
        y1, state = rglru_block(p, x[:, :7])
        outs = [np.asarray(y1)]
        for t in range(7, 12):
            yt, state = rglru_block(p, x[:, t:t + 1], state=state,
                                    single_step=True)
            outs.append(np.asarray(yt))
        np.testing.assert_allclose(np.concatenate(outs, 1),
                                   np.asarray(y_full), rtol=1e-5,
                                   atol=1e-5)

    @given(t=st.integers(2, 24))
    @settings(max_examples=15, deadline=None)
    @pytest.mark.slow
    def test_state_is_contraction(self, t):
        """|a_t| < 1 => recurrence is stable (no state blow-up)."""
        p = init_rglru_block(jax.random.PRNGKey(4), 8, 12, 4, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(t), (1, t, 8)) * 5.0
        _, state = rglru_block(p, x)
        assert np.all(np.isfinite(np.asarray(state.h)))
