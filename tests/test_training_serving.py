"""Training loop, optimizer, checkpointing, data pipeline, serving."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data.pipeline import PackedLMDataset, synth_corpus
from repro.data.tokenizer import ByteTokenizer
from repro.models import ModelConfig, build_model
from repro.serving.engine import Request, ServingEngine, throughput_report
from repro.serving.sampler import SamplingParams, sample
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.loop import train
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      cosine_lr)


@pytest.fixture(scope="module")
def tiny():
    cfg = ModelConfig(name="tiny", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=259, dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class TestOptimizer:
    def test_cosine_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        lrs = [float(cosine_lr(cfg, jnp.asarray(s)))
               for s in [0, 5, 10, 55, 100]]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert lrs[2] > lrs[3] > lrs[4]
        assert lrs[4] == pytest.approx(0.1, rel=1e-3)

    @given(gscale=st.floats(0.1, 100.0))
    @settings(max_examples=10, deadline=None)
    def test_clipping_bounds_update(self, gscale):
        params = {"w": jnp.ones((4, 4))}
        grads = {"w": jnp.full((4, 4), gscale)}
        cfg = AdamWConfig(lr=0.1, clip_norm=1.0, warmup_steps=0,
                          total_steps=10, weight_decay=0.0)
        state = adamw_init(params)
        new, state, metrics = adamw_update(cfg, grads, state, params)
        assert float(metrics["grad_norm"]) == pytest.approx(4 * gscale,
                                                            rel=1e-4)
        # post-clip grad norm <= 1 => first-step update magnitude ~ lr
        delta = np.abs(np.asarray(new["w"] - params["w"])).max()
        assert delta <= 0.11

    def test_no_decay_on_vectors(self):
        params = {"w": jnp.ones((4, 4)), "g": jnp.ones((4,))}
        grads = {"w": jnp.zeros((4, 4)), "g": jnp.zeros((4,))}
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=0,
                          total_steps=10, clip_norm=1e9)
        new, _, _ = adamw_update(cfg, grads, adamw_init(params), params)
        assert np.asarray(new["w"]).max() < 1.0    # decayed
        np.testing.assert_allclose(np.asarray(new["g"]), 1.0)  # not


class TestTraining:
    def test_loss_decreases(self, tiny):
        cfg, model, params = tiny
        ds = PackedLMDataset(seq_len=32, n_docs=300,
                             vocab_size=cfg.vocab_size)
        _, _, hist = train(model, params, ds.batches(8),
                           AdamWConfig(lr=1e-3, warmup_steps=5,
                                       total_steps=40),
                           steps=40, log_every=10)
        assert hist[-1]["loss"] < hist[0]["loss"] * 0.9

    def test_chunked_loss_matches_dense_ce(self, tiny):
        """The chunked CE must equal naive full-logit CE."""
        cfg, model, params = tiny
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 19), 0,
                                    cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}
        loss, metrics = model.loss(params, batch)
        logits, _ = model.forward(params, batch)
        lf = np.asarray(logits, np.float32)
        logz = np.log(np.exp(lf - lf.max(-1, keepdims=True)).sum(-1)) \
            + lf.max(-1)
        gold = np.take_along_axis(lf, np.asarray(tokens)[..., None],
                                  -1)[..., 0]
        want = float((logz - gold).mean())
        assert float(metrics["ce"]) == pytest.approx(want, rel=1e-4)

    def test_remat_matches_no_remat(self):
        import dataclasses
        cfg = ModelConfig(name="r", arch_type="dense", n_layers=2,
                          d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                          vocab_size=64, dtype=jnp.float32)
        m1 = build_model(cfg)
        m2 = build_model(dataclasses.replace(cfg, remat=True))
        params = m1.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
        batch = {"tokens": tokens, "labels": tokens}
        g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
        g2 = jax.grad(lambda p: m2.loss(p, batch)[0])(params)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


class TestCheckpoint:
    def test_roundtrip(self, tiny, tmp_path):
        cfg, model, params = tiny
        opt = adamw_init(params)
        path = str(tmp_path / "ck")
        save_checkpoint(path, 7, {"params": params, "opt": opt})
        step, out = load_checkpoint(path, {"params": params, "opt": opt})
        assert step == 7
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(out["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestData:
    def test_packing_no_waste(self):
        ds = PackedLMDataset(seq_len=64, n_docs=100)
        row = ds.row(0)
        assert row["tokens"].shape == (64,)
        # labels are next-token shifted
        np.testing.assert_array_equal(ds.row(0)["labels"][:-1],
                                      ds.row(0)["tokens"][1:])

    def test_tokenizer_roundtrip(self):
        tok = ByteTokenizer()
        s = "the scheduler binds local memory."
        assert tok.decode(tok.encode(s)) == s

    def test_deterministic(self):
        a = synth_corpus(10, seed=3)
        b = synth_corpus(10, seed=3)
        assert a == b


class TestServing:
    def test_greedy_matches_forward_argmax(self, tiny):
        """The engine's first sampled token == argmax of full forward."""
        cfg, model, params = tiny
        prompt = [1, 2, 3, 4, 5]
        eng = ServingEngine(model, params, max_len=32)
        comps = eng.generate([Request(uid=0, prompt=prompt,
                                      sampling=SamplingParams(
                                          max_new_tokens=1))])
        batch = {"tokens": jnp.asarray([prompt]),
                 "labels": jnp.asarray([prompt])}
        logits, _ = model.forward(params, batch)
        want = int(jnp.argmax(logits[0, -1]))
        assert comps[0].tokens[0] == want

    def test_bucketing_by_length(self, tiny):
        cfg, model, params = tiny
        eng = ServingEngine(model, params, max_len=32)
        reqs = [Request(uid=i, prompt=[1] * (3 + i % 2),
                        sampling=SamplingParams(max_new_tokens=2))
                for i in range(6)]
        buckets = eng._buckets(reqs, max_batch=2)
        assert all(len({len(r.prompt) for r in b}) == 1 for b in buckets)
        assert all(len(b) <= 2 for b in buckets)
        comps = eng.generate(reqs, max_batch=2)
        assert [c.uid for c in comps] == list(range(6))

    def test_eos_stops(self, tiny):
        cfg, model, params = tiny
        eng = ServingEngine(model, params, max_len=64)
        batch = {"tokens": jnp.asarray([[1, 2, 3]]),
                 "labels": jnp.asarray([[1, 2, 3]])}
        logits, _ = model.forward(params, batch)
        eos = int(jnp.argmax(logits[0, -1]))  # force eos == first token
        comps = eng.generate([Request(
            uid=0, prompt=[1, 2, 3],
            sampling=SamplingParams(max_new_tokens=16, eos_id=eos))])
        assert len(comps[0].tokens) == 1

    def test_sampler_top_k(self):
        logits = jnp.asarray([[[0.0, 1.0, 2.0, 3.0]]])
        for seed in range(5):
            t = sample(logits, SamplingParams(temperature=1.0, top_k=2),
                       jax.random.PRNGKey(seed))
            assert int(t[0, 0]) in (2, 3)

    def test_throughput_report(self, tiny):
        cfg, model, params = tiny
        eng = ServingEngine(model, params, max_len=32)
        comps = eng.generate([Request(uid=0, prompt=[1, 2, 3],
                                      sampling=SamplingParams(
                                          max_new_tokens=4))])
        rep = throughput_report(comps)
        assert rep["new_tokens"] == 4 and rep["requests"] == 1
