"""Fault-injection harness (PR: SLO-aware overload protection).

``repro.serving.faults`` is the switchboard every chaos test and the
``tools/check.sh`` chaos smoke lane arm failures through, so its own
contract gets pinned here: arming/disarming semantics, the zero-cost
``ACTIVE`` fast path, deterministic every-N-th firing, ``REPRO_FAULTS``
environment parsing, and the two in-tree integration points that need
no model — the HTTP front-end's lossy-stream fault and the scheduler's
injected pool exhaustion.
"""

import json
import time

import pytest

from repro.obs import MetricsRegistry
from repro.serving import (ContinuousScheduler, KVCachePool, KVPoolConfig,
                           Request, SamplingParams, faults)


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.reset()
    yield
    faults.reset()


class TestRegistry:
    def test_arm_disarm_and_active_flag(self):
        assert not faults.ACTIVE
        faults.arm("step.latency_ms", 5)
        assert faults.ACTIVE
        assert faults.armed("step.latency_ms")
        assert faults.value("step.latency_ms") == 5.0
        faults.arm("http.drop_sse", 2)
        faults.disarm("step.latency_ms")
        assert faults.ACTIVE            # one point still armed
        faults.disarm("http.drop_sse")
        assert not faults.ACTIVE
        assert faults.value("step.latency_ms", 7.0) == 7.0

    def test_reset_clears_everything(self):
        faults.arm("pool.exhaust", 1)
        faults.should_fire("pool.exhaust")
        faults.reset()
        assert not faults.ACTIVE
        assert not faults.armed("pool.exhaust")
        assert faults.hits("pool.exhaust") == 0

    def test_should_fire_every_nth_is_deterministic(self):
        faults.arm("http.drop_sse", 3)
        fired = [faults.should_fire("http.drop_sse") for _ in range(9)]
        assert fired == [False, False, True] * 3
        assert faults.hits("http.drop_sse") == 3

    def test_should_fire_unarmed_is_false(self):
        assert not faults.should_fire("http.drop_sse")
        assert faults.hits("http.drop_sse") == 0

    def test_maybe_sleep_sleeps_and_counts(self):
        faults.arm("step.latency_ms", 30)
        t0 = time.perf_counter()
        faults.maybe_sleep("step.latency_ms")
        assert time.perf_counter() - t0 >= 0.025
        assert faults.hits("step.latency_ms") == 1

    def test_maybe_sleep_unarmed_is_free(self):
        t0 = time.perf_counter()
        faults.maybe_sleep("step.latency_ms")
        assert time.perf_counter() - t0 < 0.02
        assert faults.hits("step.latency_ms") == 0


class TestLoadEnv:
    def test_parses_pairs_and_skips_garbage(self, monkeypatch):
        monkeypatch.setenv(faults.ENV_VAR,
                           "step.latency_ms=40, http.drop_sse=3,"
                           "bogus, nope=abc, =5")
        assert faults.load_env() == 2
        assert faults.value("step.latency_ms") == 40.0
        assert faults.value("http.drop_sse") == 3.0
        assert not faults.armed("bogus")

    def test_empty_env_is_noop(self, monkeypatch):
        monkeypatch.delenv(faults.ENV_VAR, raising=False)
        assert faults.load_env() == 0
        assert not faults.ACTIVE


# ----------------------------------------------------------------------
# integration: injected pool exhaustion blocks scheduler admission
# ----------------------------------------------------------------------
def _pool(n_pages=17, page_size=4):
    return KVCachePool(KVPoolConfig(
        n_pages=n_pages, page_size=page_size, n_layers=2, n_kv_heads=2,
        head_dim=8, dtype_bytes=4))


class TestPoolExhaustFault:
    def test_admission_fails_while_armed(self):
        sched = ContinuousScheduler(_pool(), max_running=2, max_len=64)
        sched.submit(Request(uid=0, prompt=[1, 2, 3],
                             sampling=SamplingParams(max_new_tokens=2)))
        faults.arm("pool.exhaust", 1)       # every admission attempt
        plan = sched.step()
        assert not plan.prefills and not sched.running
        assert faults.hits("pool.exhaust") == 1
        faults.disarm("pool.exhaust")
        plan = sched.step()
        assert len(sched.running) == 1      # heals once disarmed


# ----------------------------------------------------------------------
# integration: the HTTP front-end's lossy-stream fault
# ----------------------------------------------------------------------
class TestDropSseFault:
    def test_dropped_frames_still_counted_in_done(self):
        from test_http_serving import FakeBackend, _post, _read_sse
        from repro.serving.http import HttpFrontend

        faults.arm("http.drop_sse", 2)      # lose every 2nd token frame
        fe = HttpFrontend(FakeBackend([11, 12, 13, 14])).start()
        try:
            conn, resp = _post(fe, {"prompt": [1, 2, 3],
                                    "max_tokens": 4, "stream": True})
            assert resp.status == 200
            _, events = _read_sse(resp)
            conn.close()
        finally:
            fe.close()
        toks = [e["token"] for e in events if "token" in e]
        done = [e for e in events if "done" in e][0]["done"]
        # the wire lost frames; the done frame reports the true count —
        # exactly the mismatch the router's lossy-stream check catches
        assert toks == [11, 13]
        assert done["completion_tokens"] == 4
        assert faults.hits("http.drop_sse") == 2

    def test_scrape_fault_slows_metrics_endpoint(self):
        import http.client

        from test_http_serving import FakeBackend
        from repro.serving.http import HttpFrontend

        faults.arm("http.scrape_ms", 40)
        fe = HttpFrontend(FakeBackend()).start()
        try:
            conn = http.client.HTTPConnection(fe.host, fe.port, timeout=5)
            t0 = time.perf_counter()
            conn.request("GET", "/metrics.json")
            body = conn.getresponse().read()
            assert time.perf_counter() - t0 >= 0.03
            json.loads(body)
            conn.close()
        finally:
            fe.close()
        assert faults.hits("http.scrape_ms") == 1
