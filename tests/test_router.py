"""Multi-replica router (PR: HTTP front-end + prefix-affinity router).

Three layers, mirroring the subsystem:

* **AffinityRing properties** (fast, hypothesis-compat shim): same key
  -> same live replica, deterministic across instances; a replica's
  death remaps exactly its own keyspace; the least-loaded fallback can
  never pick a dead replica.
* **Router semantics over in-process fake workers** (fast, no
  subprocesses): token delivery, affinity placement, retry-on-death
  for zero-token requests, FAILED-with-chained-cause for mid-stream
  death, cancellation, metrics.
* **Fault injection over real worker subprocesses** (``slow``):
  SIGKILL a worker mid-stream and mid-queue — in-flight handles FAIL
  with the death chained, zero-token requests retry on the survivor,
  the ring drains the dead replica, the fleet leaves no orphans after
  ``shutdown()``, and greedy tokens over the full HTTP stack match the
  in-process engine byte-for-byte.
"""

import http.client
import json
import random
import threading
import time

import pytest
from _hypothesis_compat import given, settings, st

from repro.serving import (NoReplicasError, Request, RouterError,
                           SamplingParams, WorkerDiedError,
                           prefix_chain_key)
from repro.serving.async_engine import RequestState
from repro.serving.router import (AffinityRing, Router, _mix64,
                                  pick_least_loaded)

# ----------------------------------------------------------------------
# affinity ring properties
# ----------------------------------------------------------------------
KEYS = st.integers(min_value=0, max_value=(1 << 64) - 1)
RIDS = st.lists(st.integers(min_value=0, max_value=31), min_size=1,
                max_size=8)


class TestAffinityRing:
    @settings(max_examples=50)
    @given(KEYS, RIDS)
    def test_pick_is_deterministic_across_instances(self, key, rids):
        a, b = AffinityRing(rids), AffinityRing(reversed(rids))
        assert a.pick(key) == b.pick(key)
        assert a.pick(key) in a.live()

    @settings(max_examples=30)
    @given(st.lists(KEYS, min_size=1, max_size=40), RIDS)
    def test_death_remaps_only_the_dead_replicas_keys(self, keys, rids):
        ring = AffinityRing(rids)
        before = {k: ring.pick(k) for k in keys}
        victim = sorted(set(rids))[0]
        ring.remove(victim)
        if not ring.live():
            return
        for k in keys:
            after = ring.pick(k)
            if before[k] != victim:
                # survivors' keyspaces never move (their prefix pages
                # stay warm) ...
                assert after == before[k]
            else:
                # ... and the dead replica's keys land on a survivor
                assert after != victim and after in ring.live()

    @settings(max_examples=30)
    @given(st.lists(KEYS, min_size=1, max_size=40), RIDS)
    def test_rejoin_restores_the_original_map(self, keys, rids):
        ring = AffinityRing(rids)
        before = {k: ring.pick(k) for k in keys}
        victim = max(rids)
        ring.remove(victim)
        ring.add(victim)
        assert {k: ring.pick(k) for k in keys} == before

    def test_empty_ring_raises(self):
        ring = AffinityRing([1])
        ring.remove(1)
        with pytest.raises(NoReplicasError):
            ring.pick(123)

    def test_mix64_spreads_consecutive_keys(self):
        picks = {AffinityRing(range(4)).pick(k) for k in range(64)}
        assert picks == set(range(4))    # not all on one replica
        assert len({_mix64(x) for x in range(1000)}) == 1000

    @settings(max_examples=50)
    @given(RIDS, st.lists(st.integers(min_value=0, max_value=31),
                          max_size=4),
           st.integers(min_value=0, max_value=999))
    def test_least_loaded_never_picks_a_dead_replica(self, rids, dead,
                                                     seed):
        live = sorted(set(rids) - set(dead))
        if not live:
            return
        inflight = {r: r % 3 for r in set(rids) | set(dead)}
        rng = random.Random(seed)
        assert pick_least_loaded(live, inflight, rng) in live

    def test_least_loaded_prefers_the_lighter_of_two(self):
        rng = random.Random(0)
        got = [pick_least_loaded([0, 1], {0: 5, 1: 0}, rng)
               for _ in range(20)]
        assert all(g == 1 for g in got)

    def test_least_loaded_accepts_a_score_callable(self):
        # the router passes its /metrics.json scrape as a callable;
        # lower score wins regardless of what the tuple encodes
        rng = random.Random(0)
        score = {0: (3.0, -2.0), 1: (0.0, -9.0)}
        got = [pick_least_loaded([0, 1], lambda r: score[r], rng)
               for _ in range(20)]
        assert all(g == 1 for g in got)


class TestPrefixChainKey:
    def test_same_full_blocks_same_key_despite_tail(self):
        a = prefix_chain_key(list(range(32)) + [99, 98], 16)
        b = prefix_chain_key(list(range(32)) + [1], 16)
        assert a is not None and a == b

    def test_short_prompt_has_no_key(self):
        assert prefix_chain_key([1, 2, 3], 16) is None

    def test_max_blocks_caps_the_chain(self):
        base = list(range(32))
        a = prefix_chain_key(base + list(range(100, 116)), 16,
                             max_blocks=2)
        b = prefix_chain_key(base + list(range(200, 216)), 16,
                             max_blocks=2)
        assert a == b
        assert (prefix_chain_key(base + list(range(100, 116)), 16)
                != prefix_chain_key(base + list(range(200, 216)), 16))

    def test_matches_prefix_cache_chain_scheme(self):
        from repro.serving.kv_pool import _CHAIN_ROOT
        toks = list(range(16))
        assert prefix_chain_key(toks, 16) == hash((_CHAIN_ROOT,
                                                   tuple(toks)))


# ----------------------------------------------------------------------
# router over in-process fake workers
# ----------------------------------------------------------------------
class FakeWorker:
    """In-process stand-in for HttpWorkerClient: replays a token list,
    optionally 'dying' (broken connection) after ``die_after`` tokens."""

    def __init__(self, tokens=(11, 12, 13), *, die_after=None,
                 delay=0.0):
        self.tokens = list(tokens)
        self.die_after = die_after
        self.delay = delay
        self.bodies = []
        self._alive = True

    def alive(self):
        return self._alive

    def describe(self):
        return "fake"

    def stream_completion(self, body, *, timeout):
        self.bodies.append(body)
        out = self.tokens[:int(body["max_tokens"])]
        for i, t in enumerate(out):
            if self.die_after is not None and i >= self.die_after:
                self._alive = False
                raise WorkerDiedError("fake worker died")
            if self.delay:
                time.sleep(self.delay)
            yield {"index": 0, "text": "", "token": t}
        if self.die_after is not None and self.die_after >= len(out):
            self._alive = False
            raise WorkerDiedError("fake worker died at the end")
        yield {"done": {"prompt_tokens": len(body["prompt"]),
                        "completion_tokens": len(out),
                        "finish_reason": "length"}}


def _req(prompt, max_new=3):
    return Request(uid=0, prompt=prompt,
                   sampling=SamplingParams(max_new_tokens=max_new))


KEYED = list(range(1, 33))      # two full 16-token blocks -> keyed


class TestRouterFakeWorkers:
    def test_tokens_and_completion_round_trip(self):
        r = Router({0: FakeWorker([5, 6, 7])}, page_size=16)
        h = r.submit(_req(KEYED, max_new=3))
        assert list(r.stream(h, timeout=5)) == [5, 6, 7]
        comp = r.result(h, timeout=5)
        assert comp.tokens == [5, 6, 7]
        assert comp.prompt_len == len(KEYED)
        assert h.state is RequestState.FINISHED
        r.shutdown()

    def test_on_token_fires_per_token(self):
        r = Router({0: FakeWorker([5, 6])}, page_size=16)
        got = []
        h = r.submit(_req(KEYED, max_new=2), on_token=got.append)
        r.result(h, timeout=5)
        assert got == [5, 6]
        r.shutdown()

    def test_same_prefix_same_replica(self):
        workers = {i: FakeWorker() for i in range(4)}
        r = Router(workers, page_size=16)
        tails = ([], [77], [88, 89])
        handles = [r.submit(_req(KEYED + t)) for t in tails]
        for h in handles:
            r.result(h, timeout=5)
        assert len({h.replica for h in handles}) == 1
        snap = json.loads(r.registry.snapshot_json())
        counters = {(c["name"], tuple(sorted(c["labels"].items()))):
                    c["value"] for c in snap["counters"]}
        assert counters[("router.affinity.keyed", ())] == 3
        assert counters[("router.affinity.hits", ())] == 2
        r.shutdown()

    def test_unkeyed_uses_least_loaded_fallback(self):
        workers = {0: FakeWorker(), 1: FakeWorker()}
        r = Router(workers, page_size=16, seed=3)
        h = r.submit(_req([1, 2, 3]))        # < one block: no key
        r.result(h, timeout=5)
        assert h.replica in (0, 1)
        assert r.registry.get("router.affinity.keyed").value() == 0
        r.shutdown()

    def test_zero_token_death_retries_on_survivor(self):
        # the keyed replica dies before any token: the request never
        # produced state, so it must re-run elsewhere
        first = AffinityRing([0, 1]).pick(
            prefix_chain_key(KEYED, 16, max_blocks=2))
        good = FakeWorker([9, 9, 9])
        workers = {first: FakeWorker(die_after=0), 1 - first: good}
        r = Router(workers, page_size=16)
        h = r.submit(_req(KEYED))
        comp = r.result(h, timeout=5)
        assert comp.tokens == [9, 9, 9]
        assert h.n_retries == 1 and h.replica == 1 - first
        assert r.health()["live"] == 1
        assert first not in r.ring
        r.shutdown()

    def test_midstream_death_fails_with_chained_cause(self):
        r = Router({0: FakeWorker(die_after=2)}, page_size=16)
        h = r.submit(_req(KEYED, max_new=5))
        with pytest.raises(RouterError) as ei:
            list(r.stream(h, timeout=5))
        assert h.state is RequestState.FAILED
        cause = ei.value.__cause__
        assert isinstance(cause, WorkerDiedError)
        assert "mid-stream" in str(cause)
        assert isinstance(cause.__cause__, WorkerDiedError)
        r.shutdown()

    def test_retries_are_bounded(self):
        workers = {0: FakeWorker(die_after=0), 1: FakeWorker(die_after=0),
                   2: FakeWorker(die_after=0)}
        r = Router(workers, page_size=16, max_retries=1)
        h = r.submit(_req(KEYED))
        with pytest.raises(RouterError):
            r.result(h, timeout=5)
        assert h.n_retries == 1
        r.shutdown()

    def test_all_dead_surfaces_no_replicas(self):
        r = Router({0: FakeWorker(die_after=0)}, page_size=16,
                   max_retries=5)
        h = r.submit(_req(KEYED))
        with pytest.raises(RouterError) as ei:
            r.result(h, timeout=5)
        assert isinstance(ei.value.__cause__, NoReplicasError)
        r.shutdown()

    def test_cancel_mid_stream(self):
        r = Router({0: FakeWorker([1] * 50, delay=0.02)}, page_size=16)
        h = r.submit(_req(KEYED, max_new=50))
        for _ in r.stream(h, timeout=5):
            assert r.cancel(h)
            break
        t0 = time.time()
        while not h.done and time.time() - t0 < 5:
            time.sleep(0.01)
        assert h.state is RequestState.CANCELLED
        with pytest.raises(Exception):
            r.result(h, timeout=1)
        r.shutdown()

    def test_readmit_restores_a_drained_replica(self):
        r = Router({0: FakeWorker(), 1: FakeWorker()}, page_size=16)
        assert r.mark_dead(0)
        assert r.health()["live"] == 1 and 0 not in r.ring
        fresh = FakeWorker([42, 42])
        assert r.readmit(0, fresh)
        assert r.health()["live"] == 2 and 0 in r.ring
        assert r.workers[0] is fresh
        assert not r.readmit(0)         # idempotent on a live replica
        assert not r.readmit(99)        # unknown rid
        assert r.registry.get("router.readmissions").value() == 1
        assert (r.registry.get("router.replicas_live").value() == 2)
        r.shutdown()

    def test_readmit_restores_the_original_keyspace(self):
        # rendezvous hashing: the healed replica gets exactly its old
        # keys back, so its re-warmed prefix pages are reachable again
        workers = {i: FakeWorker() for i in range(3)}
        r = Router(workers, page_size=16)
        key = r.affinity_key(KEYED)
        before = r.ring.pick(key)
        r.mark_dead(before)
        assert r.ring.pick(key) != before
        r.readmit(before, FakeWorker())
        assert r.ring.pick(key) == before
        r.shutdown()

    def test_inflight_gauge_returns_to_zero(self):
        r = Router({0: FakeWorker()}, page_size=16)
        r.result(r.submit(_req(KEYED)), timeout=5)
        snap = json.loads(r.registry.snapshot_json())
        g = [x for x in snap["gauges"]
             if x["name"] == "router.inflight"]
        assert g and all(x["value"] == 0 for x in g)
        live = [x for x in snap["gauges"]
                if x["name"] == "router.replicas_live"]
        assert live[0]["value"] == 1
        r.shutdown()


# ----------------------------------------------------------------------
# scraped load signal for the least-loaded fallback
# ----------------------------------------------------------------------
class MetricWorker(FakeWorker):
    """FakeWorker that also serves a ``/metrics.json``-shaped snapshot
    (the registry ``snapshot()`` document the real client fetches)."""

    def __init__(self, *a, queue=0.0, free=0.0, **kw):
        super().__init__(*a, **kw)
        self.queue, self.free = queue, free
        self.n_scrapes = 0

    def metrics(self):
        self.n_scrapes += 1
        return {"gauges": [
            {"name": "scheduler.queue_depth", "labels": {},
             "value": self.queue},
            {"name": "kv_pool.pages_free",
             "labels": {"node": 0, "shard": 0}, "value": self.free},
            {"name": "kv_pool.pages_free",
             "labels": {"node": 1, "shard": 0}, "value": self.free},
        ]}


UNKEYED = [1, 2, 3]     # < one full block: least-loaded fallback


class TestScrapedLoadSignal:
    def _drive(self, workers, n=8, **kw):
        r = Router(workers, page_size=16, **kw)
        picks = []
        for _ in range(n):
            h = r.submit(_req(UNKEYED))
            r.result(h, timeout=5)
            picks.append(h.replica)
        r.shutdown()
        return picks

    def test_prefers_the_shallower_queue(self):
        # replica 0 reports a deep scheduler queue; every unkeyed
        # request must land on 1 even though in-flight counts agree
        workers = {0: MetricWorker(queue=5, free=100),
                   1: MetricWorker(queue=0, free=100)}
        assert set(self._drive(workers, load_ttl=0.0)) == {1}

    def test_kv_pressure_breaks_queue_ties(self):
        # equal queues: the replica with more free KV pages wins (it
        # can admit a long prompt without preempting)
        workers = {0: MetricWorker(queue=1, free=2),
                   1: MetricWorker(queue=1, free=90)}
        assert set(self._drive(workers, load_ttl=0.0)) == {1}

    def test_scrapes_are_ttl_cached(self):
        workers = {0: MetricWorker(free=10), 1: MetricWorker(free=10)}
        self._drive(workers, n=6, load_ttl=60.0)
        assert workers[0].n_scrapes == 1 and workers[1].n_scrapes == 1
        workers = {0: MetricWorker(free=10), 1: MetricWorker(free=10)}
        self._drive(workers, n=3, load_ttl=0.0)
        assert workers[0].n_scrapes == 3 and workers[1].n_scrapes == 3

    def test_falls_back_to_inflight_without_metrics(self):
        # plain FakeWorkers have no metrics endpoint: the score
        # degrades to the router's own in-flight counts and routing
        # still works
        workers = {0: FakeWorker(), 1: FakeWorker()}
        picks = self._drive(workers, load_ttl=0.0)
        assert all(p in (0, 1) for p in picks)

    def test_mark_dead_drops_the_cached_score(self):
        r = Router({0: MetricWorker(), 1: MetricWorker()}, page_size=16,
                   load_ttl=60.0)
        r._load_score(0)
        assert 0 in r._load_cache
        r.mark_dead(0)
        assert 0 not in r._load_cache
        r.shutdown()


# ----------------------------------------------------------------------
# fault injection over real worker subprocesses (slow)
# ----------------------------------------------------------------------
def _start_fleet(n, extra=()):
    from repro.serving import Router, Supervisor
    sup = Supervisor(n, ["--arch", "tiny", *extra])
    clients = sup.start()
    router = Router(clients, page_size=16)
    sup.on_death = lambda rid, rc: router.mark_dead(rid)
    return sup, router


@pytest.mark.slow
class TestWorkerFleetFaults:
    def test_sigkill_midstream_and_midqueue(self):
        # one running slot per worker: A streams, B (same affinity key)
        # queues behind it with zero tokens when the worker dies
        sup, router = _start_fleet(2, ["--max-running", "1"])
        try:
            killed = threading.Event()

            def kill_after_3(tok, _n=[0]):
                _n[0] += 1
                if _n[0] == 3 and not killed.is_set():
                    sup.kill(a.replica)          # SIGKILL mid-stream
                    killed.set()

            a = router.submit(_req(KEYED, max_new=400),
                              on_token=kill_after_3)
            # wait until A is actually streaming so B queues behind it
            t0 = time.time()
            while not a.tokens and time.time() - t0 < 120:
                time.sleep(0.02)
            assert a.tokens, "A never started streaming"
            b = router.submit(_req(KEYED + [7], max_new=4))
            assert b.request.prompt[:32] == a.request.prompt[:32]

            # A: mid-stream death -> FAILED, cause chained
            with pytest.raises(RouterError) as ei:
                router.result(a, timeout=120)
            assert a.state is RequestState.FAILED
            assert isinstance(ei.value.__cause__, WorkerDiedError)

            # B: zero tokens -> retried on the survivor, finishes
            comp = router.result(b, timeout=120)
            assert len(comp.tokens) == 4
            assert b.replica != a.replica

            # the ring drained the dead replica; the router stays up
            # and the survivor keeps serving new work
            assert router.health()["live"] == 1
            assert a.replica not in router.ring
            c = router.submit(_req(KEYED, max_new=3))
            assert len(router.result(c, timeout=120).tokens) == 3
            assert c.replica == b.replica
        finally:
            router.shutdown()
            sup.shutdown()
        # no orphan subprocesses after shutdown()
        assert all(not alive for alive in sup.alive().values())
        assert all(p.poll() is not None for p in sup.procs.values())

    def test_sigkill_respawn_heals_the_fleet(self):
        # self-healing: SIGKILL a worker; the supervisor respawns it
        # (bounded budget), the router re-admits it to the ring, and
        # the healed replica serves its old keyspace again.  A second
        # kill exhausts the budget: the replica stays dead.
        from repro.serving import Router, Supervisor
        sup = Supervisor(2, ["--arch", "tiny"], max_respawns=1,
                         respawn_backoff=0.05)
        clients = sup.start()
        router = Router(clients, page_size=16)
        sup.on_death = lambda rid, rc: router.mark_dead(rid)
        sup.on_respawn = lambda rid, c: router.readmit(rid, c)
        try:
            victim = router.ring.pick(router.affinity_key(KEYED))
            old_proc = sup.procs[victim]
            sup.kill(victim)
            t0 = time.time()        # death noticed, then healed
            while ((sup.respawns().get(victim) != 1
                    or router.health()["live"] < 2)
                   and time.time() - t0 < 120):
                time.sleep(0.05)
            assert router.health()["live"] == 2, "fleet never healed"
            assert victim in router.ring
            assert sup.procs[victim] is not old_proc
            assert sup.alive()[victim]
            assert sup.respawns() == {victim: 1}

            # the healed replica serves its old keyspace over the wire
            h = router.submit(_req(KEYED, max_new=3))
            assert len(router.result(h, timeout=120).tokens) == 3
            assert h.replica == victim
            assert (router.registry.get("router.readmissions").value()
                    == 1)

            # budget spent: the second death stays dead
            sup.kill(victim)
            t0 = time.time()
            while router.health()["live"] > 1 and time.time() - t0 < 120:
                time.sleep(0.05)
            time.sleep(0.5)     # give a (buggy) respawn time to appear
            assert router.health()["live"] == 1
            assert not sup.alive()[victim]
            assert sup.respawns() == {victim: 1}
        finally:
            router.shutdown()
            sup.shutdown()
        assert all(p.poll() is not None for p in sup.procs.values())
        assert all(p.poll() is not None for p in sup._retired)

    def test_full_http_stack_greedy_parity(self):
        # the acceptance gate: greedy tokens over router + worker
        # subprocess + two HTTP hops == in-process AsyncEngine, and the
        # same prompt re-asked is an affinity hit
        import jax

        from repro.serving import AsyncEngine, HttpFrontend
        from repro.serving.worker import build_tiny
        sup, router = _start_fleet(2)
        fe = HttpFrontend(router).start()
        try:
            prompt = list(range(1, 25))
            body = json.dumps({"prompt": prompt, "max_tokens": 6,
                               "stream": True})
            wire = []
            for _ in range(2):      # second ask: same key, same replica
                toks = []
                conn = http.client.HTTPConnection(fe.host, fe.port,
                                                  timeout=120)
                conn.request("POST", "/v1/completions", body,
                             {"Content-Type": "application/json"})
                resp = conn.getresponse()
                while True:
                    line = resp.readline().strip()
                    if not line.startswith(b"data:"):
                        continue
                    payload = line[5:].strip()
                    if payload == b"[DONE]":
                        break
                    ev = json.loads(payload)
                    assert "error" not in ev, ev
                    if "token" in ev:
                        toks.append(ev["token"])
                conn.close()
                wire.append(toks)
            assert wire[0] == wire[1] and len(wire[0]) == 6

            model, params = build_tiny()
            with AsyncEngine(model, params, max_len=128,
                             page_size=16) as eng:
                h = eng.submit(_req(prompt, max_new=6))
                ref = list(eng.stream(h, timeout=120))
            assert wire[0] == ref, (wire[0], ref)
            del model, params
            jax.clear_caches()

            snap = json.loads(router.registry.snapshot_json())
            hits = [c for c in snap["counters"]
                    if c["name"] == "router.affinity.hits"]
            assert hits[0]["value"] >= 1
        finally:
            fe.close()
            router.shutdown()
            sup.shutdown()
        assert all(p.poll() is not None for p in sup.procs.values())
