"""Multi-device TP semantics (subprocess: forces 8 host devices).

In-process tests must see the single real CPU device, so everything
needing a real multi-device mesh runs in a child interpreter with
``--xla_force_host_platform_device_count=8``.
"""

import os
import subprocess
import sys
import textwrap

import pytest

# every test here spawns a child interpreter with 8 forced host
# devices — minutes of wall time, so the whole module is slow-lane
pytestmark = pytest.mark.slow


def _run(snippet: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(snippet)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_tp_blocks_match_reference_on_8_devices():
    print(_run("""
        import jax, numpy as np
        from repro.core import tp
        assert len(jax.devices()) == 8
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)
        d, f, t = 32, 64, 8
        params = {k: (rng.normal(size=s)*0.1).astype(np.float32)
                  for k, s in [("w_gate",(d,f)),("w_up",(d,f)),
                               ("w_down",(f,d))]}
        x = rng.normal(size=(t, d)).astype(np.float32)
        ref = tp.mlp_reference(params, x)
        for mode in ("sync_a", "sync_b"):
            blk = tp.make_tp_block(mesh, "mlp", sync_mode=mode)
            out = blk(params, x)
            assert np.allclose(out, ref, atol=1e-5), mode
        ap = {k: (rng.normal(size=(d,d))*0.1).astype(np.float32)
              for k in ("w_q","w_k","w_v","w_o")}
        refa = tp.attention_reference(ap, x, n_heads=8)
        for mode in ("sync_a", "sync_b"):
            blk = tp.make_tp_block(mesh, "attention", n_heads=8,
                                   sync_mode=mode)
            assert np.allclose(blk(ap, x), refa, atol=1e-5), mode
        print("TP-OK")
    """))


@pytest.mark.slow
def test_sharded_params_placement():
    print(_run("""
        import jax, numpy as np
        from repro.core import tp
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("model",))
        params = {"w_up": np.zeros((16, 64), np.float32),
                  "w_down": np.zeros((64, 16), np.float32),
                  "norm": np.zeros((16,), np.float32)}
        sharded = tp.shard_params(params, mesh)
        # §3.2: w_up row-partitioned (axis 1), w_down col (axis 0)
        P = jax.sharding.PartitionSpec
        assert sharded["w_up"].sharding.spec == P(None, "model")
        assert sharded["w_down"].sharding.spec == P("model", None)
        assert sharded["norm"].sharding.spec == jax.sharding.PartitionSpec()
        # node-local bytes: each device holds 1/8 of each matrix
        shard_bytes = sharded["w_up"].addressable_shards[0].data.nbytes
        assert shard_bytes == 16*64*4 // 8
        print("SHARD-OK")
    """))


@pytest.mark.slow
def test_seq_sharded_flash_decode_combine():
    """combine_partials under a real sequence-sharded mesh."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.models.attention import (flash_attention,
                                            combine_partials)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((8,), ("data",))
        B,S,H,D = 1, 64, 2, 16
        rng = np.random.default_rng(0)
        q = rng.normal(size=(B,1,H,D)).astype(np.float32)
        k = rng.normal(size=(B,S,H,D)).astype(np.float32)
        v = rng.normal(size=(B,S,H,D)).astype(np.float32)
        full = flash_attention(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), causal=True,
                               q_offset=S-1, chunk=16)
        def body(q_, k_, v_):
            size = k_.shape[1]
            idx = jax.lax.axis_index("data")
            p = flash_attention(q_, k_, v_, causal=True, q_offset=S-1,
                                kv_offset=idx*size, chunk=16,
                                return_partial=True)
            return combine_partials(p, "data", q_.dtype)
        fn = shard_map(body, mesh=mesh,
                       in_specs=(P(), P(None, "data", None, None),
                                 P(None, "data", None, None)),
                       out_specs=P(), check_rep=False)
        out = fn(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v))
        assert np.allclose(np.asarray(out), np.asarray(full),
                           atol=1e-5), np.abs(np.asarray(out)-np.asarray(full)).max()
        print("SEQSHARD-OK")
    """))


@pytest.mark.slow
def test_dryrun_reduced_case_runs():
    """End-to-end dryrun module on one pair (real 512-device lowering)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "gemma3-1b", "--shape", "decode_32k"],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "roofline:" in out.stdout and "1 ok, 0 failed" in out.stdout


@pytest.mark.slow
def test_moe_hook_tp_and_ep_match_dense_oracle():
    """shard_map MoE dispatch (TP-in-expert and expert-parallel) vs
    the dense oracle, on a real 2x4 mesh."""
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.shardings import Policy, make_moe_hook
        from repro.models.moe import init_moe, moe
        from repro.models.config import ModelConfig
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        d, f, E, k = 16, 32, 8, 2
        cfg = ModelConfig(name="m", arch_type="moe", n_layers=2,
                          d_model=d, n_heads=2, n_kv_heads=1, d_ff=f,
                          vocab_size=64, n_experts=E, experts_per_token=k,
                          capacity_factor=8.0, dtype=jnp.float32)
        params = init_moe(jax.random.PRNGKey(0), d, f, E, "silu",
                          jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 6, d),
                              jnp.float32)
        ref, _ = moe(params, x, k=k, act="silu", impl="dense")
        with mesh:
            for ep in (False, True):
                hook = make_moe_hook(cfg, mesh, Policy(expert_parallel=ep),
                                     batch_size=4)
                y, aux = jax.jit(hook)(params, x)
                err = np.abs(np.asarray(y) - np.asarray(ref)).max()
                assert err < 1e-4, (ep, err)
        print("MOE-HOOK-OK")
    """))
