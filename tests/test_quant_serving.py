"""Quantized serving path (PR: Q4_0 weights + int8 KV pages).

Format level (fast lane): Q4_0 round-trip error bounds, the exact
pad-to-block path, per-layer stacked quantization, int8 KV row
round-trips, and the ``KVPoolConfig`` byte math (int8 pages must fit
>= 1.9x in the same pool bytes — ``docs/quantization.md``).

Dispatch level (fast lane): ``quantize_serving_params`` leaf
selection on the real bench-tiny tree, the ``qmm`` hook vs dense
parity, Pallas-kernel-vs-jnp-reference parity across tile shapes,
the int8 paged cache structure, and scale-aware paged decode
attention vs explicitly dequantized pools.

TP level (fast lane): the sharding specs map ``q4_packed`` /
``q4_scales`` by their parent weight's rule and ``k_scale`` /
``v_scale`` like the code buffers, and column-sharding commutes with
quantization (Q4_0 quantizes along K; the head split slices N).

Engine level: a fast q4+int8 run through ``ContinuousServingEngine``
(page_bytes accounting, dispatch counters, prefix-sharing parity over
int8 pages), and the ``slow``-marked e2e divergence gate — the fp32
engine's greedy continuations replayed teacher-forced through the
quantized engine must match at or above the documented bound
(``benchmarks.serving_bench.QUANT_MATCH_BOUND``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.quant import kv_int8, q4_0
from repro.quant.policy import (QuantPolicy, count_q4_leaves, is_q4_leaf,
                                make_qmm, quantize_serving_params)
from repro.serving import (ContinuousServingEngine, KVPoolConfig, Request,
                           SamplingParams)

QUANT_MATCH_BOUND = 0.80    # documented bound, docs/quantization.md


def tiny_cfg(**kw):
    base = dict(name="bench-tiny", arch_type="dense", n_layers=4,
                d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                vocab_size=259, dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)


# ----------------------------------------------------------------------
# Q4_0 format
# ----------------------------------------------------------------------

class TestQ4Format:
    def test_round_trip_error_bounded_by_half_scale(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (96, 8), jnp.float32)
        packed, scales = q4_0.quantize(w)
        wd = q4_0.dequantize(packed, scales)
        # the code grid is asymmetric (-8..+7 times the scale), so the
        # clamped positive side can err by up to one full |scale|
        # (plus the fp16 round-trip of the scale itself)
        bound = jnp.repeat(jnp.abs(scales), q4_0.BLOCK, axis=0) + 1e-6
        assert jnp.all(jnp.abs(wd - w) <= bound)

    def test_block_absmax_is_exact(self):
        # the signed max of each block maps to code 0 or 15 exactly
        # (scale = signed_max / -8), modulo the fp16 scale round-trip
        w = jax.random.normal(jax.random.PRNGKey(1), (64, 4), jnp.float32)
        packed, scales = q4_0.quantize(w)
        wd = q4_0.dequantize(packed, scales)
        wf = np.asarray(w).reshape(-1, q4_0.BLOCK, 4)
        wdf = np.asarray(wd).reshape(-1, q4_0.BLOCK, 4)
        i = np.argmax(np.abs(wf), axis=1)
        got = np.take_along_axis(wdf, i[:, None, :], axis=1)[:, 0, :]
        want = np.take_along_axis(wf, i[:, None, :], axis=1)[:, 0, :]
        assert np.allclose(got, want, rtol=1e-3, atol=1e-6)

    def test_unaligned_k_raises_without_pad(self):
        w = jnp.ones((33, 4), jnp.float32)
        with pytest.raises(ValueError, match="pad=True"):
            q4_0.quantize(w)

    def test_pad_to_block_is_exact(self):
        # zero rows quantize to code 8 -> dequantize to exactly 0.0,
        # so the padded product equals the unpadded product bit-for-bit
        K = 40                                     # pads to 64
        w = jax.random.normal(jax.random.PRNGKey(2), (K, 8), jnp.float32)
        packed, scales = q4_0.quantize(w, pad=True)
        assert packed.shape == (q4_0.padded_k(K) // 2, 8)
        wd = q4_0.dequantize(packed, scales)
        assert jnp.all(wd[K:] == 0.0)
        x = jax.random.normal(jax.random.PRNGKey(3), (5, K), jnp.float32)
        xp = jnp.pad(x, ((0, 0), (0, q4_0.padded_k(K) - K)))
        assert jnp.array_equal(x @ wd[:K], xp @ wd)

    def test_quantize_stacked_matches_per_layer(self):
        w = jax.random.normal(jax.random.PRNGKey(4), (3, 64, 8),
                              jnp.float32)
        ps, ss = q4_0.quantize_stacked(w)
        for i in range(3):
            p, s = q4_0.quantize(w[i])
            assert jnp.array_equal(ps[i], p)
            assert jnp.array_equal(ss[i], s)

    def test_bytes_per_weight(self):
        assert q4_0.BYTES_PER_WEIGHT == 0.5625
        assert q4_0.quantized_bytes((64, 16)) == 64 * 16 // 2 + 2 * 16 * 4


# ----------------------------------------------------------------------
# int8 KV rows
# ----------------------------------------------------------------------

class TestKvInt8:
    def test_round_trip_error_bounded_by_half_scale(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (6, 2, 32),
                              jnp.float32)
        q, s = kv_int8.quantize_rows(x)
        assert q.dtype == jnp.int8 and s.shape == (6, 2)
        xd = kv_int8.dequantize_rows(q, s)
        assert jnp.all(jnp.abs(xd - x) <= s[..., None] * 0.5 + 1e-7)

    def test_zero_rows_round_trip_exactly(self):
        x = jnp.zeros((3, 2, 16), jnp.float32)
        q, s = kv_int8.quantize_rows(x)
        assert jnp.all(q == 0) and jnp.all(s == 0)
        assert jnp.array_equal(kv_int8.dequantize_rows(q, s), x)

    def test_bytes_per_row_head(self):
        assert kv_int8.kv_bytes_per_row_head(32) == 36      # vs 128 fp32


# ----------------------------------------------------------------------
# pool byte math
# ----------------------------------------------------------------------

class TestPoolByteMath:
    def _cfg(self, kv_dtype, head_dim=32):
        return KVPoolConfig(n_pages=8, page_size=16, n_layers=4,
                            n_kv_heads=2, head_dim=head_dim,
                            dtype_bytes=4, kv_dtype=kv_dtype)

    def test_fp32_page_bytes(self):
        assert self._cfg("fp32").page_bytes == 2 * 4 * 16 * 2 * 32 * 4

    def test_int8_page_bytes(self):
        assert self._cfg("int8").page_bytes == 2 * 4 * 16 * 2 * (32 + 4)

    @pytest.mark.parametrize("head_dim", (32, 64, 128))
    def test_capacity_ratio_clears_floor(self, head_dim):
        # 4D/(D+4): 3.56x at 32, asymptotically 4x — floor is 1.9x
        ratio = (self._cfg("fp32", head_dim).page_bytes
                 / self._cfg("int8", head_dim).page_bytes)
        assert ratio >= 1.9
        assert ratio == pytest.approx(4 * head_dim / (head_dim + 4))

    def test_unknown_kv_dtype_raises(self):
        with pytest.raises(ValueError, match="kv_dtype"):
            self._cfg("fp8").page_bytes


# ----------------------------------------------------------------------
# policy: leaf selection + the qmm hook
# ----------------------------------------------------------------------

class TestQuantizeServingParams:
    def test_selects_attn_and_mlp_projections(self):
        model = build_model(tiny_cfg())
        params = model.init(jax.random.PRNGKey(0))
        qp = quantize_serving_params(params)
        # the uniform stack: w_q/w_k/w_v/w_o + w_gate/w_up/w_down
        assert count_q4_leaves(qp) == 7
        lp = qp["layers"]
        assert is_q4_leaf(lp["attn"]["w_q"])
        assert not is_q4_leaf(qp["embed"])
        # stacked (L, K, N) leaves quantize per layer along K
        L, d = 4, 128
        assert lp["attn"]["w_q"]["q4_packed"].shape == (L, d // 2, d)
        assert lp["attn"]["w_q"]["q4_scales"].shape == (L, d // 32, d)

    def test_min_size_spares_small_leaves(self):
        model = build_model(tiny_cfg())
        params = model.init(jax.random.PRNGKey(0))
        assert count_q4_leaves(
            quantize_serving_params(params, min_size=10**9)) == 0

    def test_policy_validates(self):
        with pytest.raises(ValueError, match="weights"):
            QuantPolicy(weights="q8")
        with pytest.raises(ValueError, match="kv_dtype"):
            QuantPolicy(kv_dtype="fp16")
        assert not QuantPolicy().active
        assert QuantPolicy(kv_dtype="int8").active


class TestQmmHook:
    def test_dense_leaf_passthrough(self):
        qmm = make_qmm("ref")
        x = jax.random.normal(jax.random.PRNGKey(6), (3, 8), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(7), (8, 5), jnp.float32)
        assert jnp.array_equal(qmm(x, w), x @ w)

    def test_q4_leaf_matches_dequantized_dense(self):
        K, N = 96, 64
        w = jax.random.normal(jax.random.PRNGKey(8), (K, N), jnp.float32)
        packed, scales = q4_0.quantize(w)
        leaf = {"q4_packed": packed, "q4_scales": scales}
        x = jax.random.normal(jax.random.PRNGKey(9), (2, 3, K),
                              jnp.float32)
        got = make_qmm("ref")(x, leaf)
        want = x @ q4_0.dequantize(packed, scales)
        assert got.shape == (2, 3, N)
        assert jnp.allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_pad_to_block_activation_padding(self):
        K, N = 40, 32                              # K pads to 64
        w = jax.random.normal(jax.random.PRNGKey(10), (K, N), jnp.float32)
        packed, scales = q4_0.quantize(w, pad=True)
        x = jax.random.normal(jax.random.PRNGKey(11), (4, K), jnp.float32)
        got = make_qmm("ref")(x, {"q4_packed": packed,
                                  "q4_scales": scales})
        want = x @ q4_0.dequantize(packed, scales)[:K]
        assert jnp.allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("shape,blocks", [
        ((64, 64), (32, 32)),
        ((128, 96), (64, 32)),
        ((96, 128), (32, 128)),
    ])
    def test_kernel_matches_reference(self, shape, blocks):
        from repro.kernels.ops import q4_matmul
        K, N = shape
        bk, bn = blocks
        w = jax.random.normal(jax.random.PRNGKey(12), (K, N), jnp.float32)
        packed, scales = q4_0.quantize(w)
        x = jax.random.normal(jax.random.PRNGKey(13), (3, K), jnp.float32)
        ref = q4_matmul(x, packed, scales, impl="ref")
        ker = q4_matmul(x, packed, scales, impl="kernel",
                        block_k=bk, block_n=bn)
        assert jnp.allclose(ker, ref, rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# int8 paged cache + attention read path
# ----------------------------------------------------------------------

class TestInt8PagedCache:
    def test_cache_structure(self):
        model = build_model(tiny_cfg())
        cache = model.init_cache(2, 64, page_size=8, n_pages=16,
                                 kv_dtype="int8")
        lc = cache["layers"][0]["self"]
        assert lc["k"].dtype == jnp.int8
        assert lc["k"].shape == (16 * 8, 2, 32)
        assert lc["k_scale"].dtype == jnp.float32
        assert lc["k_scale"].shape == (16 * 8, 2)
        fp = model.init_cache(2, 64, page_size=8, n_pages=16)
        assert "k_scale" not in fp["layers"][0]["self"]

    def test_int8_requires_paged_cache(self):
        model = build_model(tiny_cfg())
        with pytest.raises(ValueError, match="kv_dtype"):
            model.init_cache(2, 64, kv_dtype="int8")
        with pytest.raises(ValueError, match="kv_dtype"):
            model.init_cache(2, 64, page_size=8, n_pages=16,
                             kv_dtype="fp8")

    def test_scaled_ref_matches_dequantized_pool(self):
        from repro.kernels.ref import paged_decode_attention_ref
        P, ps, H, G, D, B = 6, 4, 2, 2, 16, 3
        key = jax.random.PRNGKey(14)
        kv = jax.random.normal(key, (P, ps, H, D), jnp.float32)
        q8, s = kv_int8.quantize_rows(kv)
        q = jax.random.normal(jax.random.PRNGKey(15), (B, H, G, D),
                              jnp.float32)
        bt = jnp.asarray([[1, 2, 0], [3, 4, 5], [2, 0, 0]], jnp.int32)
        lens = jnp.asarray([6, 10, 3], jnp.int32)
        deq = kv_int8.dequantize_rows(q8, s)
        want = paged_decode_attention_ref(q, deq, deq, bt, lens)
        got = paged_decode_attention_ref(q, q8, q8, bt, lens,
                                         k_scales=s, v_scales=s)
        assert jnp.allclose(got, want, rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------------------
# TP sharding of quantized leaves
# ----------------------------------------------------------------------

class TestTpSpecs:
    def test_q4_leaves_shard_by_parent_rule(self):
        from jax.sharding import PartitionSpec as P

        from repro.launch.shardings import serving_tp_param_specs
        model = build_model(tiny_cfg())
        qp = quantize_serving_params(model.init(jax.random.PRNGKey(0)))
        shapes = jax.eval_shape(lambda: qp)
        specs = serving_tp_param_specs(shapes, axis="model")
        attn, mlp = specs["layers"]["attn"], specs["layers"]["mlp"]
        # head-sharded parents: packed AND scales slice their N dim
        assert attn["w_q"]["q4_packed"] == P(None, None, "model")
        assert attn["w_q"]["q4_scales"] == P(None, None, "model")
        # replicated parents stay replicated when quantized
        assert attn["w_o"]["q4_packed"] == P()
        assert mlp["w_down"]["q4_scales"] == P()

    def test_scale_buffers_shard_like_code_buffers(self):
        from jax.sharding import PartitionSpec as P

        from repro.launch.shardings import paged_cache_specs
        model = build_model(tiny_cfg())
        cache = model.init_cache(2, 64, page_size=8, n_pages=16,
                                 kv_dtype="int8")
        specs = paged_cache_specs(jax.eval_shape(lambda: cache),
                                  axis="model")
        lc = specs["layers"][0]["self"]
        assert lc["k"] == P(None, "model", None)
        assert lc["k_scale"] == P(None, "model")
        assert lc["v_scale"] == P(None, "model")

    def test_column_shard_commutes_with_quantize(self):
        # Q4_0 quantizes along K; the head split slices columns (N),
        # so shard-then-quantize == quantize-then-shard byte-for-byte
        w = jax.random.normal(jax.random.PRNGKey(16), (64, 32),
                              jnp.float32)
        packed, scales = q4_0.quantize(w)
        for cols in (slice(0, 16), slice(16, 32)):
            p, s = q4_0.quantize(w[:, cols])
            assert jnp.array_equal(packed[:, cols], p)
            assert jnp.array_equal(scales[:, cols], s)


# ----------------------------------------------------------------------
# engines
# ----------------------------------------------------------------------

def _reqs(n=3, max_new=6, seed=21):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=list(rng.integers(1, 258, 6 + 4 * i)),
                    sampling=SamplingParams(max_new_tokens=max_new))
            for i in range(n)]


class TestQuantEngine:
    def test_q4_int8_engine_serves_and_accounts(self):
        model = build_model(tiny_cfg())
        params = model.init(jax.random.PRNGKey(0))
        qp = QuantPolicy(weights="q4", kv_dtype="int8", impl="ref")
        eng = ContinuousServingEngine(model, params, max_len=48,
                                      max_running=4, page_size=8,
                                      quant=qp)
        # the runner rewrote its params copy; the shared model is clean
        assert count_q4_leaves(eng.core.runner.params) == 7
        assert count_q4_leaves(params) == 0
        assert eng.pool.cfg.page_bytes == 2 * 4 * 8 * 2 * (32 + 4)
        comps = eng.generate(_reqs())
        assert [len(c.tokens) for c in comps] == [6, 6, 6]
        reg = eng.core.registry
        disp = reg.get("runner.quant.q4_dispatch")
        assert disp is not None
        assert disp.value(phase="prefill") > 0
        assert disp.value(phase="decode") > 0

    def test_prefix_sharing_parity_over_int8_pages(self):
        # shared int8 pages (+ CoW of codes AND scales) must not change
        # a single greedy token vs the same engine without the cache
        model = build_model(tiny_cfg())
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(31)
        system = list(rng.integers(1, 258, 24))     # 3 full pages @ ps=8
        reqs = [Request(uid=i,
                        prompt=system + list(rng.integers(1, 258, 4)),
                        sampling=SamplingParams(max_new_tokens=6))
                for i in range(4)]
        qp = QuantPolicy(kv_dtype="int8")
        toks = {}
        for cached in (False, True):
            # max_running=1 serves sequentially, so request 0's pages
            # are published before request 1 admits and can share them
            eng = ContinuousServingEngine(model, params, max_len=64,
                                          max_running=1, page_size=8,
                                          prefix_cache=cached, quant=qp)
            toks[cached] = [c.tokens for c in eng.generate(reqs)]
        assert toks[True] == toks[False]
        assert eng.pool.stats["shared_pages"] > 0

    def test_int8_only_greedy_matches_fp32_on_short_decode(self):
        # int8 KV error at these context lengths is far below bench-tiny
        # argmax margins for a couple of steps; parity here is a cheap
        # canary for the read/write paths (the real accuracy gate is the
        # slow teacher-forced test below)
        model = build_model(tiny_cfg())
        params = model.init(jax.random.PRNGKey(0))
        reqs = _reqs(n=2, max_new=2, seed=41)
        toks = {}
        for name, qp in (("fp32", None),
                         ("int8", QuantPolicy(kv_dtype="int8"))):
            eng = ContinuousServingEngine(model, params, max_len=48,
                                          max_running=4, page_size=8,
                                          quant=qp)
            toks[name] = [c.tokens for c in eng.generate(reqs)]
        assert toks["int8"] == toks["fp32"]


@pytest.mark.slow
class TestDivergenceGate:
    def test_teacher_forced_match_meets_documented_bound(self):
        """The e2e divergence gate (docs/quantization.md): fp32 greedy
        continuations replayed teacher-forced through the q4+int8
        engine must agree on >= QUANT_MATCH_BOUND of positions.  The
        model is briefly warm-trained (fixed seed, deterministic) so
        argmax margins are real; teacher forcing makes the rate
        cascade-free."""
        from repro.data.pipeline import PackedLMDataset
        from repro.training.loop import train
        from repro.training.optimizer import AdamWConfig

        model = build_model(tiny_cfg())
        params0 = model.init(jax.random.PRNGKey(0))
        ds = PackedLMDataset(seq_len=64, n_docs=500, vocab_size=259)
        params, _, _ = train(model, params0, ds.batches(8),
                             AdamWConfig(lr=2e-3, warmup_steps=5,
                                         total_steps=80),
                             steps=80, log_every=1000)

        rng = np.random.default_rng(7)
        prompts = [list(rng.integers(1, 258, 4 + 4 * (i % 3)))
                   for i in range(4)]
        gen = SamplingParams(temperature=0.0, max_new_tokens=12)
        one = SamplingParams(temperature=0.0, max_new_tokens=1)

        def engine(quant):
            return ContinuousServingEngine(model, params, max_len=64,
                                           max_running=8, page_size=8,
                                           quant=quant)

        ref = {c.uid: c.tokens for c in engine(None).generate(
            [Request(uid=i, prompt=p, sampling=gen)
             for i, p in enumerate(prompts)])}
        replay, want = [], []
        for i, p in enumerate(prompts):
            for j in range(len(ref[i])):
                replay.append(Request(uid=len(replay),
                                      prompt=p + ref[i][:j],
                                      sampling=one))
                want.append(ref[i][j])
        qeng = engine(QuantPolicy(weights="q4", kv_dtype="int8",
                                  impl="ref"))
        got = {c.uid: c.tokens for c in qeng.generate(replay)}
        match = sum(int(got[u][0] == want[u]) for u in range(len(want)))
        rate = match / len(want)
        assert rate >= QUANT_MATCH_BOUND, (
            f"teacher-forced greedy match {rate:.3f} under the "
            f"documented bound {QUANT_MATCH_BOUND}")
