"""Per-architecture smoke tests (deliverable f).

Each assigned arch is instantiated as a REDUCED same-family variant
(2 layers, d_model <= 512, <= 4 experts) and runs one forward + one
train step on CPU, asserting output shapes and the absence of NaNs.
The FULL configs are exercised only via the dry-run (see
``repro.launch.dryrun``)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model, reduced_config
from repro.training.loop import make_train_step
from repro.training.optimizer import AdamWConfig, adamw_init


def _batch_for(cfg, B, S, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
    }
    batch["labels"] = batch["tokens"]
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            ks[1], (B, cfg.n_audio_frames, cfg.d_model), jnp.float32)
    if cfg.cross_attn_every:
        batch["image_embeds"] = jax.random.normal(
            ks[2], (B, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch_setup(request):
    import dataclasses
    cfg = reduced_config(get_config(request.param))
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, remat=False,
                              capacity_factor=4.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return request.param, cfg, model, params


def test_reduced_limits(arch_setup):
    name, cfg, model, params = arch_setup
    assert cfg.n_layers <= max(len(cfg.block_pattern), 5) or cfg.n_layers <= 5
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


def test_forward_shapes_no_nan(arch_setup):
    name, cfg, model, params = arch_setup
    B, S = 2, 16
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any(), name
    assert not np.isnan(float(aux)), name


def test_one_train_step(arch_setup):
    name, cfg, model, params = arch_setup
    B, S = 2, 16
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(2))
    step = jax.jit(make_train_step(
        model, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)))
    new_params, opt_state, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"])), name
    assert np.isfinite(float(metrics["grad_norm"])), name
    # parameters actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert moved, f"{name}: train step did not update parameters"


def test_prefill_decode_consistency(arch_setup):
    name, cfg, model, params = arch_setup
    B, S = 2, 10
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(3))
    logits, _ = model.forward(params, batch)
    cache = model.init_cache(B, 24)
    pl_logits, cache = model.prefill(params, batch, cache)
    np.testing.assert_allclose(
        np.asarray(pl_logits[:, 0], np.float32),
        np.asarray(logits[:, -1], np.float32), rtol=2e-4, atol=2e-4)
    tok = jnp.argmax(pl_logits, -1).astype(jnp.int32)
    dl, cache = model.decode_step(params, cache, tok, jnp.asarray(S))
    ext = dict(batch)
    ext["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    ext["labels"] = ext["tokens"]
    fl, _ = model.forward(params, ext)
    np.testing.assert_allclose(
        np.asarray(dl[:, 0], np.float32),
        np.asarray(fl[:, -1], np.float32), rtol=5e-3, atol=5e-3)
